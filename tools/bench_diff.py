#!/usr/bin/env python3
"""Diff a roofline bench report against the committed baseline.

Usage: bench_diff.py BASELINE.json CURRENT.json

Both files use the schema `rust/benches/dqn_runtime.rs --json` writes:
{"bench": ..., "roofline": [{"engine", "batch", "per_sample_us", ...}],
 "training": [{"mode", "jobs", "batch", "per_sample_us", ...}]}.
Roofline cells are matched by (engine, batch), training cells by
(mode, jobs, batch); both are compared on per_sample_us:

  * > 10% slower than baseline  -> GitHub Actions warning annotation
  * > 2x slower than baseline   -> error annotation + exit 1

The 2x gate is enforcing: the committed BENCH_dqn_runtime.json is a
shared-CI-core envelope, not a provisional schema stub, so a cell
beyond 2x fails the job. If a slowdown is intentional, re-record by
copying a CI-produced BENCH_dqn_runtime.json over the baseline in the
same PR that causes it. (A baseline carrying `"provisional": true`
would downgrade errors to warnings — that escape hatch is kept for
bootstrapping new benches, but the committed baseline no longer uses
it for the roofline section. The training section has its own
per-section flag, `"training_provisional": true`, so a freshly
bootstrapped training baseline can warn without loosening the
roofline gate.)

Cells present on one side only never fail the gate (the AOT engine row
exists only where compiled artifacts do); they are reported so silent
coverage loss is visible in the log.

Stdlib only: the CI image must not need pip.
"""

import json
import sys

WARN_RATIO = 1.10
FAIL_RATIO = 2.0


def roofline_cells(report):
    cells = {}
    for row in report.get("roofline", []):
        cells[("roofline", row["engine"], int(row["batch"]))] = float(row["per_sample_us"])
    return cells


def training_cells(report):
    cells = {}
    for row in report.get("training", []):
        key = ("training", f'{row["mode"]}/jobs={int(row["jobs"])}', int(row["batch"]))
        cells[key] = float(row["per_sample_us"])
    return cells


def diff_section(name, base_cells, cur_cells, provisional):
    """Compare one section's cells; return the number of hard failures
    (0 if the section is provisional — those are downgraded)."""
    failures = 0
    for key in sorted(base_cells):
        _, engine, batch = key
        if key not in cur_cells:
            print(f"note: cell {engine}/batch={batch} absent from current report")
            continue
        base, cur = base_cells[key], cur_cells[key]
        if base <= 0.0:
            print(f"note: cell {engine}/batch={batch} has a degenerate baseline ({base})")
            continue
        ratio = cur / base
        label = (
            f"{engine} batch={batch}: {cur:.3f} us/sample vs baseline "
            f"{base:.3f} ({ratio:.2f}x)"
        )
        if ratio > FAIL_RATIO:
            failures += 1
            severity = "warning" if provisional else "error"
            print(f"::{severity}::{label} — exceeds the {FAIL_RATIO:.0f}x failure gate")
        elif ratio > WARN_RATIO:
            print(f"::warning::{label} — exceeds the {WARN_RATIO - 1:.0%} regression budget")
        else:
            print(f"ok: {label}")

    for key in sorted(set(cur_cells) - set(base_cells)):
        print(f"note: new cell {key[1]}/batch={key[2]} not in baseline yet")

    if failures and provisional:
        print(
            f"{failures} {name} cell(s) beyond the failure gate, but that section's "
            "baseline is provisional — reported as warnings only"
        )
        return 0
    return failures


def main(argv):
    if len(argv) != 3:
        print(f"usage: {argv[0]} BASELINE.json CURRENT.json", file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        baseline = json.load(f)
    with open(argv[2]) as f:
        current = json.load(f)

    base_roofline = roofline_cells(baseline)
    if not base_roofline:
        print(f"::error::baseline {argv[1]} has no roofline cells")
        return 1

    failures = diff_section(
        "roofline", base_roofline, roofline_cells(current), bool(baseline.get("provisional"))
    )
    base_training = training_cells(baseline)
    failures += diff_section(
        "training",
        base_training,
        training_cells(current),
        bool(baseline.get("provisional")) or bool(baseline.get("training_provisional")),
    )

    if failures:
        return 1
    total = len(base_roofline) + len(base_training)
    print(f"per-sample timings within budget across {total} baseline cells")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
