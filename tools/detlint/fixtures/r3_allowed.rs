use std::time::Instant;

pub fn wall_clock() -> f64 {
    // detlint: allow(R3) -- fixture: reporting-only, never mixed into fingerprint()
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
