use std::collections::HashMap;

pub struct Hub {
    table: HashMap<u64, f64>,
}

pub fn digest(hub: &Hub) -> u64 {
    let mut acc = 0u64;
    for (k, v) in hub.table.iter() {
        acc ^= k.wrapping_add(v.to_bits());
    }
    acc
}
