pub fn mean(rows: &[Vec<f32>]) -> f32 {
    // The legal shape: order-sequenced f64 accumulation, one cast at
    // the end (the discipline of runtime/params.rs).
    let mut acc = 0.0f64;
    for row in rows {
        for &x in row {
            acc += x as f64;
        }
    }
    (acc / rows.len() as f64) as f32
}

pub fn bounded(pair: [f32; 2]) -> f32 {
    let mut small = 0.0f32;
    // detlint: allow(R2) -- fixture: two-element sum, order fixed by the array
    small += pair[0] + pair[1];
    small
}
