pub fn mean(rows: &[Vec<f32>]) -> f32 {
    let mut acc = 0.0f32;
    for row in rows {
        acc += row.iter().sum::<f32>();
    }
    let mut count = 0.0f32;
    for _row in rows {
        count += 1.0;
    }
    acc / count
}
