pub trait ReplayPolicy {
    /// Determinism: canonical order, stable across workers.
    fn get(&self, i: usize) -> u64;

    /// Default method bodies are not trait items.
    /// Determinism: derived from `get`, inherits its contract.
    fn first(&self) -> u64 {
        let x = self.get(0);
        x
    }

    fn latest(&self) -> Option<u64>; // detlint: allow(R5) -- fixture: contract documented on the blanket impl
}
