pub fn checked(v: Option<u32>) -> u32 {
    v.expect("fixture: invariant upheld by caller") // detlint: allow(R4) -- fixture: invariant documented at the call site
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(super::checked(Some(1)), Some(1).unwrap());
    }
}
