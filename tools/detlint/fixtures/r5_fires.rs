pub trait TunableRuntime: Sync {
    /// Determinism: pure function of its arguments.
    fn id(&self) -> u32;

    /// Runs one episode (no contract documented — fires).
    fn run_episode(&self, seed: u64) -> f64;
}
