// detlint: allow(R1) -- fixture: import kept to exercise suppression
use std::collections::HashMap;

pub struct Hub {
    table: HashMap<u64, f64>, // detlint: allow(R1) -- fixture: lookup-only episode cache
}

pub fn snapshot(hub: &Hub) -> Vec<(u64, f64)> {
    // detlint: allow(R1) -- fixture: sorted by the next statement before any digest sees it
    let mut rows: Vec<(u64, f64)> = hub.table.iter().map(|(k, v)| (*k, *v)).collect();
    rows.sort_unstable_by_key(|r| r.0);
    rows
}

pub fn ordered_keys(hub: &Hub) -> Vec<u64> {
    let sorted: std::collections::BTreeSet<u64> = hub.table.keys().copied().collect();
    sorted.into_iter().collect()
}
