pub fn take(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn must(v: Option<u32>) -> u32 {
    v.expect("fixture: must be set")
}
