//! Golden fixture tests: each rule has one fixture proving it fires
//! (checked against an expected-diagnostics file) and one proving the
//! `detlint: allow` annotation (or the legal idiom) silences it.

#![allow(clippy::unwrap_used)]

use detlint::{scan_file, Diagnostic};

/// Parse an expected-diagnostics file: one `<line> <rule-id>` per line.
fn parse_expected(expected: &str) -> Vec<(usize, String)> {
    expected
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let mut parts = l.split_whitespace();
            let line: usize = parts.next().expect("line number").parse().expect("numeric line");
            let rule = parts.next().expect("rule id").to_string();
            (line, rule)
        })
        .collect()
}

fn found(diags: &[Diagnostic]) -> Vec<(usize, String)> {
    diags.iter().map(|d| (d.line, d.rule.id().to_string())).collect()
}

fn check_fires(label: &str, source: &str, expected: &str) {
    let diags = scan_file(label, source);
    assert_eq!(
        found(&diags),
        parse_expected(expected),
        "diagnostics for {label} diverge from the golden file:\n{diags:#?}"
    );
}

fn check_clean(label: &str, source: &str) {
    let diags = scan_file(label, source);
    assert!(diags.is_empty(), "expected {label} to scan clean, got:\n{diags:#?}");
}

#[test]
fn r1_fires_golden() {
    check_fires(
        "rust/src/coordinator/hub.rs",
        include_str!("../fixtures/r1_fires.rs"),
        include_str!("../fixtures/expected/r1_fires.txt"),
    );
}

#[test]
fn r1_allowed_is_clean() {
    check_clean("rust/src/coordinator/hub.rs", include_str!("../fixtures/r1_allowed.rs"));
}

#[test]
fn r2_fires_golden() {
    check_fires(
        "rust/src/runtime/params.rs",
        include_str!("../fixtures/r2_fires.rs"),
        include_str!("../fixtures/expected/r2_fires.txt"),
    );
}

#[test]
fn r2_allowed_is_clean() {
    check_clean("rust/src/runtime/params.rs", include_str!("../fixtures/r2_allowed.rs"));
}

#[test]
fn r3_fires_golden() {
    check_fires(
        "rust/src/campaign/shared.rs",
        include_str!("../fixtures/r3_fires.rs"),
        include_str!("../fixtures/expected/r3_fires.txt"),
    );
}

#[test]
fn r3_allowed_is_clean() {
    check_clean("rust/src/campaign/shared.rs", include_str!("../fixtures/r3_allowed.rs"));
}

#[test]
fn r4_fires_golden() {
    check_fires(
        "rust/src/util/lint_fixture.rs",
        include_str!("../fixtures/r4_fires.rs"),
        include_str!("../fixtures/expected/r4_fires.txt"),
    );
}

#[test]
fn r4_allowed_is_clean() {
    check_clean("rust/src/util/lint_fixture.rs", include_str!("../fixtures/r4_allowed.rs"));
}

#[test]
fn r4_does_not_apply_outside_library_code() {
    // Same source as the firing fixture, but under benches: exempt.
    check_clean("rust/benches/lint_fixture.rs", include_str!("../fixtures/r4_fires.rs"));
}

#[test]
fn r5_fires_golden() {
    check_fires(
        "rust/src/backend/mod.rs",
        include_str!("../fixtures/r5_fires.rs"),
        include_str!("../fixtures/expected/r5_fires.txt"),
    );
}

#[test]
fn r5_allowed_is_clean() {
    check_clean("rust/src/coordinator/replay/mod.rs", include_str!("../fixtures/r5_allowed.rs"));
}

#[test]
fn r0_bad_allow_golden() {
    check_fires(
        "rust/src/util/lint_fixture.rs",
        include_str!("../fixtures/r0_bad_allow.rs"),
        include_str!("../fixtures/expected/r0_bad_allow.txt"),
    );
}
