//! detlint — determinism & invariant lint for this repository.
//!
//! Every result the reproduction claims (1/2/4-worker fingerprint
//! identity, bit-identical shared learning) rests on invariants that
//! used to live only in comments: order-sequenced `f64` accumulation,
//! `BTreeMap`-only merge/digest paths, seeded RNG, no ambient clocks in
//! anything a fingerprint can reach. detlint turns those comments into
//! machine-checked rules (see `docs/determinism.md` for the catalogue
//! and rationale):
//!
//! * **R1** — no `HashMap`/`HashSet` in fingerprint/digest/merge
//!   modules at all; elsewhere, no *iteration* over hash containers
//!   (`.iter()`, `.values()`, `.keys()`, `.into_iter()`, `.drain()`,
//!   `for … in`) unless the same statement chain sorts the result.
//! * **R2** — no `f32` accumulation loops in restricted modules;
//!   reductions must use the order-sequenced `f64` discipline of
//!   `runtime/params.rs`.
//! * **R3** — no wall-clock / ambient nondeterminism (`Instant::now`,
//!   `SystemTime`, `thread::current`, `std::env`) in restricted
//!   modules.
//! * **R4** — no `.unwrap()` / `.expect("…")` in library code under
//!   `rust/src` (`#[cfg(test)]` regions are exempt).
//! * **R5** — every `fn` on the `TunableRuntime` / `Agent` /
//!   `ReplayPolicy` seams documents its determinism contract
//!   (a doc line containing "Determinism").
//!
//! Suppression is per-site and must carry a reason:
//!
//! ```text
//! // detlint: allow(R4) -- invariant: entry inserted two lines up
//! ```
//!
//! A trailing annotation covers its own line; an annotation on a
//! comment-only line covers the next line that has code. An annotation
//! without a ` -- reason` is itself a diagnostic (R0).
//!
//! The scanner is a comment/string-aware line scanner, not a parser
//! (`syn` is not in the offline image). Known limits, acceptable for
//! this codebase: raw byte-strings with embedded quotes are not
//! handled; `.expect(` only fires when the opening `"` of the message
//! is on the same line; hash-container tracking is per-file and
//! name-based. The corresponding fixture corpus lives in
//! `tools/detlint/fixtures/`.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Lint rules. `BadAllow` (reported as `R0`) marks a malformed
/// suppression annotation, which must never pass silently.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    BadAllow,
    R1,
    R2,
    R3,
    R4,
    R5,
}

impl Rule {
    /// The five checked rules, in report order (`BadAllow` is emitted
    /// by the annotation parser, not checked against code).
    pub const CHECKS: [Rule; 5] = [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5];

    pub fn id(self) -> &'static str {
        match self {
            Rule::BadAllow => "R0",
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
        }
    }

    /// One-line description for the summary table.
    pub fn describe(self) -> &'static str {
        match self {
            Rule::BadAllow => "malformed detlint annotation (missing rule or ` -- reason`)",
            Rule::R1 => "hash-container iteration on a fingerprint/digest/merge path",
            Rule::R2 => "f32 accumulation in a restricted module (use sequenced f64)",
            Rule::R3 => "ambient nondeterminism (clock/env/thread-id) in a restricted module",
            Rule::R4 => "unwrap()/expect() in library code (tests exempt)",
            Rule::R5 => "seam trait fn without a documented determinism contract",
        }
    }

    pub fn parse(s: &str) -> Option<Rule> {
        match s.trim() {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One finding: `path:line: rule: message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.path, self.line, self.rule, self.message)
    }
}

/// A source line split into code (comments stripped, string contents
/// blanked but their delimiting quotes kept) and comment text.
#[derive(Debug, Default)]
struct SrcLine {
    code: String,
    comment: String,
}

impl SrcLine {
    fn has_code(&self) -> bool {
        !self.code.trim().is_empty()
    }
}

/// Split a file into per-line (code, comment) pairs. String contents
/// are blanked so patterns inside messages never fire; the delimiting
/// quotes survive so `.expect("` is still visible. Nested block
/// comments, char literals (including `b'"'`) and raw strings are
/// handled; lifetimes are not mistaken for char literals.
fn preprocess(source: &str) -> Vec<SrcLine> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let chars: Vec<char> = source.chars().collect();
    let mut lines: Vec<SrcLine> = Vec::new();
    let mut cur = SrcLine::default();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r'
                    && !cur.code.ends_with(|p: char| p.is_alphanumeric() || p == '_')
                {
                    // Possible raw string r"…" / r#"…"#.
                    let mut j = i + 1;
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        cur.code.push('"');
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if next == Some('\\') {
                        // Escaped char literal: skip to the closing quote.
                        let mut j = i + 3; // past '\ and the escaped char
                        while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                            j += 1;
                        }
                        cur.code.push_str("' '");
                        i = if chars.get(j) == Some(&'\'') { j + 1 } else { j };
                    } else if chars.get(i + 2) == Some(&'\'') && next != Some('\'') {
                        // Plain char literal 'x' (covers '"' too).
                        cur.code.push_str("' '");
                        i += 3;
                    } else {
                        // Lifetime.
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    cur.code.push(' ');
                    // Skip the escaped char unless it is the newline of a
                    // line-continuation (the top-of-loop handles those).
                    if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        cur.code.push('"');
                        st = St::Code;
                        i += 1 + hashes as usize;
                    } else {
                        cur.code.push(' ');
                        i += 1;
                    }
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// Parse `detlint: allow(R1, R4) -- reason` out of a comment. Returns
/// the allowed rules, or a `BadAllow` diagnostic if the annotation is
/// present but malformed (unknown rule, or no ` -- reason`).
fn parse_allow(comment: &str) -> Option<Result<Vec<Rule>, String>> {
    let at = comment.find("detlint:")?;
    let rest = comment[at + "detlint:".len()..].trim_start();
    let Some(args) = rest.strip_prefix("allow(") else {
        return Some(Err("expected `allow(<rule>, …)` after `detlint:`".to_string()));
    };
    let Some(close) = args.find(')') else {
        return Some(Err("unclosed `allow(` in detlint annotation".to_string()));
    };
    let mut rules = Vec::new();
    for part in args[..close].split(',') {
        match Rule::parse(part) {
            Some(r) => rules.push(r),
            None => {
                return Some(Err(format!("unknown rule {:?} in detlint annotation", part.trim())))
            }
        }
    }
    if rules.is_empty() {
        return Some(Err("empty rule list in detlint annotation".to_string()));
    }
    let tail = args[close + 1..].trim_start();
    match tail.strip_prefix("--") {
        Some(reason) if !reason.trim().is_empty() => Some(Ok(rules)),
        _ => Some(Err("detlint annotation needs a reason: `-- <why this is safe>`".to_string())),
    }
}

/// Where a file sits in the rule matrix, derived from its path.
struct FileClass {
    /// Fingerprint/digest/merge-reachable module: R1 (strict), R2, R3.
    restricted: bool,
    /// Library code under `rust/src`: R4 applies outside tests.
    library: bool,
}

fn classify(path: &str) -> FileClass {
    let p = path.replace('\\', "/");
    const RESTRICTED: [&str; 8] = [
        "coordinator/hub.rs",
        "campaign/collector.rs",
        "campaign/report.rs",
        "campaign/shared.rs",
        // The async driver picks which generation every worker trains
        // against; a hash-ordered queue or clock-derived decision here
        // would change merge order, and with it the hub digest.
        "campaign/async_shared.rs",
        "runtime/params.rs",
        // The dense kernels compute every Q-value a fingerprinted
        // trajectory consumes: an f32 accumulation or ambient-state
        // read here would break bitwise reproducibility at the root.
        "runtime/native/kernels.rs",
        // The fused cross-job trainer stacks every live job's
        // minibatch through these same reductions; its claim to be
        // bitwise-identical to the sequential path holds only under
        // the identical f64/ordering discipline.
        "runtime/native/fused.rs",
    ];
    // Directory-scoped restrictions: replay policies and the on-disk
    // campaign store (its frames round-trip fingerprinted bits, so any
    // iteration-order or clock dependence there corrupts resume).
    let restricted = RESTRICTED.iter().any(|m| p.ends_with(m))
        || p.contains("coordinator/replay/")
        || p.contains("campaign/store/");
    let library = p.contains("rust/src/");
    FileClass { restricted, library }
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does `pat` occur in `code` with no identifier character immediately
/// before it (so `q.iter()` does not match `freq.iter()`)?
fn find_with_boundary(code: &str, pat: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = code[from..].find(pat) {
        let at = from + rel;
        let bounded = at == 0 || !code[..at].chars().next_back().is_some_and(is_ident_char);
        if bounded {
            return true;
        }
        from = at + pat.len();
    }
    false
}

/// Extract the identifier that ends at byte offset `end` (exclusive),
/// skipping trailing whitespace.
fn ident_ending_at(code: &str, end: usize) -> Option<String> {
    let head = code[..end].trim_end();
    let start = head
        .char_indices()
        .rev()
        .take_while(|&(_, c)| is_ident_char(c))
        .last()
        .map(|(i, _)| i)?;
    let ident = &head[start..];
    if ident.is_empty() || ident.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(ident.to_string())
}

/// If this line declares a hash-container binding or field
/// (`name: …HashMap<…>` / `name = HashMap::new()`), return its name.
fn hash_decl_name(code: &str) -> Option<String> {
    let pos = match (code.find("HashMap"), code.find("HashSet")) {
        (Some(a), Some(b)) => a.min(b),
        (Some(a), None) => Some(a)?,
        (None, Some(b)) => Some(b)?,
        (None, None) => return None,
    };
    // Walk back over type-ish characters to the `:` (type ascription)
    // or `=` (initializer) that binds the name.
    let bytes = code.as_bytes();
    let mut k = pos;
    while k > 0 {
        let c = bytes[k - 1] as char;
        if c == ':' {
            if k >= 2 && bytes[k - 2] == b':' {
                k -= 2; // path separator `::`, keep walking
                continue;
            }
            return ident_ending_at(code, k - 1);
        }
        if c == '=' {
            return ident_ending_at(code, k - 1);
        }
        if is_ident_char(c) || matches!(c, '<' | '>' | '&' | ' ' | '\t' | '(' | ',') {
            k -= 1;
            continue;
        }
        return None;
    }
    None
}

/// Does this line iterate the hash container `name`? Returns the
/// offending operation for the message.
fn iteration_hit(code: &str, name: &str) -> Option<String> {
    const METHODS: [&str; 8] = [
        ".iter()",
        ".iter_mut()",
        ".into_iter()",
        ".values()",
        ".values_mut()",
        ".keys()",
        ".drain(",
        ".retain(",
    ];
    for m in METHODS {
        let pat = format!("{name}{m}");
        if find_with_boundary(code, &pat) {
            return Some(format!("{name}{m}"));
        }
    }
    if code.contains("for ") {
        for prefix in ["in &mut ", "in &", "in "] {
            let pat = format!("{prefix}{name}");
            let mut from = 0;
            while let Some(rel) = code[from..].find(&pat) {
                let at = from + rel;
                let end = at + pat.len();
                let before_ok =
                    at == 0 || !code[..at].chars().next_back().is_some_and(is_ident_char);
                let after_ok = !code[end..].chars().next().is_some_and(is_ident_char);
                if before_ok && after_ok {
                    return Some(format!("for … in {name}"));
                }
                from = end;
            }
        }
    }
    None
}

/// The statement chain starting at `start`: lines up to and including
/// the first line containing `;` (capped at 8 lines).
fn chain_text(lines: &[SrcLine], start: usize) -> String {
    let mut out = String::new();
    for line in lines.iter().skip(start).take(8) {
        out.push_str(&line.code);
        out.push('\n');
        if line.code.contains(';') {
            break;
        }
    }
    out
}

/// Seam traits whose every `fn` must document its determinism contract.
const SEAM_TRAITS: [&str; 3] = ["TunableRuntime", "Agent", "ReplayPolicy"];

/// Scan one file. `path` is only used to classify the file and label
/// diagnostics, so fixture tests can pass synthetic paths.
pub fn scan_file(path: &str, source: &str) -> Vec<Diagnostic> {
    let class = classify(path);
    let lines = preprocess(source);
    let n = lines.len();
    let mut diags: Vec<Diagnostic> = Vec::new();

    // Brace depth before/after each line (over blanked code only).
    let mut depth_before = vec![0i64; n];
    let mut depth_after = vec![0i64; n];
    let mut d = 0i64;
    for (i, line) in lines.iter().enumerate() {
        depth_before[i] = d;
        for c in line.code.chars() {
            match c {
                '{' => d += 1,
                '}' => d -= 1,
                _ => {}
            }
        }
        depth_after[i] = d;
    }

    // `#[cfg(test)]` regions: the attribute line, the item it guards,
    // and (if the item opens a brace) everything until that brace
    // closes. All rules skip test code — it cannot perturb runtime
    // determinism, and R4 explicitly exempts it.
    let mut in_test = vec![false; n];
    let mut pending_cfg = false;
    let mut region_floor: Option<i64> = None;
    for i in 0..n {
        if let Some(floor) = region_floor {
            in_test[i] = true;
            if depth_after[i] <= floor {
                region_floor = None;
            }
            continue;
        }
        if lines[i].code.contains("#[cfg(test)]") {
            in_test[i] = true;
            pending_cfg = true;
            continue;
        }
        if pending_cfg && lines[i].has_code() {
            in_test[i] = true;
            // Further attribute lines (`#[allow(...)]`, `#[test]`, ...)
            // stacked between the cfg and its item stay part of the
            // pending prefix — the guarded item is the first
            // non-attribute code line.
            if lines[i].code.trim_start().starts_with("#[") {
                continue;
            }
            pending_cfg = false;
            if depth_after[i] > depth_before[i] {
                region_floor = Some(depth_before[i]);
            }
        }
    }

    // Per-line allowed rules from annotations. A trailing annotation
    // covers its own line; a comment-line annotation covers the next
    // line with code.
    let mut allowed: Vec<Vec<Rule>> = vec![Vec::new(); n];
    let mut pending_allow: Vec<Rule> = Vec::new();
    for i in 0..n {
        match parse_allow(&lines[i].comment) {
            Some(Ok(rules)) => {
                if lines[i].has_code() {
                    allowed[i].extend(rules);
                } else {
                    pending_allow.extend(rules);
                }
            }
            Some(Err(msg)) => {
                diags.push(Diagnostic {
                    path: path.to_string(),
                    line: i + 1,
                    rule: Rule::BadAllow,
                    message: msg,
                });
            }
            None => {}
        }
        if lines[i].has_code() && !pending_allow.is_empty() {
            allowed[i].append(&mut pending_allow);
        }
    }

    let push = |diags: &mut Vec<Diagnostic>, line: usize, rule: Rule, message: String| {
        if !allowed[line].contains(&rule) {
            diags.push(Diagnostic { path: path.to_string(), line: line + 1, rule, message });
        }
    };

    // Pass 1: collect hash-container binding names and f32-typed
    // mutable accumulators (non-test code).
    let mut hash_names: Vec<String> = Vec::new();
    let mut f32_names: Vec<String> = Vec::new();
    for i in 0..n {
        if in_test[i] {
            continue;
        }
        let code = &lines[i].code;
        if let Some(name) = hash_decl_name(code) {
            if !hash_names.contains(&name) {
                hash_names.push(name);
            }
        }
        if code.contains("f32") {
            if let Some(at) = code.find("let mut ") {
                let name: String = code[at + "let mut ".len()..]
                    .chars()
                    .take_while(|&c| is_ident_char(c))
                    .collect();
                if !name.is_empty() && !f32_names.contains(&name) {
                    f32_names.push(name);
                }
            }
        }
    }

    // Pass 2: per-line rules.
    for i in 0..n {
        if in_test[i] {
            continue;
        }
        let code = &lines[i].code;
        if !lines[i].has_code() {
            continue;
        }

        // R1 strict tier: restricted modules must not mention hash
        // containers at all (BTreeMap is the only legal merge carrier).
        if class.restricted && (code.contains("HashMap") || code.contains("HashSet")) {
            push(
                &mut diags,
                i,
                Rule::R1,
                "hash container in a fingerprint/digest/merge module; use BTreeMap/BTreeSet"
                    .to_string(),
            );
        } else {
            // R1 general tier: no unsorted iteration over a tracked
            // hash container anywhere scanned.
            for name in &hash_names {
                if let Some(op) = iteration_hit(code, name) {
                    let chain = chain_text(&lines, i);
                    let sorted = chain.contains("sort") || chain.contains("BTree");
                    if !sorted {
                        push(
                            &mut diags,
                            i,
                            Rule::R1,
                            format!(
                                "iteration over hash container `{op}` with no sort on the \
                                 statement chain"
                            ),
                        );
                    }
                    break;
                }
            }
        }

        if class.restricted {
            // R2: f32 accumulation (the PR 3 ensemble-median class of
            // bug). Flag `+=` on an f32-typed line, `sum::<f32>` and
            // `fold(0.0f32 / 0f32` reductions.
            let mut f32_accum = (code.contains("+=") && code.contains("f32"))
                || code.contains("sum::<f32>")
                || code.contains("fold(0.0f32")
                || code.contains("fold(0f32");
            if !f32_accum && code.contains("+=") {
                // Accumulation into a binding declared `let mut x … f32`
                // earlier in the file.
                f32_accum = f32_names.iter().any(|name| {
                    find_with_boundary(code, &format!("{name} +="))
                        || code.contains(&format!("*{name} +="))
                });
            }
            if f32_accum {
                push(
                    &mut diags,
                    i,
                    Rule::R2,
                    "f32 accumulation in a restricted module; use the order-sequenced f64 \
                     discipline of runtime/params.rs"
                        .to_string(),
                );
            }

            // R3: ambient nondeterminism near fingerprint/digest paths.
            const AMBIENT: [&str; 5] =
                ["Instant::now", "SystemTime", "thread::current", "std::env::", "env::var"];
            for pat in AMBIENT {
                if code.contains(pat) {
                    push(
                        &mut diags,
                        i,
                        Rule::R3,
                        format!("ambient nondeterminism `{pat}` in a restricted module"),
                    );
                    break;
                }
            }
        }

        // R4: unwrap/expect in library code.
        if class.library {
            if code.contains(".unwrap()") {
                push(
                    &mut diags,
                    i,
                    Rule::R4,
                    "unwrap() in library code; return Result (anyhow::Context) or restructure"
                        .to_string(),
                );
            }
            if code.contains(".expect(\"") {
                push(
                    &mut diags,
                    i,
                    Rule::R4,
                    "expect() in library code; return Result (anyhow::Context) or restructure"
                        .to_string(),
                );
            }
        }
    }

    // Pass 3 (R5): every fn on a seam trait documents its determinism
    // contract with a doc line containing "Determinism".
    let mut i = 0;
    while i < n {
        let code = &lines[i].code;
        let is_seam = SEAM_TRAITS.iter().any(|t| {
            let pat = format!("pub trait {t}");
            code.find(&pat).is_some_and(|at| {
                !code[at + pat.len()..].chars().next().is_some_and(is_ident_char)
            })
        });
        if !is_seam || in_test[i] {
            i += 1;
            continue;
        }
        let trait_depth = depth_before[i];
        let mut j = i + 1;
        while j < n && depth_before[j] > trait_depth {
            // A trait item lives at depth trait_depth + 1; anything
            // deeper is a default-method body.
            if depth_before[j] == trait_depth + 1 {
                let trimmed = lines[j].code.trim_start();
                if trimmed.starts_with("fn ") || trimmed.starts_with("unsafe fn ") {
                    let name_part = trimmed.trim_start_matches("unsafe ");
                    let name: String = name_part["fn ".len()..]
                        .chars()
                        .take_while(|&c| is_ident_char(c))
                        .collect();
                    let mut documented = false;
                    let mut k = j;
                    while k > 0 {
                        k -= 1;
                        let above = &lines[k];
                        if above.comment.contains("Determinism") {
                            documented = true;
                            break;
                        }
                        let attr_only = !above.has_code()
                            || above.code.trim_start().starts_with("#[");
                        if !attr_only {
                            break;
                        }
                    }
                    if !documented {
                        push(
                            &mut diags,
                            j,
                            Rule::R5,
                            format!(
                                "seam trait fn `{name}` lacks a determinism contract \
                                 (add a `/// Determinism: …` doc line)"
                            ),
                        );
                    }
                }
            }
            j += 1;
        }
        i = j;
    }

    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Recursively collect `.rs` files under `dir`, sorted by path so
/// output (and the diagnostic fingerprint of a run) is deterministic.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan the repository rooted at `root`: `rust/src`, `rust/benches`
/// and `examples` (`rust/tests` and `tools/` are out of scope — test
/// code is exempt by design, and detlint does not lint itself).
pub fn scan_repo(root: &Path) -> io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/benches", "examples"] {
        collect_rs(&root.join(sub), &mut files)?;
    }
    let mut diags = Vec::new();
    for file in files {
        let source = fs::read_to_string(&file)?;
        let label = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        diags.extend(scan_file(&label, &source));
    }
    Ok(diags)
}

/// Per-rule counts for the summary table, in `R0..R5` order.
pub fn rule_counts(diags: &[Diagnostic]) -> Vec<(Rule, usize)> {
    let mut order = vec![Rule::BadAllow];
    order.extend(Rule::CHECKS);
    order
        .into_iter()
        .map(|r| (r, diags.iter().filter(|d| d.rule == r).count()))
        .collect()
}

/// JSON-encode diagnostics (hand-rolled: no serde in the image).
pub fn to_json(diags: &[Diagnostic]) -> String {
    fn esc(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }
    let mut out = String::from("[");
    for (i, dg) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"path\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\"}}",
            esc(&dg.path),
            dg.line,
            dg.rule,
            esc(&dg.message)
        ));
    }
    out.push(']');
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn rules_at(diags: &[Diagnostic]) -> Vec<(usize, Rule)> {
        diags.iter().map(|d| (d.line, d.rule)).collect()
    }

    #[test]
    fn preprocess_blanks_strings_and_comments() {
        let lines = preprocess("let x = \"a.unwrap()\"; // .unwrap()\nlet y = 1;");
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].comment.contains(".unwrap()"));
        assert!(lines[0].code.contains('"'), "delimiting quotes survive");
        assert_eq!(lines[1].code, "let y = 1;");
    }

    #[test]
    fn preprocess_handles_quote_char_literal() {
        // The json.rs idiom that motivated `.expect("` matching: a
        // byte-char literal containing a double quote must not open a
        // string.
        let lines = preprocess("self.expect(b'\"')?;\nlet z = 2;");
        assert_eq!(lines[1].code, "let z = 2;");
        assert!(!lines[0].code.contains('"'), "char-literal quote blanked");
    }

    #[test]
    fn preprocess_keeps_lifetimes() {
        let lines = preprocess("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(lines[0].code.contains("'a"));
    }

    #[test]
    fn r4_fires_on_unwrap_and_expect_in_library_code() {
        let src = "pub fn f() { x.unwrap(); }\npub fn g() { y.expect(\"msg\"); }\n";
        let d = scan_file("rust/src/foo.rs", src);
        assert_eq!(rules_at(&d), vec![(1, Rule::R4), (2, Rule::R4)]);
    }

    #[test]
    fn r4_exempts_tests_and_non_src() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(scan_file("rust/src/foo.rs", src).is_empty());
        assert!(scan_file("rust/benches/b.rs", "fn f() { x.unwrap(); }").is_empty());
    }

    #[test]
    fn cfg_test_region_survives_stacked_attributes() {
        // The repo's test mods carry `#[allow(clippy::unwrap_used)]`
        // between the cfg and the mod; the region must still cover the
        // mod body, and must still end when its brace closes.
        let src = "#[cfg(test)]\n#[allow(clippy::unwrap_used)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\npub fn f() { y.unwrap(); }\n";
        let d = scan_file("rust/src/foo.rs", src);
        assert_eq!(rules_at(&d), vec![(6, Rule::R4)]);
    }

    #[test]
    fn r4_ignores_expect_method_on_parser() {
        // util/json.rs defines its own `expect(b'"')` — no string
        // literal opens, so `.expect("` must not fire.
        let d = scan_file("rust/src/util/json.rs", "fn f() { self.expect(b'{')?; }");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn allow_annotation_suppresses_with_reason() {
        let src = "pub fn f() { x.unwrap(); } // detlint: allow(R4) -- test helper\n";
        assert!(scan_file("rust/src/foo.rs", src).is_empty());
        let above = "// detlint: allow(R4) -- invariant: set above\npub fn f() { x.unwrap(); }\n";
        assert!(scan_file("rust/src/foo.rs", above).is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_diagnostic() {
        let src = "pub fn f() { x.unwrap(); } // detlint: allow(R4)\n";
        let d = scan_file("rust/src/foo.rs", src);
        assert!(d.iter().any(|x| x.rule == Rule::BadAllow));
        assert!(d.iter().any(|x| x.rule == Rule::R4), "malformed allow must not suppress");
    }

    #[test]
    fn r1_strict_in_restricted_modules() {
        let d = scan_file("rust/src/coordinator/hub.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules_at(&d), vec![(1, Rule::R1)]);
    }

    #[test]
    fn r1_iteration_requires_sort_on_chain() {
        let src = "let m: HashMap<u64, f64> = HashMap::new();\n\
                   let v: Vec<_> = m.iter().collect();\n";
        let d = scan_file("rust/src/foo.rs", src);
        assert_eq!(rules_at(&d), vec![(2, Rule::R1)]);
        let sorted = "let m: HashMap<u64, f64> = HashMap::new();\n\
                      let mut v: Vec<_> = m.iter().collect();\n\
                      v.sort();  ";
        // Sort on the *same chain* is what passes; this two-statement
        // form still fires (the chain ends at the first `;`).
        assert_eq!(rules_at(&scan_file("rust/src/foo.rs", sorted)), vec![(2, Rule::R1)]);
        let chained = "let m: HashMap<u64, f64> = HashMap::new();\n\
                       let v: Vec<_> = m.iter()\n    .sorted()\n    .collect();\n";
        assert!(scan_file("rust/src/foo.rs", chained).is_empty());
    }

    #[test]
    fn r1_boundary_does_not_match_suffixes() {
        let src = "let m: HashMap<u64, f64> = HashMap::new();\nlet s = freq.iter().sum::<f64>();\n";
        let d = scan_file("rust/src/foo.rs", &src.replace("m:", "q:"));
        assert!(d.is_empty(), "freq must not match tracked name q: {d:?}");
    }

    #[test]
    fn r2_and_r3_fire_only_in_restricted_modules() {
        let src = "let mut acc = 0.0f32;\nacc += x as f32;\nlet t = Instant::now();\n";
        let d = scan_file("rust/src/runtime/params.rs", src);
        assert_eq!(rules_at(&d), vec![(2, Rule::R2), (3, Rule::R3)]);
        assert!(scan_file("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn dense_kernels_are_a_restricted_module() {
        // kernels.rs computes every Q-value a fingerprinted trajectory
        // consumes; R2/R3 must police it like params.rs.
        let src = "let mut acc = 0.0f32;\nacc += x as f32;\nlet t = Instant::now();\n";
        let d = scan_file("rust/src/runtime/native/kernels.rs", src);
        assert_eq!(rules_at(&d), vec![(2, Rule::R2), (3, Rule::R3)]);
        // The sibling wrapper module stays unrestricted (it holds no
        // reductions of its own).
        assert!(scan_file("rust/src/runtime/native/mlp.rs", src).is_empty());
    }

    #[test]
    fn fused_trainer_is_a_restricted_module() {
        // fused.rs promises bitwise identity with the sequential
        // training path; that promise is only as strong as the same
        // R1/R2/R3 discipline the kernels live under.
        let src = "let mut acc = 0.0f32;\nacc += x as f32;\nlet t = Instant::now();\n";
        let d = scan_file("rust/src/runtime/native/fused.rs", src);
        assert_eq!(rules_at(&d), vec![(2, Rule::R2), (3, Rule::R3)]);
        let hash = "use std::collections::HashMap;\n";
        let d = scan_file("rust/src/runtime/native/fused.rs", hash);
        assert_eq!(rules_at(&d), vec![(1, Rule::R1)]);
    }

    #[test]
    fn campaign_store_is_a_restricted_directory() {
        // Every file in the store serializes fingerprinted bits; a
        // hash-map iteration or wall-clock read anywhere in the
        // directory would corrupt resumed fingerprints.
        let src = "let mut acc = 0.0f32;\nacc += x as f32;\nlet t = Instant::now();\n";
        for file in ["format.rs", "shard.rs", "manifest.rs", "mod.rs"] {
            let d = scan_file(&format!("rust/src/campaign/store/{file}"), src);
            assert_eq!(rules_at(&d), vec![(2, Rule::R2), (3, Rule::R3)], "{file}");
        }
        // The sibling cache module is not directory-restricted.
        assert!(scan_file("rust/src/campaign/cache.rs", src).is_empty());
    }

    #[test]
    fn r5_requires_determinism_docs_on_seam_traits() {
        let src = "pub trait Agent: Send {\n\
                   \x20   /// Determinism: pure.\n\
                   \x20   fn name(&self) -> &'static str;\n\
                   \x20   /// Just a doc.\n\
                   \x20   fn train(&mut self);\n\
                   }\n";
        let d = scan_file("rust/src/coordinator/agent.rs", src);
        assert_eq!(rules_at(&d), vec![(5, Rule::R5)]);
        assert!(d[0].message.contains("`train`"));
        // Non-seam traits are not checked.
        let other = "pub trait Workload {\n    fn build(&self);\n}\n";
        assert!(scan_file("rust/src/foo.rs", other).is_empty());
    }

    #[test]
    fn json_escapes_quotes() {
        let d = vec![Diagnostic {
            path: "a.rs".into(),
            line: 3,
            rule: Rule::R4,
            message: "bad \"msg\"".into(),
        }];
        let j = to_json(&d);
        assert!(j.contains("\\\"msg\\\""));
        assert!(j.starts_with('[') && j.ends_with(']'));
    }
}
