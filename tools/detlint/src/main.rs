//! detlint CLI: scan the repository and report determinism-invariant
//! violations (see `docs/determinism.md`).
//!
//! ```text
//! cargo run -p detlint                # human-readable, nonzero exit on findings
//! cargo run -p detlint -- --json     # machine-readable (CI)
//! cargo run -p detlint -- --root X   # scan a different checkout
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{rule_counts, scan_repo, to_json, Rule};

fn main() -> ExitCode {
    let mut json = false;
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("detlint: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: detlint [--json] [--root <dir>]");
                println!("rules:");
                for rule in Rule::CHECKS {
                    println!("  {}  {}", rule.id(), rule.describe());
                }
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("detlint: unknown argument {other:?} (try --help)");
                return ExitCode::from(2);
            }
        }
    }

    let diags = match scan_repo(&root) {
        Ok(diags) => diags,
        Err(err) => {
            eprintln!("detlint: scan failed under {}: {err}", root.display());
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", to_json(&diags));
        return if diags.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE };
    }

    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("detlint: clean");
        return ExitCode::SUCCESS;
    }
    println!("\nrule summary:");
    for (rule, count) in rule_counts(&diags) {
        if count > 0 {
            println!("  {}  {:>4}  {}", rule.id(), count, rule.describe());
        }
    }
    println!("\n{} finding(s). Suppress only with", diags.len());
    println!("  // detlint: allow(<rule>) -- <reason>");
    ExitCode::FAILURE
}
