//! Quickstart: tune one workload with AITuning in ~a minute.
//!
//! ```sh
//! make artifacts                      # once: AOT-compile the Q-network
//! cargo run --release --example quickstart
//! ```
//!
//! Runs the paper's §5 loop — reference run, 15 tuning runs driven by
//! the deep Q-network (falling back to the tabular agent if artifacts
//! are missing), ensemble inference — on the Lattice-Boltzmann workload,
//! then prints the per-run log and the shipped configuration.

use aituning::coordinator::{Action, AgentKind, Controller, TuningConfig};
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let artifacts = aituning::runtime::default_artifacts_dir();
    let agent = if artifacts.join("manifest.json").exists() {
        AgentKind::Dqn
    } else {
        eprintln!("artifacts not found — falling back to the tabular agent");
        AgentKind::Tabular
    };

    let cfg = TuningConfig { agent, runs: 15, seed: 7, ..TuningConfig::default() };
    let mut ctl = Controller::new(cfg)?;

    let kind = WorkloadKind::LatticeBoltzmann;
    let images = 64;
    println!("tuning {} at {images} images ({} agent)\n", kind.name(), ctl.agent_name());

    let out = ctl.tune(kind, images)?;

    let mut t = Table::new(&["run", "total (µs)", "reward", "action"]);
    for r in &out.log.runs {
        t.row(vec![
            r.run_index.to_string(),
            format!("{:.0}", r.total_time_us),
            format!("{:+.4}", r.reward),
            r.action
                .map(|a| {
                    let table = aituning::mpi_t::MPICH_CVARS;
                    Action::from_index(table, a).describe(table)
                })
                .unwrap_or_else(|| "reference (vanilla MPICH)".into()),
        ]);
    }
    t.print();

    println!("\nreference: {:.0} µs", out.reference_us);
    println!("best:      {:.0} µs ({:+.1}%)", out.best_us, out.improvement() * 100.0);
    println!("shipped ensemble configuration (§5.4):\n  {}", out.ensemble);
    let ens = ctl.evaluate(kind, images, &out.ensemble, 3)?;
    println!(
        "ensemble evaluation: {:.0} µs ({:+.1}% vs reference)",
        ens,
        (out.reference_us - ens) / out.reference_us * 100.0
    );
    Ok(())
}
