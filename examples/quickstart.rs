//! Quickstart: tune one workload with AITuning in ~a minute.
//!
//! ```sh
//! cargo run --release --example quickstart          # 15 tuning runs
//! cargo run --release --example quickstart 3        # tiny smoke (CI)
//! ```
//!
//! Runs the paper's §5 loop — reference run, N tuning runs driven by
//! the deep Q-network on the **native engine** (pure Rust: no
//! artifacts, no PJRT, works on every backend), ensemble inference —
//! on the Lattice-Boltzmann workload, then prints the per-run log and
//! the shipped configuration.

use aituning::coordinator::{Action, AgentKind, Controller, TuningConfig};
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    // An unparseable count must error, not silently fall back — CI's
    // tiny-smoke invocation depends on the argument taking effect.
    let runs: usize = match std::env::args().nth(1) {
        None => 15,
        Some(arg) => arg
            .parse()
            .map_err(|_| anyhow::anyhow!("run count must be an integer, got {arg:?}"))?,
    };
    let cfg = TuningConfig { agent: AgentKind::Dqn, runs, seed: 7, ..TuningConfig::default() };
    let mut ctl = Controller::new(cfg)?;

    let kind = WorkloadKind::LatticeBoltzmann;
    let images = 64;
    println!(
        "tuning {} at {images} images ({} agent, native engine, {runs} runs)\n",
        kind.name(),
        ctl.agent_name()
    );

    let out = ctl.tune(kind, images)?;

    let mut t = Table::new(&["run", "total (µs)", "reward", "action"]);
    for r in &out.log.runs {
        t.row(vec![
            r.run_index.to_string(),
            format!("{:.0}", r.total_time_us),
            format!("{:+.4}", r.reward),
            r.action
                .map(|a| {
                    let table = aituning::mpi_t::MPICH_CVARS;
                    Action::from_index(table, a).describe(table)
                })
                .unwrap_or_else(|| "reference (vanilla MPICH)".into()),
        ]);
    }
    t.print();

    println!("\nreference: {:.0} µs", out.reference_us);
    println!("best:      {:.0} µs ({:+.1}%)", out.best_us, out.improvement() * 100.0);
    println!(
        "DQN losses: {} updates, running mean {:.4}",
        ctl.losses().len(),
        ctl.losses().mean()
    );
    println!("shipped ensemble configuration (§5.4):\n  {}", out.ensemble);
    let ens = ctl.evaluate(kind, images, &out.ensemble, 3)?;
    println!(
        "ensemble evaluation: {:.0} µs ({:+.1}% vs reference)",
        ens,
        (out.reference_us - ens) / out.reference_us * 100.0
    );
    Ok(())
}
