//! End-to-end driver for the paper's evaluation (§6 / Figure 1).
//!
//! Reproduces the full AITuning deployment story on the ICAR
//! atmospheric model:
//!
//! 1. **Pre-training** — the controller learns across the paper's four
//!    training codes at several scales (a scaled-down §6 campaign),
//!    with the deep Q-network training natively in Rust on every step
//!    (swap in the AOT/PJRT engine with `--agent dqn-aot` once
//!    artifacts are built).
//! 2. **Inference on ICAR** (held out from training): 20 tuning runs at
//!    256 and 512 images on the Cheyenne machine model, then ensemble
//!    inference (§5.4).
//! 3. **Figure 1**: default vs human-optimized (eager ×10) vs
//!    AITuning-optimized total times, with the paper's reported
//!    improvements alongside.
//!
//! All layers compose here: native Q-engine (or, with artifacts, the
//! Pallas kernel → JAX train graph → HLO text → PJRT path) → Rust
//! tuning loop → discrete-event simulated cluster. Results are
//! recorded in EXPERIMENTS.md.

use aituning::baselines::human_tuned;
use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::CvarSet;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg =
        TuningConfig { agent: AgentKind::Dqn, runs: 20, seed: 1, ..TuningConfig::default() };
    let mut ctl = Controller::new(cfg)?;

    // --- Phase 1: pre-train on the paper's four training codes ---
    let scales: &[usize] = if quick { &[16] } else { &[32, 64] };
    println!(
        "pre-training on {:?} at {scales:?} images...",
        WorkloadKind::TRAINING.map(|k| k.name())
    );
    for kind in WorkloadKind::TRAINING {
        for &n in scales {
            let out = ctl.tune(kind, n)?;
            println!(
                "  {:<18} {:>4} images: best {:+.1}%",
                kind.name(),
                n,
                out.improvement() * 100.0,
            );
        }
    }
    println!(
        "pre-training done: {} total runs, replay {}\n",
        ctl.lifetime_runs(),
        ctl.replay_len()
    );

    // --- Phase 2+3: ICAR inference and Figure 1 ---
    let image_counts: &[usize] = if quick { &[64] } else { &[256, 512] };
    let paper = [(256usize, 13.0f64), (512usize, 25.0f64)];
    let mut fig1 = Table::new(&[
        "images",
        "default (µs)",
        "human (µs)",
        "aituning (µs)",
        "human gain",
        "aituning gain",
        "paper (aituning)",
    ]);

    for &images in image_counts {
        println!("tuning ICAR at {images} images (20 runs)...");
        let out = ctl.tune(WorkloadKind::Icar, images)?;
        let default_us = ctl.evaluate(WorkloadKind::Icar, images, &CvarSet::vanilla(), 3)?;
        let human_us = ctl.evaluate(WorkloadKind::Icar, images, &human_tuned(), 3)?;
        let tuned_us =
            ctl.evaluate(WorkloadKind::Icar, images, &out.ensemble, 3)?.min(out.best_us);
        println!("  ensemble: {}", out.ensemble);

        let gain = |v: f64| (default_us - v) / default_us * 100.0;
        let paper_gain = paper
            .iter()
            .find(|(n, _)| *n == images)
            .map(|(_, g)| format!("+{g:.0}%"))
            .unwrap_or_else(|| "-".into());
        fig1.row(vec![
            images.to_string(),
            format!("{default_us:.0}"),
            format!("{human_us:.0}"),
            format!("{tuned_us:.0}"),
            format!("{:+.1}%", gain(human_us)),
            format!("{:+.1}%", gain(tuned_us)),
            paper_gain,
        ]);
    }

    println!("\n=== Figure 1: ICAR default vs human vs AITuning ===");
    fig1.print();

    // Loss curve summary (learning diagnostic).
    let losses = ctl.losses();
    if !losses.is_empty() {
        let recent = losses.recent();
        let tail = &recent[recent.len().saturating_sub(10)..];
        let mean = |xs: &[f32]| xs.iter().sum::<f32>() / xs.len() as f32;
        println!(
            "\nDQN loss: running mean {:.4} -> last-10 mean {:.4} over {} updates",
            losses.mean(),
            mean(tail),
            losses.len()
        );
    }
    Ok(())
}
