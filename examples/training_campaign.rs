//! §6 training campaign driver (scaled).
//!
//! The paper trains AITuning on four CAF codes (CloverLeaf, LBM,
//! Skeleton PIC, PRK) at 64–2048 processes on two machines, ~5000 runs
//! total. This driver runs the same campaign shape — both machine
//! models, all four training codes, a range of image counts — scaled to
//! minutes of simulated-cluster time. Pass `--full` for the larger
//! sweep (64..512 images), `--quick` for a smoke pass.

use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512]
    } else if quick {
        &[16]
    } else {
        &[32, 64, 128]
    };
    let runs_per = if quick { 6 } else { 20 };

    let mut t = Table::new(&["machine", "workload", "images", "reference (µs)", "best gain"]);
    let mut total_runs = 0usize;
    for machine in [Machine::cheyenne(), Machine::edison()] {
        let agent = if aituning::runtime::default_artifacts_dir().join("manifest.json").exists() {
            AgentKind::Dqn
        } else {
            AgentKind::Tabular
        };
        let cfg = TuningConfig {
            machine: machine.clone(),
            agent,
            runs: runs_per,
            seed: 5,
            ..TuningConfig::default()
        };
        let mut ctl = Controller::new(cfg)?;
        for kind in WorkloadKind::TRAINING {
            for &n in image_counts {
                let out = ctl.tune(kind, n)?;
                t.row(vec![
                    machine.name.to_string(),
                    kind.name().to_string(),
                    n.to_string(),
                    format!("{:.0}", out.reference_us),
                    format!("{:+.1}%", out.improvement() * 100.0),
                ]);
            }
        }
        total_runs += ctl.lifetime_runs();
    }
    println!("=== §6 training campaign (scaled; paper: 5000 runs at 64–2048 procs) ===");
    t.print();
    println!("\ntotal application runs executed: {total_runs}");
    Ok(())
}
