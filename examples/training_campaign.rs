//! §6 training campaign driver (scaled), on the parallel campaign
//! engine.
//!
//! The paper trains AITuning on four CAF codes (CloverLeaf, LBM,
//! Skeleton PIC, PRK) at 64–2048 processes on two machines, ~5000 runs
//! total. This driver runs the same campaign shape — both machine
//! models, all four training codes, a range of image counts — scaled to
//! minutes of simulated-cluster time, with every (workload, images)
//! cell an independent seeded job fanned across all cores. Pass
//! `--full` for the larger sweep (64..512 images), `--quick` for a
//! smoke pass.

use aituning::campaign::{job_grid, CampaignConfig, CampaignEngine};
use aituning::coordinator::{AgentKind, TuningConfig};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512]
    } else if quick {
        &[16]
    } else {
        &[32, 64, 128]
    };
    let runs_per = if quick { 6 } else { 20 };

    let mut t = Table::new(&["machine", "workload", "images", "reference (µs)", "best gain"]);
    let mut total_runs = 0usize;
    let mut wall = 0.0f64;
    let mut workers = 0;
    for machine in [Machine::cheyenne(), Machine::edison()] {
        let agent = if aituning::runtime::default_artifacts_dir().join("manifest.json").exists() {
            AgentKind::Dqn
        } else {
            AgentKind::Tabular
        };
        let base = TuningConfig {
            machine: machine.clone(),
            agent,
            runs: runs_per,
            seed: 5,
            ..TuningConfig::default()
        };
        let jobs = job_grid(&WorkloadKind::TRAINING, image_counts, agent, base.seed);
        let report = CampaignEngine::new(CampaignConfig { base, workers: 0 }).run(&jobs)?;
        for r in &report.results {
            t.row(vec![
                machine.name.to_string(),
                r.job.workload.name().to_string(),
                r.job.images.to_string(),
                format!("{:.0}", r.outcome.reference_us),
                format!("{:+.1}%", r.outcome.improvement() * 100.0),
            ]);
        }
        total_runs += report.total_app_runs();
        wall += report.wall_clock.as_secs_f64();
        workers = report.workers;
    }
    println!("=== §6 training campaign (scaled; paper: 5000 runs at 64–2048 procs) ===");
    t.print();
    println!(
        "\ntotal application runs executed: {total_runs} in {wall:.2}s on {workers} workers"
    );
    Ok(())
}
