//! §6 training campaign driver (scaled), on the parallel campaign
//! engine.
//!
//! The paper trains AITuning on four CAF codes (CloverLeaf, LBM,
//! Skeleton PIC, PRK) at 64–2048 processes on two machines, ~5000 runs
//! total. This driver runs the same campaign shape — both machine
//! models, all four training codes, a range of image counts — scaled to
//! minutes of simulated-cluster time, as **one** job grid spanning both
//! testbeds fanned across all cores. Pass `--full` for the larger sweep
//! (64..512 images), `--quick` for a smoke pass, `--shared` to couple
//! the jobs through the LearnerHub parameter server and print the
//! independent-vs-shared ablation instead of the plain table, and
//! `--replay uniform|stratified|prioritized` to pick the replay
//! retention/selection policy.

use aituning::campaign::{ablation_table, job_grid, CampaignConfig, CampaignEngine};
use aituning::coordinator::{AgentKind, ReplayPolicyKind, SharedLearning, TuningConfig};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let full = argv.iter().any(|a| a == "--full");
    let quick = argv.iter().any(|a| a == "--quick");
    let shared_mode = argv.iter().any(|a| a == "--shared");
    // --replay uniform|stratified|prioritized (hub + controller buffers)
    let replay_policy = match argv.iter().position(|a| a == "--replay") {
        None => ReplayPolicyKind::default(),
        Some(i) => {
            let name = argv
                .get(i + 1)
                .expect("--replay needs a value (uniform|stratified|prioritized)");
            ReplayPolicyKind::parse(name)
                .unwrap_or_else(|| panic!("unknown replay policy {name:?}"))
        }
    };
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512]
    } else if quick {
        &[16]
    } else {
        &[32, 64, 128]
    };
    let runs_per = if quick { 6 } else { 20 };
    let machines = [Machine::cheyenne(), Machine::edison()];
    // Native DQN engine: no artifacts required.
    let agent = AgentKind::Dqn;
    let base = TuningConfig {
        machine: machines[0].clone(),
        agent,
        runs: runs_per,
        seed: 5,
        shared: shared_mode.then_some(SharedLearning { sync_every: if quick { 2 } else { 5 }, ..SharedLearning::default() }),
        replay_policy,
        ..TuningConfig::default()
    };
    let jobs = job_grid(
        aituning::backend::BackendId::Coarrays,
        &machines,
        &WorkloadKind::TRAINING,
        image_counts,
        agent,
        base.seed,
    );
    let engine = CampaignEngine::new(CampaignConfig {
        base,
        workers: 0,
        straggle: None,
        fuse_training: true,
    });

    if shared_mode {
        let independent = engine.run(&jobs)?;
        let shared = engine.run_shared(&jobs)?;
        println!("=== §6 training campaign: independent vs shared learning ===");
        ablation_table(&independent, &shared).print();
        let hub = shared.hub.expect("shared report carries hub state");
        println!(
            "\ngeomean speedup: independent {:.3}x vs shared {:.3}x",
            independent.geomean_speedup(),
            shared.geomean_speedup()
        );
        println!("hub: {}", hub.describe());
        return Ok(());
    }

    let report = engine.run(&jobs)?;
    let mut t = Table::new(&["machine", "workload", "images", "reference (µs)", "best gain"]);
    for r in &report.results {
        t.row(vec![
            r.job.machine.to_string(),
            r.job.workload.name().to_string(),
            r.job.images.to_string(),
            format!("{:.0}", r.outcome.reference_us),
            format!("{:+.1}%", r.outcome.improvement() * 100.0),
        ]);
    }
    println!("=== §6 training campaign (scaled; paper: 5000 runs at 64–2048 procs) ===");
    t.print();
    println!(
        "\ntotal application runs executed: {} in {:.2}s on {} workers",
        report.total_app_runs(),
        report.wall_clock.as_secs_f64(),
        report.workers
    );
    Ok(())
}
