//! §5.5 convergence study driver.
//!
//! Sweeps Gaussian noise from 0% to 30% (the paper's maximum) over the
//! three synthetic model families and reports how close the RL machinery
//! gets to each model's known optimum. The paper's claim under test:
//! "Even with high level of noise (up to 30% ...), our algorithm has
//! always been able to find a set of control variables reasonably close
//! to the known best."

use aituning::convergence::{run_convergence, ConvergenceConfig, SyntheticModel};
use aituning::coordinator::AgentKind;
use aituning::mpi_t::CvarId;
use aituning::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let models: Vec<(&str, SyntheticModel)> = vec![
        (
            "parabola(polls→2600)",
            SyntheticModel::Parabola { cvar: CvarId(4), best: 2600, curvature: 12.0 },
        ),
        (
            "coupled(async×eager)",
            SyntheticModel::CoupledParabola {
                int_cvar: CvarId(5),
                bool_cvar: CvarId(0),
                best_off: 131_072,
                // 192 action steps above the default: reachable within
                // the run budget (the paper's fixed 1024-byte step).
                best_on: 327_680,
                bool_gain: 0.25,
                curvature: 4.0,
            },
        ),
        ("bool-step(async)", SyntheticModel::BoolStep { cvar: CvarId(0), gain: 0.3 }),
    ];

    // Native DQN engine: no artifacts required; quick mode stays
    // tabular for wall-clock only.
    let agent = if quick { AgentKind::Tabular } else { AgentKind::Dqn };
    let runs = if quick { 100 } else { 400 };

    let mut t = Table::new(&["model", "noise", "dist-to-best", "time ratio", "converged?"]);
    for (name, model) in &models {
        for noise in [0.0, 0.10, 0.20, 0.30] {
            let cfg = ConvergenceConfig {
                agent,
                runs,
                noise,
                seed: 17,
                ..ConvergenceConfig::default()
            };
            let rep = run_convergence(model, &cfg)?;
            // "reasonably close to the known best": within 10% of the
            // domain and within 5% of the optimal time.
            let ok = rep.best_distance < 0.10 && rep.best_ratio < 1.05;
            t.row(vec![
                name.to_string(),
                format!("{:.0}%", noise * 100.0),
                format!("{:.4}", rep.best_distance),
                format!("{:.4}", rep.best_ratio),
                if ok { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    let agent_name = match agent {
        AgentKind::Dqn => "dqn",
        AgentKind::DqnAot => "dqn-aot",
        AgentKind::DqnTarget => "dqn+target",
        AgentKind::Tabular => "tabular",
    };
    println!("=== §5.5 convergence of the RL machinery ({agent_name} agent, {runs} runs) ===");
    t.print();
    Ok(())
}
