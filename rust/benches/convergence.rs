//! §5.5 convergence table: noise sweep (0–30%) × synthetic models ×
//! agents (deep vs tabular ablation).
//!
//! Expected shape (paper): converges "reasonably close to the known
//! best" at every noise level up to 30%.

use aituning::convergence::{run_convergence, ConvergenceConfig, SyntheticModel};
use aituning::coordinator::AgentKind;
use aituning::mpi_t::CvarId;
use aituning::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let runs = if quick { 80 } else { 300 };

    let models: Vec<(&str, SyntheticModel)> = vec![
        ("parabola", SyntheticModel::Parabola { cvar: CvarId(4), best: 2600, curvature: 12.0 }),
        (
            "coupled",
            SyntheticModel::CoupledParabola {
                int_cvar: CvarId(5),
                bool_cvar: CvarId(0),
                best_off: 131_072,
                // 192 action steps above the default: reachable within
                // the run budget (the paper's fixed 1024-byte step).
                best_on: 327_680,
                bool_gain: 0.25,
                curvature: 4.0,
            },
        ),
        ("bool-step", SyntheticModel::BoolStep { cvar: CvarId(0), gain: 0.3 }),
    ];
    let agents: Vec<(&str, AgentKind)> = if quick {
        vec![("tabular", AgentKind::Tabular)]
    } else {
        vec![("dqn", AgentKind::Dqn), ("tabular", AgentKind::Tabular)]
    };

    let mut t =
        Table::new(&["agent", "model", "noise", "dist-to-best", "time ratio", "converged"]);
    for (aname, agent) in &agents {
        for (mname, model) in &models {
            for noise in [0.0, 0.10, 0.20, 0.30] {
                // Average over seeds to report robustness, as §5.5 does
                // ("has always been able to find ...").
                let seeds: &[u64] = if quick { &[17] } else { &[17, 23, 31] };
                let mut worst_dist: f64 = 0.0;
                let mut worst_ratio: f64 = 1.0;
                for &seed in seeds {
                    let cfg = ConvergenceConfig {
                        agent: *agent,
                        runs,
                        noise,
                        seed,
                        ..ConvergenceConfig::default()
                    };
                    let rep = run_convergence(model, &cfg)?;
                    worst_dist = worst_dist.max(rep.best_distance);
                    worst_ratio = worst_ratio.max(rep.best_ratio);
                }
                let ok = worst_dist < 0.10 && worst_ratio < 1.05;
                t.row(vec![
                    aname.to_string(),
                    mname.to_string(),
                    format!("{:.0}%", noise * 100.0),
                    format!("{worst_dist:.4}"),
                    format!("{worst_ratio:.4}"),
                    if ok { "yes".into() } else { "NO".into() },
                ]);
            }
        }
    }
    println!("=== §5.5 RL convergence on synthetic models (worst over seeds) ===");
    t.print();
    Ok(())
}
