#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! §6.2 POLLS_BEFORE_YIELD analysis: sweep the poll budget on ICAR at
//! 256 and 512 images (base config: async progress on, as AITuning
//! found for ICAR).
//!
//! Expected shape (paper): at 256 images the knob is "not relevant"
//! (default 1000 fine, differences within noise); at 512 images values
//! in the 1200–1500 region are best, with a clear penalty for small
//! budgets.

use aituning::coordinator::run_episode;
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if quick { &[32, 64] } else { &[256, 512] };
    let values = [200i64, 600, 1000, 1100, 1200, 1350, 1500, 2000, 4000];
    let reps = if quick { 2 } else { 5 };
    let machine = Machine::cheyenne();

    let mut t = Table::new(&["images", "polls_before_yield", "total (µs)", "vs default(1000)"]);
    for &images in image_counts {
        let mut base = CvarSet::vanilla();
        base.set(CvarId(0), 1); // async progress (AITuning's ICAR find)
        let mut default_t = None;
        // Evaluate default first so the comparison column is stable.
        let mut order = vec![1000i64];
        order.extend(values.iter().filter(|&&v| v != 1000));
        let mut rows = Vec::new();
        for v in order {
            let mut cv = base.clone();
            cv.set(CvarId(4), v);
            let mut total = 0.0;
            for r in 0..reps {
                total += run_episode(
                    WorkloadKind::Icar, images, &machine, &cv, 0.02, 42, r as u64 + 1,
                )?
                .total_time_us;
            }
            let mean = total / reps as f64;
            if v == 1000 {
                default_t = Some(mean);
            }
            rows.push((v, mean));
        }
        let d = default_t.unwrap();
        rows.sort_by_key(|&(v, _)| v);
        for (v, mean) in rows {
            t.row(vec![
                images.to_string(),
                v.to_string(),
                format!("{mean:.0}"),
                format!("{:+.2}%", (d - mean) / d * 100.0),
            ]);
        }
    }
    println!("=== §6.2 POLLS_BEFORE_YIELD sweep on ICAR (async-progress base) ===");
    t.print();
    Ok(())
}
