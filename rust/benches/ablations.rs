#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Design-choice ablations (DESIGN.md): experience replay on/off,
//! ensemble vs single-best vs last-config inference, DQN vs tabular
//! agent, and AITuning vs the random/evolutionary/human baselines at
//! equal run budget.
//!
//! All fixed-config scoring goes through one campaign engine, so
//! evaluations fan across worker threads and repeat visits to the same
//! configuration (the vanilla reference, revisited search points) are
//! answered from the episode cache instead of re-simulated.

use aituning::baselines::{human_tuned, Evolutionary, RandomSearch, Searcher};
use aituning::campaign::{CampaignConfig, CampaignEngine, CampaignJob};
use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::CvarSet;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let images = if quick { 32 } else { 128 };
    let budget = if quick { 8 } else { 20 };
    let kind = WorkloadKind::Icar;
    let have_artifacts =
        aituning::runtime::default_artifacts_dir().join("manifest.json").exists();

    let base = TuningConfig { runs: budget, seed: 9, ..TuningConfig::default() };

    // Scoring engine (fixed-config evaluation only, cached + parallel).
    let engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig { agent: AgentKind::Tabular, ..base.clone() },
        workers: 0,
        straggle: None,
        fuse_training: true,
    });
    let vanilla = engine.evaluate(kind, images, &CvarSet::vanilla(), 3)?;
    let human = engine.evaluate(kind, images, &human_tuned(), 3)?;
    let pct = |v: f64| format!("{:+.1}%", (vanilla - v) / vanilla * 100.0);

    let mut t = Table::new(&["variant", "total (µs)", "vs vanilla"]);
    t.row(vec!["vanilla".into(), format!("{vanilla:.0}"), "+0.0%".into()]);
    t.row(vec!["human (eager x10)".into(), format!("{human:.0}"), pct(human)]);

    // --- agent ablation: DQN vs tabular, run as one parallel campaign ---
    let mut agents = vec![("tabular agent", AgentKind::Tabular)];
    if !quick {
        // Native engine: no artifacts required.
        agents.insert(0, ("dqn agent", AgentKind::Dqn));
    }
    let jobs: Vec<CampaignJob> = agents
        .iter()
        .map(|&(_, agent)| CampaignJob {
            backend: aituning::backend::BackendId::Coarrays,
            machine: base.machine.name,
            workload: kind,
            images,
            agent,
            seed: base.seed,
        })
        .collect();
    let report = CampaignEngine::new(CampaignConfig {
        base: base.clone(),
        workers: 0,
        straggle: None,
        fuse_training: true,
    })
    .run(&jobs)?;
    for ((name, _), r) in agents.iter().zip(&report.results) {
        // inference ablation: best vs ensemble vs last
        let out = &r.outcome;
        let configs = [
            out.best.clone(),
            out.ensemble.clone(),
            out.log.runs.last().unwrap().cvars.clone(),
        ];
        let scores = engine.evaluate_batch(kind, images, &configs, 3)?;
        t.row(vec![format!("{name}: best-run cfg"), format!("{:.0}", scores[0]), pct(scores[0])]);
        t.row(vec![
            format!("{name}: ensemble cfg (§5.4)"),
            format!("{:.0}", scores[1]),
            pct(scores[1]),
        ]);
        t.row(vec![
            format!("{name}: last cfg (no ensemble)"),
            format!("{:.0}", scores[2]),
            pct(scores[2]),
        ]);
    }

    // --- deployment ablation: pre-trained DQN (the paper's §5.4
    //     story: AITuning ships already trained) vs the cold-start
    //     rows above. Stays on one controller: the point is the shared
    //     replay/weights accumulated *across* workloads, which is
    //     inherently sequential. ---
    if !quick {
        let mut ctl = Controller::new(TuningConfig { agent: AgentKind::Dqn, ..base.clone() })?;
        for k in aituning::workloads::WorkloadKind::TRAINING {
            let _ = ctl.tune(k, 32)?;
        }
        let out = ctl.tune(kind, images)?;
        let scores =
            engine.evaluate_batch(kind, images, &[out.best.clone(), out.ensemble.clone()], 3)?;
        t.row(vec![
            "dqn (pre-trained): best-run cfg".into(),
            format!("{:.0}", scores[0]),
            pct(scores[0]),
        ]);
        t.row(vec![
            "dqn (pre-trained): ensemble cfg".into(),
            format!("{:.0}", scores[1]),
            pct(scores[1]),
        ]);
    }

    // --- Q-target ablation (the paper cites fixed Q-targets but does
    //     not implement them, §5.2) ---
    if have_artifacts && !quick {
        let report = CampaignEngine::new(CampaignConfig {
            base: base.clone(),
            workers: 1,
            straggle: None,
            fuse_training: true,
        })
        .run(&[CampaignJob {
            backend: aituning::backend::BackendId::Coarrays,
            machine: base.machine.name,
            workload: kind,
            images,
            agent: AgentKind::DqnTarget,
            seed: base.seed,
        }])?;
        let v = engine.evaluate(kind, images, &report.results[0].outcome.ensemble, 3)?;
        t.row(vec!["dqn + target network (not in paper)".into(), format!("{v:.0}"), pct(v)]);
    }

    // --- replay ablation (tabular for speed; the refresh cadence lives
    //     in the base config, so each variant is its own engine) ---
    for (name, refresh) in [("replay refresh on", 200usize), ("replay refresh off", usize::MAX)] {
        let variant = CampaignEngine::new(CampaignConfig {
            base: TuningConfig {
                agent: AgentKind::Tabular,
                replay_refresh_every: refresh,
                ..base.clone()
            },
            workers: 1,
            straggle: None,
            fuse_training: true,
        });
        let report = variant.run(&[CampaignJob {
            backend: aituning::backend::BackendId::Coarrays,
            machine: base.machine.name,
            workload: kind,
            images,
            agent: AgentKind::Tabular,
            seed: base.seed,
        }])?;
        let v = engine.evaluate(kind, images, &report.results[0].outcome.ensemble, 3)?;
        t.row(vec![name.into(), format!("{v:.0}"), pct(v)]);
    }

    // --- search baselines at equal budget (batched across workers) ---
    let mut random = RandomSearch::new(101);
    let (_, rnd) = {
        let mut eval = |cvs: &[CvarSet]| engine.evaluate_batch(kind, images, cvs, 1);
        random.search_batched(budget, &mut eval)?
    };
    t.row(vec!["random search".into(), format!("{rnd:.0}"), pct(rnd)]);
    let mut evo = Evolutionary::new(102);
    let (_, ev) = {
        let mut eval = |cvs: &[CvarSet]| engine.evaluate_batch(kind, images, cvs, 1);
        evo.search_batched(budget, &mut eval)?
    };
    t.row(vec!["evolutionary (AutoTune-like)".into(), format!("{ev:.0}"), pct(ev)]);

    println!("=== Ablations: ICAR @ {images} images, budget {budget} runs ===");
    t.print();
    println!(
        "episode cache: {} entries, {} hits / {} misses",
        engine.cache().len(),
        engine.cache().hits(),
        engine.cache().misses()
    );
    Ok(())
}
