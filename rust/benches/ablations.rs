//! Design-choice ablations (DESIGN.md): experience replay on/off,
//! ensemble vs single-best vs last-config inference, DQN vs tabular
//! agent, and AITuning vs the random/evolutionary/human baselines at
//! equal run budget.

use aituning::baselines::{human_tuned, Evolutionary, RandomSearch, Searcher};
use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::CvarSet;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let images = if quick { 32 } else { 128 };
    let budget = if quick { 8 } else { 20 };
    let kind = WorkloadKind::Icar;
    let have_artifacts =
        aituning::runtime::default_artifacts_dir().join("manifest.json").exists();

    let base = TuningConfig { runs: budget, seed: 9, ..TuningConfig::default() };

    // Scoring controller (fixed-config evaluation only).
    let mut scorer =
        Controller::new(TuningConfig { agent: AgentKind::Tabular, ..base.clone() })?;
    let vanilla = scorer.evaluate(kind, images, &CvarSet::vanilla(), 3)?;
    let pct = |v: f64| format!("{:+.1}%", (vanilla - v) / vanilla * 100.0);

    let mut t = Table::new(&["variant", "total (µs)", "vs vanilla"]);
    t.row(vec!["vanilla".into(), format!("{vanilla:.0}"), "+0.0%".into()]);
    t.row(vec![
        "human (eager x10)".into(),
        format!("{:.0}", scorer.evaluate(kind, images, &human_tuned(), 3)?),
        pct(scorer.evaluate(kind, images, &human_tuned(), 3)?),
    ]);

    // --- agent ablation: DQN vs tabular ---
    let mut agents = vec![("tabular agent", AgentKind::Tabular)];
    if have_artifacts && !quick {
        agents.insert(0, ("dqn agent", AgentKind::Dqn));
    }
    for (name, agent) in agents {
        let mut ctl = Controller::new(TuningConfig { agent, ..base.clone() })?;
        let out = ctl.tune(kind, images)?;
        // inference ablation: best vs ensemble vs last
        let best = scorer.evaluate(kind, images, &out.best, 3)?;
        let ens = scorer.evaluate(kind, images, &out.ensemble, 3)?;
        let last = scorer.evaluate(kind, images, &out.log.runs.last().unwrap().cvars, 3)?;
        t.row(vec![format!("{name}: best-run cfg"), format!("{best:.0}"), pct(best)]);
        t.row(vec![format!("{name}: ensemble cfg (§5.4)"), format!("{ens:.0}"), pct(ens)]);
        t.row(vec![format!("{name}: last cfg (no ensemble)"), format!("{last:.0}"), pct(last)]);
    }

    // --- deployment ablation: pre-trained DQN (the paper's §5.4
    //     story: AITuning ships already trained) vs the cold-start
    //     rows above ---
    if have_artifacts && !quick {
        let mut ctl = Controller::new(TuningConfig { agent: AgentKind::Dqn, ..base.clone() })?;
        for k in aituning::workloads::WorkloadKind::TRAINING {
            let _ = ctl.tune(k, 32)?;
        }
        let out = ctl.tune(kind, images)?;
        let best = scorer.evaluate(kind, images, &out.best, 3)?;
        let ens = scorer.evaluate(kind, images, &out.ensemble, 3)?;
        t.row(vec!["dqn (pre-trained): best-run cfg".into(), format!("{best:.0}"), pct(best)]);
        t.row(vec!["dqn (pre-trained): ensemble cfg".into(), format!("{ens:.0}"), pct(ens)]);
    }

    // --- Q-target ablation (the paper cites fixed Q-targets but does
    //     not implement them, §5.2) ---
    if have_artifacts && !quick {
        let mut ctl =
            Controller::new(TuningConfig { agent: AgentKind::DqnTarget, ..base.clone() })?;
        let out = ctl.tune(kind, images)?;
        let v = scorer.evaluate(kind, images, &out.ensemble, 3)?;
        t.row(vec!["dqn + target network (not in paper)".into(), format!("{v:.0}"), pct(v)]);
    }

    // --- replay ablation (tabular for speed) ---
    for (name, refresh) in [("replay refresh on", 200usize), ("replay refresh off", usize::MAX)] {
        let mut ctl = Controller::new(TuningConfig {
            agent: AgentKind::Tabular,
            replay_refresh_every: refresh,
            ..base.clone()
        })?;
        let out = ctl.tune(kind, images)?;
        let v = scorer.evaluate(kind, images, &out.ensemble, 3)?;
        t.row(vec![name.into(), format!("{v:.0}"), pct(v)]);
    }

    // --- search baselines at equal budget ---
    let mut random = RandomSearch::new(101);
    let (_, rnd) = {
        let mut eval = |cv: &CvarSet| scorer.evaluate(kind, images, cv, 1);
        random.search(budget, &mut eval)?
    };
    t.row(vec!["random search".into(), format!("{rnd:.0}"), pct(rnd)]);
    let mut evo = Evolutionary::new(102);
    let (_, ev) = {
        let mut eval = |cv: &CvarSet| scorer.evaluate(kind, images, cv, 1);
        evo.search(budget, &mut eval)?
    };
    t.row(vec!["evolutionary (AutoTune-like)".into(), format!("{ev:.0}"), pct(ev)]);

    println!("=== Ablations: ICAR @ {images} images, budget {budget} runs ===");
    t.print();
    Ok(())
}
