//! Simulator performance: simulated-runs-per-minute and event
//! throughput for each workload at campaign scales.
//!
//! §Perf target: ≥ 10k simulated runs/min on the small campaign cells
//! so the paper's 5000-run campaign stays cheap.

use aituning::coarray::{lower_all, RuntimeOptions};
use aituning::mpi_t::CvarSet;
use aituning::simmpi::{Engine, Machine, SimConfig};
use aituning::util::bench::{opaque, time, Table};
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if quick { &[16, 64] } else { &[64, 256, 512] };
    let samples = if quick { 3 } else { 8 };
    let machine = Machine::cheyenne();

    let mut t = Table::new(&["workload", "images", "msgs/run", "median run", "runs/min"]);
    for kind in WorkloadKind::ALL {
        for &images in image_counts {
            if images < kind.instantiate().min_images() {
                continue;
            }
            let mut rng = Rng::new(42);
            let progs = kind.instantiate().build(images, &mut rng);
            let lowered = lower_all(&progs, &RuntimeOptions::default());
            // count messages once
            let mut cfg = SimConfig::new(machine.clone(), CvarSet::vanilla(), images);
            cfg.noise = 0.02;
            let stats = Engine::new(cfg, lowered.clone()).run();
            let msgs = stats.eager_msgs + stats.rendezvous_msgs;

            let s = time(1, samples, || {
                let mut cfg = SimConfig::new(machine.clone(), CvarSet::vanilla(), images);
                cfg.noise = 0.02;
                opaque(Engine::new(cfg, lowered.clone()).run());
            });
            let runs_per_min = 60_000.0 / s.median_ms();
            t.row(vec![
                kind.name().to_string(),
                images.to_string(),
                msgs.to_string(),
                format!("{:.2} ms", s.median_ms()),
                format!("{runs_per_min:.0}"),
            ]);
        }
    }
    println!("=== simmpi engine throughput ===");
    t.print();
}
