//! §6.2 eager-threshold analysis: sweep CH3_EAGER_MAX_MSG_SIZE on ICAR.
//!
//! Expected shape (paper): the default threshold leaves ICAR's halo
//! puts on the rendezvous path; raising it "by an order of magnitude"
//! (the human tuning) converts them to eager and recovers most of the
//! communication cost; far beyond that, returns flatten (and copies
//! start to cost).
//!
//! Sweep points are independent fixed-config evaluations, so the timing
//! column fans across the campaign engine's worker pool; one extra
//! noise-free probe episode per point (same derived problem instance as
//! the timed runs) classifies the protocol.

use aituning::campaign::{CampaignConfig, CampaignEngine};
use aituning::coordinator::TuningConfig;
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if quick { &[32] } else { &[256, 512] };
    let reps = if quick { 2 } else { 5 };
    let machine = Machine::cheyenne();
    // default 128 KiB .. x32; ICAR's per-round halo is 192 KiB.
    let multipliers = [1i64, 2, 4, 8, 10, 16, 32];

    let engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig { machine: machine.clone(), seed: 42, ..TuningConfig::default() },
        workers: 0,
        straggle: None,
        fuse_training: true,
    });

    let mut t = Table::new(&[
        "images", "eager_max", "x default", "protocol", "total (µs)", "vs default",
    ]);
    for &images in image_counts {
        let configs: Vec<CvarSet> = multipliers
            .iter()
            .map(|&m| {
                let mut cv = CvarSet::vanilla();
                cv.set(CvarId(5), 131_072 * m);
                cv
            })
            .collect();
        let means = engine.evaluate_batch(WorkloadKind::Icar, images, &configs, reps)?;

        let d = means[0];
        for ((&m, cv), &mean) in multipliers.iter().zip(&configs).zip(&means) {
            // Noise-free probe run for the protocol classification.
            let probe = engine.probe_episode(WorkloadKind::Icar, images, cv)?;
            let proto = if probe.raw.eager_msgs > probe.raw.rendezvous_msgs {
                "eager"
            } else {
                "rendezvous"
            };
            t.row(vec![
                images.to_string(),
                (131_072 * m).to_string(),
                format!("x{m}"),
                proto.to_string(),
                format!("{mean:.0}"),
                format!("{:+.2}%", (d - mean) / d * 100.0),
            ]);
        }
    }
    println!("=== §6.2 eager threshold sweep on ICAR (halo = 192 KiB/round) ===");
    t.print();
    println!(
        "episode cache: {} entries ({} hits / {} misses)",
        engine.cache().len(),
        engine.cache().hits(),
        engine.cache().misses()
    );
    Ok(())
}
