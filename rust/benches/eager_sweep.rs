//! §6.2 eager-threshold analysis: sweep CH3_EAGER_MAX_MSG_SIZE on ICAR.
//!
//! Expected shape (paper): the default threshold leaves ICAR's halo
//! puts on the rendezvous path; raising it "by an order of magnitude"
//! (the human tuning) converts them to eager and recovers most of the
//! communication cost; far beyond that, returns flatten (and copies
//! start to cost).

use aituning::coordinator::run_episode;
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let image_counts: &[usize] = if quick { &[32] } else { &[256, 512] };
    let reps = if quick { 2 } else { 5 };
    let machine = Machine::cheyenne();
    // default 128 KiB .. x32; ICAR's per-round halo is 192 KiB.
    let multipliers = [1i64, 2, 4, 8, 10, 16, 32];

    let mut t = Table::new(&[
        "images", "eager_max", "x default", "protocol", "total (µs)", "vs default",
    ]);
    for &images in image_counts {
        let mut rows = Vec::new();
        let mut default_t = None;
        for &m in &multipliers {
            let mut cv = CvarSet::vanilla();
            let v = 131_072 * m;
            cv.set(CvarId(5), v);
            let mut total = 0.0;
            let mut eager = 0u64;
            let mut rdv = 0u64;
            for r in 0..reps {
                let res = run_episode(
                    WorkloadKind::Icar, images, &machine, &cv, 0.02, 42, r as u64 + 1,
                )?;
                total += res.total_time_us;
                eager = res.raw.eager_msgs;
                rdv = res.raw.rendezvous_msgs;
            }
            let mean = total / reps as f64;
            if m == 1 {
                default_t = Some(mean);
            }
            let proto = if eager > rdv { "eager" } else { "rendezvous" };
            rows.push((m, v, proto, mean));
        }
        let d = default_t.unwrap();
        for (m, v, proto, mean) in rows {
            t.row(vec![
                images.to_string(),
                v.to_string(),
                format!("x{m}"),
                proto.to_string(),
                format!("{mean:.0}"),
                format!("{:+.2}%", (d - mean) / d * 100.0),
            ]);
        }
    }
    println!("=== §6.2 eager threshold sweep on ICAR (halo = 192 KiB/round) ===");
    t.print();
    Ok(())
}
