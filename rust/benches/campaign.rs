//! §6 training-campaign table, driven by the parallel campaign engine:
//! the four training codes across scales on both machine models,
//! reporting reference time and AITuning's best improvement per cell
//! (a scaled version of the paper's 5000-run, 64–2048-process
//! campaign).
//!
//! Every campaign is executed twice — once on 1 worker, once on all
//! cores — the engine's thread-count invariance is asserted by
//! fingerprint, and both wall clocks are reported so the parallel
//! speedup is visible in the output.

use aituning::campaign::{job_grid, CampaignConfig, CampaignEngine};
use aituning::coordinator::{AgentKind, TuningConfig};
use aituning::metrics::stats::geomean;
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512, 1024, 2048]
    } else if quick {
        &[16, 32]
    } else {
        &[64, 128, 256]
    };
    let runs_per = if quick { 6 } else { 15 };
    let agent = if aituning::runtime::default_artifacts_dir().join("manifest.json").exists()
        && !quick
    {
        AgentKind::Dqn
    } else {
        AgentKind::Tabular
    };

    let mut t = Table::new(&["machine", "workload", "images", "reference (µs)", "best gain"]);
    let mut timing = Table::new(&["machine", "jobs", "1 worker", "all cores", "speedup"]);
    let mut gains = Vec::new();
    let mut total_runs = 0;
    for machine in [Machine::cheyenne(), Machine::edison()] {
        let base = TuningConfig {
            machine: machine.clone(),
            agent,
            runs: runs_per,
            seed: 5,
            ..TuningConfig::default()
        };
        let jobs = job_grid(&WorkloadKind::TRAINING, image_counts, agent, base.seed);

        let serial =
            CampaignEngine::new(CampaignConfig { base: base.clone(), workers: 1 }).run(&jobs)?;
        let parallel = CampaignEngine::new(CampaignConfig { base, workers: 0 }).run(&jobs)?;
        assert_eq!(
            serial.fingerprint(),
            parallel.fingerprint(),
            "campaign results must be bit-identical at 1 and {} workers",
            parallel.workers
        );

        for r in &parallel.results {
            gains.push(1.0 + r.outcome.improvement());
            t.row(vec![
                machine.name.to_string(),
                r.job.workload.name().to_string(),
                r.job.images.to_string(),
                format!("{:.0}", r.outcome.reference_us),
                format!("{:+.1}%", r.outcome.improvement() * 100.0),
            ]);
        }
        total_runs += parallel.total_app_runs();
        let s1 = serial.wall_clock.as_secs_f64();
        let sn = parallel.wall_clock.as_secs_f64();
        timing.row(vec![
            machine.name.to_string(),
            format!("{}", jobs.len()),
            format!("{s1:.2}s"),
            format!("{sn:.2}s ({} workers)", parallel.workers),
            format!("{:.2}x", s1 / sn.max(1e-9)),
        ]);
    }
    println!("=== §6 training campaign ({agent:?} agent, {runs_per} runs/cell) ===");
    t.print();
    println!(
        "\ngeomean speedup across cells: {:.3}x over {} total application runs",
        geomean(&gains),
        total_runs
    );
    println!("\n=== campaign engine scaling (results verified bit-identical) ===");
    timing.print();
    Ok(())
}
