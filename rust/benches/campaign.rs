//! §6 training-campaign table: the four training codes across scales on
//! both machine models, reporting reference time and AITuning's best
//! improvement per cell (a scaled version of the paper's 5000-run,
//! 64–2048-process campaign).

use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::metrics::stats::geomean;
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512, 1024, 2048]
    } else if quick {
        &[16, 32]
    } else {
        &[64, 128, 256]
    };
    let runs_per = if quick { 6 } else { 15 };
    let agent = if aituning::runtime::default_artifacts_dir().join("manifest.json").exists()
        && !quick
    {
        AgentKind::Dqn
    } else {
        AgentKind::Tabular
    };

    let mut t = Table::new(&["machine", "workload", "images", "reference (µs)", "best gain"]);
    let mut gains = Vec::new();
    let mut total_runs = 0;
    for machine in [Machine::cheyenne(), Machine::edison()] {
        let cfg = TuningConfig {
            machine: machine.clone(),
            agent,
            runs: runs_per,
            seed: 5,
            ..TuningConfig::default()
        };
        let mut ctl = Controller::new(cfg)?;
        for kind in WorkloadKind::TRAINING {
            for &n in image_counts {
                let out = ctl.tune(kind, n)?;
                gains.push(1.0 + out.improvement());
                t.row(vec![
                    machine.name.to_string(),
                    kind.name().to_string(),
                    n.to_string(),
                    format!("{:.0}", out.reference_us),
                    format!("{:+.1}%", out.improvement() * 100.0),
                ]);
            }
        }
        total_runs += ctl.lifetime_runs();
    }
    println!("=== §6 training campaign ({agent:?} agent, {runs_per} runs/cell) ===");
    t.print();
    println!(
        "\ngeomean speedup across cells: {:.3}x over {} total application runs",
        geomean(&gains),
        total_runs
    );
    Ok(())
}
