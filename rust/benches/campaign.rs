//! §6 training-campaign table, driven by the parallel campaign engine:
//! the four training codes across scales on both machine models — one
//! job grid, one worker pool spanning both testbeds — reporting
//! reference time and AITuning's best improvement per cell (a scaled
//! version of the paper's 5000-run, 64–2048-process campaign).
//!
//! Determinism checks: the independent campaign is executed on 1 worker
//! and on all cores and the fingerprints must match; the shared-learning
//! campaign is likewise executed at both worker counts and its
//! fingerprint (which folds in the final LearnerHub state) must match
//! too — under every replay policy. The independent-vs-shared ablation
//! table compares per-cell improvements at an identical run budget, and
//! the replay-policy ablation compares uniform / stratified /
//! prioritized retention (resident occupancy + per-merge-round cost).
//!
//! `--spill-scale` instead runs the campaign-store scaling study:
//! synthetic outcome streams of 10³/10⁴/10⁵ jobs (10⁶ with `--full`)
//! pushed through a spilling [`ShardedCollector`] + [`OutcomeSink`],
//! asserting that peak collector residency stays flat (within 2× of
//! the smallest size) while the in-memory collector grows linearly —
//! the memory bound `campaign --spill-dir` rests on.
//!
//! `--async-ablation` runs the sync-vs-async shared-learning study:
//! the same job list under the round-synchronous schedule and the
//! bounded-staleness schedule at 1/4/8/16/32 workers, with an injected
//! straggler job plus per-segment jitter ([`StraggleSpec`]) modelling
//! heterogeneous segment times. Reported per worker count: wall-clock
//! speedup, geomean/best improvement per mode, and mean
//! episodes-to-threshold. `--json` emits the table as a
//! machine-readable report (CI uploads it as a workflow artifact).

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use aituning::backend::BackendId;
use aituning::campaign::store::{CampaignStore, Manifest, OutcomeSink, StoreMode};
use aituning::campaign::{
    ablation_table, job_grid, CampaignConfig, CampaignEngine, CampaignJob, CampaignReport,
    JobOutcome, ReportAccumulator, ShardedCollector, SpillSink, StraggleSpec,
};
use aituning::coordinator::{
    AgentKind, ReplayPolicyKind, SharedLearning, SyncMode, TuningConfig, TuningOutcome,
};
use aituning::metrics::{RunRecord, TuningLog};
use aituning::mpi_t::{CvarSet, PvarStats};
use aituning::simmpi::Machine;
use aituning::util::bench::Table;
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

/// One synthetic finished job: realistic shape (3-run log, cvar sets,
/// bit-varied times) without paying for simulation, so the collector
/// and store are the only things measured.
fn synthetic_outcome(i: usize) -> JobOutcome {
    let mut rng = Rng::with_stream(0xbe9c_5ca1e, i as u64);
    let job = CampaignJob {
        backend: BackendId::Coarrays,
        machine: "cheyenne",
        workload: WorkloadKind::LatticeBoltzmann,
        images: 8,
        agent: AgentKind::Tabular,
        seed: i as u64,
    };
    let mut log = TuningLog::new(job.workload.name(), job.images);
    let reference_us = rng.range_f64(900.0, 1100.0);
    let best_us = reference_us * rng.range_f64(0.85, 1.0);
    for run in 0..3 {
        log.push(RunRecord {
            run_index: run,
            cvars: CvarSet::vanilla(),
            total_time_us: rng.range_f64(800.0, 1200.0),
            reward: rng.range_f64(-1.0, 1.0),
            action: Some(run % 7),
            epsilon: 0.5,
            pvars: PvarStats::default(),
        });
    }
    JobOutcome {
        job,
        outcome: TuningOutcome {
            log,
            best: CvarSet::vanilla(),
            ensemble: CvarSet::vanilla(),
            reference_us,
            best_us,
        },
    }
}

/// The `--spill-scale` study: flat spilled residency vs linear
/// in-memory growth, plus streamed re-aggregation timing.
fn spill_scale(full: bool) -> anyhow::Result<()> {
    let sizes: &[usize] =
        if full { &[1_000, 10_000, 100_000, 1_000_000] } else { &[1_000, 10_000, 100_000] };
    // The in-memory leg exists to show linear growth, which 10⁴ rows
    // already demonstrate — no need to hold 10⁶ logs resident.
    const IN_MEMORY_CAP: usize = 10_000;
    let workers = 4;

    let mut t = Table::new(&[
        "jobs", "spilled peak resident", "in-memory peak resident", "store MB", "spill wall",
        "stream-merge wall",
    ]);
    let mut spilled_residents: Vec<usize> = Vec::new();
    for &n in sizes {
        let dir = std::env::temp_dir()
            .join(format!("aituning-spill-scale-{n}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = CampaignStore::create(&dir, Manifest::new(StoreMode::Independent, 0, n))?;

        // Spilled leg: the engine's exact push path (worker threads,
        // shared cursor, per-shard segments).
        let started = Instant::now();
        let sink = Arc::new(OutcomeSink::create(store.dir(), store.next_generation()?, workers)?);
        let collector = ShardedCollector::with_spill(
            n,
            workers,
            sink as Arc<dyn SpillSink<anyhow::Result<JobOutcome>>>,
        );
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    collector.push(w, i, Ok(synthetic_outcome(i)));
                });
            }
        });
        // Everything spilled: the in-flight items are the residency.
        let resident = collector.peak_buffered() + workers;
        let bytes = collector.spilled_bytes();
        let attempted: BTreeSet<usize> = (0..n).collect();
        let residue = collector.into_spill_residue(&attempted)?;
        assert!(residue.is_empty(), "synthetic jobs never fail");
        let spill_wall = started.elapsed();
        spilled_residents.push(resident);

        // Stream the store back through the report accumulator (the
        // resume/rebuild path) — O(shards) memory, never O(jobs).
        let started = Instant::now();
        let mut acc = ReportAccumulator::new();
        let mut merge = store.merge()?;
        while let Some((i, record)) = merge.next_record()? {
            let (_, outcome) = aituning::campaign::store::format::decode_record(&record)?;
            assert_eq!(i, acc.len(), "records must stream in job-index order");
            acc.push(&outcome);
        }
        assert_eq!(acc.len(), n);
        let merge_wall = started.elapsed();

        // In-memory leg: the classic collector buffers every row.
        let in_memory_peak = if n <= IN_MEMORY_CAP {
            let collector = ShardedCollector::new(n, workers);
            for i in 0..n {
                collector.push(i % workers, i, synthetic_outcome(i));
            }
            let peak = collector.peak_buffered();
            assert_eq!(peak, n, "in-memory residency is linear in job count");
            format!("{peak}")
        } else {
            format!("(= {n})")
        };

        t.row(vec![
            n.to_string(),
            resident.to_string(),
            in_memory_peak,
            format!("{:.1}", bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.2}s", spill_wall.as_secs_f64()),
            format!("{:.2}s", merge_wall.as_secs_f64()),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    println!("=== campaign-store spill scaling ({workers} workers) ===");
    t.print();
    let base = spilled_residents[0];
    for (&n, &resident) in sizes.iter().zip(&spilled_residents) {
        assert!(
            resident <= base.saturating_mul(2),
            "spilled residency must stay flat: {resident} rows at {n} jobs vs {base} at {}",
            sizes[0]
        );
    }
    println!(
        "peak spilled residency stayed within 2x of the {}-job baseline across {}x more jobs",
        sizes[0],
        sizes[sizes.len() - 1] / sizes[0]
    );
    Ok(())
}

/// Mean number of tuning runs a job needed before it first beat its
/// reference time by `threshold` (fraction); jobs that never got there
/// count their full budget. Lower = faster convergence.
fn episodes_to_threshold(report: &CampaignReport, threshold: f64) -> f64 {
    let mut total = 0usize;
    for r in &report.results {
        let runs = &r.outcome.log.runs;
        let target = r.outcome.reference_us * (1.0 - threshold);
        let hit = runs.iter().position(|rec| rec.total_time_us <= target);
        total += hit.map(|i| i + 1).unwrap_or(runs.len());
    }
    total as f64 / report.results.len().max(1) as f64
}

/// The `--async-ablation` study (see module docs): sync vs
/// bounded-staleness async over worker counts, straggler injected.
fn async_ablation(quick: bool, emit_json: bool) -> anyhow::Result<()> {
    use aituning::util::json::{arr, num, obj, s, Json};

    let worker_counts: &[usize] = &[1, 4, 8, 16, 32];
    let runs_per = if quick { 8 } else { 16 };
    let sync_every = 2usize;
    let segments = runs_per / sync_every;
    // Heterogeneous segment times: job 0 is a constant straggler, and
    // *every* job draws hash-derived jitter per segment. The sync
    // schedule pays the per-round max of those delays; async pays each
    // job's own chain — that gap, not the straggler constant (which is
    // a serial chain in both modes), is the async win being measured.
    let spec = StraggleSpec { straggler_job: 0, straggler_ms: 8, jitter_ms: 40, seed: 0xab1e };
    let threshold = 0.01;

    let mut t = Table::new(&[
        "workers", "jobs", "sync wall", "async wall", "speedup", "sync geo", "async geo",
        "sync eps@1%", "async eps@1%", "max staleness seen",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_at_16 = None;
    for &workers in worker_counts {
        let jobs_n = workers.max(2);
        let jobs: Vec<CampaignJob> = (0..jobs_n)
            .map(|i| CampaignJob {
                backend: BackendId::Coarrays,
                machine: "cheyenne",
                workload: WorkloadKind::TRAINING[i % WorkloadKind::TRAINING.len()],
                images: 16 << (i / WorkloadKind::TRAINING.len() % 2),
                agent: AgentKind::Tabular,
                seed: 1000 + i as u64,
            })
            .collect();
        // The window that lets W workers overlap freely: in steady
        // state the oldest in-flight pull lags by about the in-flight
        // count, so the start gate needs S ≈ 2(W-1); round up to 2W.
        let staleness = (2 * workers).max(1);
        let base = |mode: SyncMode| TuningConfig {
            machine: Machine::cheyenne(),
            agent: AgentKind::Tabular,
            runs: runs_per,
            seed: 7,
            shared: Some(SharedLearning { sync_every, mode, ..SharedLearning::default() }),
            ..TuningConfig::default()
        };
        let sync = CampaignEngine::new(CampaignConfig {
            base: base(SyncMode::Sync),
            workers,
            straggle: Some(spec),
            fuse_training: true,
        })
        .run_shared(&jobs)?;
        let async_ = CampaignEngine::new(CampaignConfig {
            base: base(SyncMode::Async { staleness }),
            workers,
            straggle: Some(spec),
            fuse_training: true,
        })
        .run_shared(&jobs)?;

        let hub = async_.hub.expect("async shared report carries hub state");
        assert_eq!(
            hub.generations,
            jobs_n * segments,
            "every segment must arrive as exactly one generation-stamped merge"
        );
        let max_staleness =
            hub.staleness.iter().rposition(|&n| n > 0).unwrap_or(0);
        let sync_wall = sync.wall_clock.as_secs_f64();
        let async_wall = async_.wall_clock.as_secs_f64();
        let speedup = sync_wall / async_wall.max(1e-9);
        if workers == 16 {
            speedup_at_16 = Some(speedup);
        }
        let sync_eps = episodes_to_threshold(&sync, threshold);
        let async_eps = episodes_to_threshold(&async_, threshold);
        t.row(vec![
            workers.to_string(),
            jobs_n.to_string(),
            format!("{sync_wall:.2}s"),
            format!("{async_wall:.2}s"),
            format!("{speedup:.2}x"),
            format!("{:.3}x", sync.geomean_speedup()),
            format!("{:.3}x", async_.geomean_speedup()),
            format!("{sync_eps:.1}"),
            format!("{async_eps:.1}"),
            format!("{max_staleness}"),
        ]);
        rows.push(obj(vec![
            ("workers", num(workers as f64)),
            ("jobs", num(jobs_n as f64)),
            ("staleness_window", num(staleness as f64)),
            ("sync_wall_s", num(sync_wall)),
            ("async_wall_s", num(async_wall)),
            ("speedup", num(speedup)),
            ("sync_geomean", num(sync.geomean_speedup())),
            ("async_geomean", num(async_.geomean_speedup())),
            ("sync_episodes_to_threshold", num(sync_eps)),
            ("async_episodes_to_threshold", num(async_eps)),
            ("hub_generations", num(hub.generations as f64)),
            (
                "staleness_histogram",
                arr(hub.staleness.iter().map(|&n| num(n as f64))),
            ),
        ]));
        // Convergence must not be bought with the speedup: async's
        // learning quality stays within tolerance of sync's.
        let geo_gap = (async_.geomean_speedup() - sync.geomean_speedup()).abs()
            / sync.geomean_speedup().max(1e-9);
        assert!(
            geo_gap <= 0.05,
            "async geomean improvement drifted {:.1}% from sync at {workers} workers",
            geo_gap * 100.0
        );
    }
    if !emit_json {
        println!("=== sync-vs-async shared learning (straggler: job 0 +{}ms, jitter 0..{}ms) ===",
            spec.straggler_ms, spec.jitter_ms);
        t.print();
    }
    // Timing assertion kept soft (a print, not a panic): CI machines
    // share cores, and the JSON record is the artifact that matters.
    // Goes to stderr so `--json` stdout stays one parseable object.
    match speedup_at_16 {
        Some(x) if x >= 1.2 => {
            eprintln!("async speedup at 16 workers: {x:.2}x (target >= 1.5x)")
        }
        Some(x) => eprintln!(
            "WARNING: async speedup at 16 workers only {x:.2}x (target >= 1.5x, soft floor 1.2x)"
        ),
        None => {}
    }
    if emit_json {
        let report = obj(vec![
            ("bench", s("async_ablation")),
            ("quick", Json::Bool(quick)),
            ("straggler_ms", num(spec.straggler_ms as f64)),
            ("jitter_ms", num(spec.jitter_ms as f64)),
            ("runs_per_job", num(runs_per as f64)),
            ("sync_every", num(sync_every as f64)),
            ("speedup_at_16_workers", speedup_at_16.map(num).unwrap_or(Json::Null)),
            ("rows", Json::Arr(rows)),
        ]);
        println!("{report}");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    if std::env::args().any(|a| a == "--spill-scale") {
        return spill_scale(full);
    }
    if std::env::args().any(|a| a == "--async-ablation") {
        let json = std::env::args().any(|a| a == "--json");
        return async_ablation(quick, json);
    }
    let image_counts: &[usize] = if full {
        &[64, 128, 256, 512, 1024, 2048]
    } else if quick {
        &[16, 32]
    } else {
        &[64, 128, 256]
    };
    let runs_per = if quick { 6 } else { 15 };
    // The native engine needs no artifacts; quick mode stays tabular
    // for wall-clock only.
    let agent = if quick { AgentKind::Tabular } else { AgentKind::Dqn };
    let machines = [Machine::cheyenne(), Machine::edison()];

    let base = TuningConfig {
        machine: machines[0].clone(),
        agent,
        runs: runs_per,
        seed: 5,
        shared: Some(SharedLearning { sync_every: if quick { 2 } else { 5 }, ..SharedLearning::default() }),
        ..TuningConfig::default()
    };
    let jobs = job_grid(
        BackendId::Coarrays,
        &machines,
        &WorkloadKind::TRAINING,
        image_counts,
        agent,
        base.seed,
    );

    // --- independent mode: serial vs parallel, bit-identical ---
    let serial = CampaignEngine::new(CampaignConfig {
        base: base.clone(),
        workers: 1,
        straggle: None,
        fuse_training: true,
    })
    .run(&jobs)?;
    let parallel = CampaignEngine::new(CampaignConfig {
        base: base.clone(),
        workers: 0,
        straggle: None,
        fuse_training: true,
    })
    .run(&jobs)?;
    assert_eq!(
        serial.fingerprint(),
        parallel.fingerprint(),
        "independent campaign must be bit-identical at 1 and {} workers",
        parallel.workers
    );

    // --- shared mode: same jobs through the LearnerHub, same check ---
    let shared_serial = CampaignEngine::new(CampaignConfig {
        base: base.clone(),
        workers: 1,
        straggle: None,
        fuse_training: true,
    })
    .run_shared(&jobs)?;
    let shared_parallel = CampaignEngine::new(CampaignConfig {
        base: base.clone(),
        workers: 0,
        straggle: None,
        fuse_training: true,
    })
    .run_shared(&jobs)?;
    assert_eq!(
        shared_serial.fingerprint(),
        shared_parallel.fingerprint(),
        "shared campaign (hub state included) must be bit-identical at 1 and {} workers",
        shared_parallel.workers
    );

    // --- ablation table: independent vs shared, identical budget ---
    println!("=== §6 training campaign ({agent:?} agent, {runs_per} runs/cell) ===");
    ablation_table(&parallel, &shared_parallel).print();
    let hub = shared_parallel.hub.expect("shared report carries hub state");
    println!(
        "\ngeomean speedup: independent {:.3}x vs shared {:.3}x over {} cells",
        parallel.geomean_speedup(),
        shared_parallel.geomean_speedup(),
        jobs.len()
    );
    println!("hub: {}", hub.describe());

    // --- replay-policy ablation: same shared campaign under each
    // retention/selection policy. Per-policy fingerprints are asserted
    // 1-vs-N (uniform was already checked above). The round-cost
    // column reports how cheap a merge round is end-to-end; note it is
    // dominated by episode simulation + training, so the zero-copy
    // HubView pull itself is pinned by the Arc::ptr_eq unit tests in
    // coordinator/hub.rs, not by this number. ---
    let sync_every = base.shared.map(|s| s.sync_every).unwrap_or(5);
    let rounds = runs_per.div_ceil(sync_every).max(1);
    let mut ablation = Table::new(&[
        "replay policy", "geomean speedup", "resident", "merge rounds", "round cost",
    ]);
    let mut policy_reports = vec![(ReplayPolicyKind::Uniform, shared_parallel.clone())];
    for policy in [ReplayPolicyKind::Stratified, ReplayPolicyKind::Prioritized] {
        let cfg = TuningConfig { replay_policy: policy, ..base.clone() };
        let one = CampaignEngine::new(CampaignConfig {
            base: cfg.clone(),
            workers: 1,
            straggle: None,
            fuse_training: true,
        })
        .run_shared(&jobs)?;
        let many = CampaignEngine::new(CampaignConfig {
            base: cfg,
            workers: 0,
            straggle: None,
            fuse_training: true,
        })
        .run_shared(&jobs)?;
        assert_eq!(
            one.fingerprint(),
            many.fingerprint(),
            "{policy} shared campaign must be bit-identical at 1 and {} workers",
            many.workers
        );
        policy_reports.push((policy, many));
    }
    for (policy, report) in &policy_reports {
        let hub = report.hub.expect("shared report carries hub state");
        ablation.row(vec![
            policy.to_string(),
            format!("{:.3}x", report.geomean_speedup()),
            format!("{}/{}", hub.replay_len, hub.total_transitions),
            format!("{rounds}"),
            format!("{:.1} ms", report.wall_clock.as_secs_f64() * 1e3 / rounds as f64),
        ]);
    }
    println!("\n=== replay-policy ablation (shared mode, {} workers) ===", shared_parallel.workers);
    ablation.print();

    // --- backend ablation: the same campaign machinery over the second
    // tunable runtime (MPI collective-algorithm selection). The tabular
    // agent sizes itself from the backend's derived action space (14
    // actions incl. the enumerated algorithm selects), and the 1-vs-N
    // fingerprint identity must hold for this backend exactly as it
    // does for coarrays. ---
    let coll_images: &[usize] = if quick { &[16, 32] } else { &[32, 64, 128] };
    let coll_base = TuningConfig {
        machine: machines[0].clone(),
        backend: BackendId::Collectives,
        agent: AgentKind::Tabular, // AOT artifacts are coarrays-shaped
        runs: runs_per,
        seed: 5,
        ..TuningConfig::default()
    };
    let coll_jobs = job_grid(
        BackendId::Collectives,
        &machines,
        BackendId::Collectives.runtime().training_workloads(),
        coll_images,
        coll_base.agent,
        coll_base.seed,
    );
    let coll_serial = CampaignEngine::new(CampaignConfig {
        base: coll_base.clone(),
        workers: 1,
        straggle: None,
        fuse_training: true,
    })
    .run(&coll_jobs)?;
    let coll_parallel = CampaignEngine::new(CampaignConfig {
        base: coll_base.clone(),
        workers: 0,
        straggle: None,
        fuse_training: true,
    })
    .run(&coll_jobs)?;
    assert_eq!(
        coll_serial.fingerprint(),
        coll_parallel.fingerprint(),
        "collectives campaign must be bit-identical at 1 and {} workers",
        coll_parallel.workers
    );
    let mut backend_table = Table::new(&[
        "backend", "cells", "geomean speedup", "best cell", "wall clock",
    ]);
    for (name, report) in
        [("coarrays", &parallel), ("collectives", &coll_parallel)]
    {
        let best = report
            .improvements()
            .into_iter()
            .fold(f64::NEG_INFINITY, f64::max);
        backend_table.row(vec![
            name.to_string(),
            report.results.len().to_string(),
            format!("{:.3}x", report.geomean_speedup()),
            format!("{:+.1}%", best * 100.0),
            format!("{:.2}s", report.wall_clock.as_secs_f64()),
        ]);
    }
    println!("\n=== backend ablation (--backend coarrays vs collectives) ===");
    backend_table.print();

    // --- engine scaling (results verified bit-identical above) ---
    let mut timing = Table::new(&["mode", "jobs", "1 worker", "all cores", "speedup"]);
    for (mode, s1, sn, w) in [
        ("independent", &serial, &parallel, parallel.workers),
        ("shared", &shared_serial, &shared_parallel, shared_parallel.workers),
    ] {
        let a = s1.wall_clock.as_secs_f64();
        let b = sn.wall_clock.as_secs_f64();
        timing.row(vec![
            mode.to_string(),
            format!("{}", jobs.len()),
            format!("{a:.2}s"),
            format!("{b:.2}s ({w} workers)"),
            format!("{:.2}x", a / b.max(1e-9)),
        ]);
    }
    println!("\n=== campaign engine scaling ===");
    timing.print();
    println!(
        "total application runs: {}",
        serial.total_app_runs() + parallel.total_app_runs()
            + shared_serial.total_app_runs()
            + shared_parallel.total_app_runs()
            + coll_serial.total_app_runs()
            + coll_parallel.total_app_runs()
    );
    Ok(())
}
