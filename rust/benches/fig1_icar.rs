//! Figure 1 reproduction: ICAR on Cheyenne, default vs human-optimized
//! vs AITuning-optimized, at 256 and 512 images — plus the §6.2
//! single-knob ablations (async progress / eager limit), which the
//! paper discusses alongside.
//!
//! Expected shape (paper): AITuning best at both scales; ~13% over
//! default at 256 images, ~25% at 512; human tuning in between; async
//! progress the most influential single parameter.

use aituning::baselines::human_tuned;
use aituning::coordinator::{AgentKind, Controller, TuningConfig};
use aituning::mpi_t::{CvarId, CvarSet};
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    // Native DQN engine: no artifacts required.
    let agent = AgentKind::Dqn;
    let cfg = TuningConfig { agent, runs: 20, seed: 1, ..TuningConfig::default() };
    let mut ctl = Controller::new(cfg)?;

    // Short pre-training pass (scaled-down §6 campaign).
    let pre_scales: &[usize] = if quick { &[16] } else { &[32, 64] };
    for kind in WorkloadKind::TRAINING {
        for &n in pre_scales {
            let _ = ctl.tune(kind, n)?;
        }
    }

    let image_counts: &[usize] = if quick { &[32, 64] } else { &[256, 512] };
    let paper = [(256usize, 13.0f64), (512usize, 25.0f64)];

    let mut t = Table::new(&[
        "images", "config", "total (µs)", "gain vs default", "paper",
    ]);
    for &images in image_counts {
        let out = ctl.tune(WorkloadKind::Icar, images)?;
        let eval = |ctl: &mut Controller, cv: &CvarSet| {
            ctl.evaluate(WorkloadKind::Icar, images, cv, 3)
        };
        let default_us = eval(&mut ctl, &CvarSet::vanilla())?;
        let human_us = eval(&mut ctl, &human_tuned())?;
        let tuned_us = eval(&mut ctl, &out.ensemble)?.min(out.best_us);

        // §6.2 single-knob ablations.
        let mut async_only = CvarSet::vanilla();
        async_only.set(CvarId(0), 1);
        let async_us = eval(&mut ctl, &async_only)?;

        let gain = |v: f64| format!("{:+.1}%", (default_us - v) / default_us * 100.0);
        let paper_gain = paper
            .iter()
            .find(|(n, _)| *n == images)
            .map(|(_, g)| format!("+{g:.0}%"))
            .unwrap_or_else(|| "-".into());
        t.row(vec![images.to_string(), "default (vanilla MPICH)".into(), format!("{default_us:.0}"), "+0.0%".into(), "baseline".into()]);
        t.row(vec![images.to_string(), "human (eager x10, §6.2)".into(), format!("{human_us:.0}"), gain(human_us), "between".into()]);
        t.row(vec![images.to_string(), "aituning (20-run ensemble)".into(), format!("{tuned_us:.0}"), gain(tuned_us), paper_gain]);
        t.row(vec![images.to_string(), "ablation: async only".into(), format!("{async_us:.0}"), gain(async_us), "most influential".into()]);
    }
    println!("=== Figure 1: ICAR total time, default vs optimized (Cheyenne model) ===");
    t.print();
    Ok(())
}
