//! L1/L2/L3 hot-path microbenchmarks: Q-network forward (action
//! selection), train step (replay update), state construction, and
//! their share of one tuning iteration vs the simulated run itself.
//!
//! §Perf target: tuning overhead (forward + train + state build) must
//! be negligible against one application run.

use aituning::coordinator::{build_state, RelativeTracker, NUM_ACTIONS, STATE_DIM};
use aituning::coordinator::{run_episode, ReplayBuffer, Transition};
use aituning::mpi_t::CvarSet;
use aituning::runtime::{Manifest, QNet, RuntimeClient};
use aituning::simmpi::Machine;
use aituning::util::bench::{opaque, time, Table};
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let dir = aituning::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return Ok(());
    }
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(&dir)?;
    let mut rng = Rng::new(0);
    let mut qnet = QNet::load(&client, &manifest, &mut rng)?;
    let samples = if quick { 20 } else { 100 };

    let mut t = Table::new(&["operation", "median", "p90", "iters"]);

    // L2/L1: forward pass (action selection path)
    let state = vec![0.3f32; STATE_DIM];
    let s = time(5, samples, || {
        opaque(qnet.q_values(&state).unwrap());
    });
    t.row(vec!["q_forward (batch 1)".into(), format!("{:.1} µs", s.median_us()), format!("{:.1} µs", s.p90_ns / 1e3), s.iters.to_string()]);

    // L2/L1: replay train step
    let mut replay = ReplayBuffer::new(1024);
    let mut rng2 = Rng::new(1);
    for i in 0..64 {
        let mut st = vec![0.0f32; STATE_DIM];
        st[0] = i as f32 / 64.0;
        replay.push(Transition {
            state: st.clone(),
            action: i % NUM_ACTIONS,
            reward: 0.1,
            next_state: st,
            done: false,
            workload: None,
        });
    }
    let batch = replay.sample(qnet.replay_batch, &mut rng2);
    let s = time(3, samples, || {
        opaque(qnet.train_step(&batch, 1e-3, 0.9).unwrap());
    });
    t.row(vec!["q_train (batch 32, Adam)".into(), format!("{:.1} µs", s.median_us()), format!("{:.1} µs", s.p90_ns / 1e3), s.iters.to_string()]);

    // L3: state construction
    let tracker = RelativeTracker::new();
    let stats = aituning::mpi_t::PvarStats::default();
    let cv = CvarSet::vanilla();
    let state_machine = Machine::cheyenne();
    let s = time(10, samples * 10, || {
        opaque(build_state(&stats, &tracker, &cv, &state_machine, 256, 3, 0.5));
    });
    t.row(vec!["build_state (L3)".into(), format!("{:.2} µs", s.median_us()), format!("{:.2} µs", s.p90_ns / 1e3), s.iters.to_string()]);

    // L3: replay sampling
    let s = time(10, samples * 10, || {
        opaque(replay.sample(32, &mut rng2));
    });
    t.row(vec!["replay sample (32)".into(), format!("{:.2} µs", s.median_us()), format!("{:.2} µs", s.p90_ns / 1e3), s.iters.to_string()]);

    // Reference: one simulated application run (the thing tuning wraps).
    let machine = Machine::cheyenne();
    let images = if quick { 16 } else { 64 };
    let s = time(1, if quick { 3 } else { 10 }, || {
        opaque(
            run_episode(WorkloadKind::LatticeBoltzmann, images, &machine, &cv, 0.02, 42, 1)
                .unwrap(),
        );
    });
    t.row(vec![
        format!("one simulated LBM run ({images} img)"),
        format!("{:.1} ms", s.median_ms()),
        format!("{:.1} ms", s.p90_ns / 1e6),
        s.iters.to_string(),
    ]);

    println!("=== DQN runtime + tuning-overhead microbenchmarks ===");
    t.print();
    println!("\ntuning overhead per iteration = forward + train + state build");
    Ok(())
}
