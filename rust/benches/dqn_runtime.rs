#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Q-engine roofline + ablation + tuning-overhead microbenchmarks.
//!
//! Part 0 — the kernel roofline: batched forward through the native
//! engine's `Scalar` and `Blocked` dense kernels over batch sizes
//! {1, 8, 32, 128, 512}, plus the AOT/PJRT path where artifacts exist
//! (its fused single-state artifact is looped per row — batch layout
//! is compiled in). Per-sample µs and the throughput multiple over the
//! per-sample scalar path (batch 1) — the number the campaign round's
//! batched greedy selection banks on. The two kernels are bitwise-
//! identical (`runtime/native/kernels.rs`), so this table measures
//! pure speed, never accuracy.
//!
//! Part 0b — the training-path roofline: one round's worth of per-job
//! gradient passes (batch 32 over one shared master), sequential
//! (a loop of per-job `NativeQNet::train_grads`) vs fused
//! (`FusedTrainer::train_grads` stacking every job's minibatch into
//! one tall GEMM per layer, through packed weight panels). The two
//! paths are bitwise-identical per job (`runtime/native/fused.rs`),
//! so this table too measures pure speed. The fused cells also assert
//! that the trainer's scratch stops growing after the warm-up call —
//! the no-per-round-allocation contract the campaign loop relies on.
//!
//! Part 1 — the engine ablation: forward (action selection) and one
//! replay train step (batch 32) on the native MLP engine, the tabular
//! fallback, and the AOT/PJRT artifact path (reported as unavailable
//! when the `pjrt` feature or the artifacts are absent — the stub row
//! documents exactly what the native engine replaces).
//!
//! Part 2 — §Perf context: state construction, replay sampling, and
//! one simulated application run. Tuning overhead (forward + train +
//! state build) must stay negligible against the run itself.
//!
//! `--quick` shrinks sample counts (the CI perf smoke); `--json`
//! additionally writes `BENCH_dqn_runtime.json` (engine × batch ×
//! median/p90 µs) so the perf trajectory is tracked across PRs.

use aituning::backend::BackendId;
use aituning::coordinator::{
    build_state, run_episode, Agent, RelativeTracker, ReplayBuffer, TabularAgent, Transition,
};
use aituning::mpi_t::CvarSet;
use aituning::runtime::{
    DenseKernel, FusedTrainer, Manifest, NativeQNet, RuntimeClient, TrainBatch,
};
use aituning::simmpi::Machine;
use aituning::util::bench::{opaque, time, Table};
use aituning::util::json::{arr, num, obj, s as js, Json};
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

/// Batch sizes the roofline sweeps.
const ROOFLINE_BATCHES: [usize; 5] = [1, 8, 32, 128, 512];

/// Round widths (live jobs) the training roofline sweeps.
const TRAINING_JOBS: [usize; 3] = [1, 4, 8];

/// Per-job minibatch size of the training roofline — the campaign
/// default (`replay_batch`).
const TRAINING_BATCH: usize = 32;

/// One measured (engine, batch) cell, kept for the JSON report.
struct RooflineRow {
    engine: &'static str,
    batch: usize,
    median_us: f64,
    p90_us: f64,
    per_sample_us: f64,
}

/// A 64-transition buffer plus one 32-row minibatch drawn from it —
/// shared by the engine ablation (the batch) and the sampling-overhead
/// timing (the buffer).
fn replay_fixture(backend: BackendId, rng: &mut Rng) -> (ReplayBuffer, TrainBatch) {
    let mut replay = ReplayBuffer::for_backend(
        1024,
        aituning::coordinator::ReplayPolicyKind::Uniform,
        backend,
    );
    for i in 0..64 {
        let mut st = vec![0.0f32; backend.state_dim()];
        st[0] = i as f32 / 64.0;
        replay.push(Transition {
            state: st.clone(),
            action: i % backend.num_actions(),
            reward: 0.1,
            next_state: st,
            done: false,
            workload: None,
        });
    }
    let batch = replay.sample(32, rng);
    (replay, batch)
}

/// Load the AOT engine if its artifacts (and the `pjrt` feature) are
/// present.
fn load_aot(rng: &mut Rng) -> anyhow::Result<aituning::runtime::AotQNet> {
    let dir = aituning::runtime::default_artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "artifacts not built");
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(&dir)?;
    aituning::runtime::AotQNet::load(&client, &manifest, rng)
}

/// One native-kernel roofline cell: time `forward_batch` under
/// `kernel`, record it, return `(call µs, per-sample µs)`.
fn native_cell(
    net: &mut NativeQNet,
    states: &[f32],
    batch: usize,
    n: usize,
    kernel: DenseKernel,
    rows: &mut Vec<RooflineRow>,
) -> (f64, f64) {
    net.set_kernel(kernel);
    let sample = time(3, n, || {
        opaque(net.forward_batch(states, batch).unwrap());
    });
    let per_sample = sample.median_us() / batch as f64;
    rows.push(RooflineRow {
        engine: kernel.name(),
        batch,
        median_us: sample.median_us(),
        p90_us: sample.p90_us(),
        per_sample_us: per_sample,
    });
    (sample.median_us(), per_sample)
}

/// Part 0: the scalar-vs-blocked-vs-AOT roofline over batch sizes.
/// Returns the measured rows for the JSON report.
fn roofline(backend: BackendId, samples: usize) -> Vec<RooflineRow> {
    let dim = backend.state_dim();
    let mut init_rng = Rng::new(0);
    let mut net = NativeQNet::with_default_shape(dim, backend.num_actions(), &mut init_rng);
    let mut aot = load_aot(&mut Rng::new(0)).ok();

    let mut rows: Vec<RooflineRow> = Vec::new();
    let mut state_rng = Rng::new(2);
    let mut table = Table::new(&[
        "batch",
        "scalar fwd",
        "scalar /sample",
        "blocked fwd",
        "blocked /sample",
        "speedup vs scalar b=1",
        "aot /sample",
    ]);

    // The per-sample scalar path at batch 1 — the baseline every other
    // cell's speedup is quoted against (what the engine did before the
    // kernel seam existed).
    let mut scalar_b1_us = f64::NAN;

    for &batch in &ROOFLINE_BATCHES {
        let states: Vec<f32> =
            (0..batch * dim).map(|_| state_rng.range_f64(-1.0, 1.0) as f32).collect();
        // Big batches do proportionally more work per call: scale the
        // sample count down (deterministically) to keep runtime sane.
        let n = (samples * 8 / (8 + batch)).max(10);

        let (scalar_us, scalar_per) =
            native_cell(&mut net, &states, batch, n, DenseKernel::Scalar, &mut rows);
        let (blocked_us, blocked_per) =
            native_cell(&mut net, &states, batch, n, DenseKernel::Blocked, &mut rows);
        if batch == 1 {
            scalar_b1_us = scalar_per;
        }

        let aot_cell = match aot.as_mut() {
            Some(engine) => {
                let sample = time(3, n, || {
                    for r in 0..batch {
                        opaque(engine.q_values(&states[r * dim..(r + 1) * dim]).unwrap());
                    }
                });
                let per_sample = sample.median_us() / batch as f64;
                rows.push(RooflineRow {
                    engine: "aot",
                    batch,
                    median_us: sample.median_us(),
                    p90_us: sample.p90_us(),
                    per_sample_us: per_sample,
                });
                format!("{per_sample:.2} µs")
            }
            None => "—".into(),
        };

        table.row(vec![
            batch.to_string(),
            format!("{scalar_us:.1} µs"),
            format!("{scalar_per:.2} µs"),
            format!("{blocked_us:.1} µs"),
            format!("{blocked_per:.2} µs"),
            format!("{:.1}x", scalar_b1_us / blocked_per),
            aot_cell,
        ]);
    }

    println!("=== dense-kernel roofline: scalar vs blocked vs AOT ===");
    table.print();
    println!(
        "speedup = per-sample scalar forward at batch 1 (the pre-seam path) / this cell;\n\
         the campaign round's batched greedy selection rides the blocked column.\n\
         kernels are bitwise-identical — see runtime/native/kernels.rs\n"
    );
    if aot.is_none() {
        println!("aot column unavailable: no compiled artifacts / pjrt feature off\n");
    }
    rows
}

/// One measured (mode, jobs) training cell, kept for the JSON report.
struct TrainingRow {
    mode: &'static str,
    jobs: usize,
    batch: usize,
    median_us: f64,
    p90_us: f64,
    per_sample_us: f64,
}

/// Part 0b: sequential vs fused cross-job gradient passes over one
/// shared master. Returns the measured rows for the JSON report.
fn training_roofline(backend: BackendId, samples: usize) -> Vec<TrainingRow> {
    let dim = backend.state_dim();
    let mut init_rng = Rng::new(0);
    let mut net = NativeQNet::with_default_shape(dim, backend.num_actions(), &mut init_rng);
    net.set_kernel(DenseKernel::Blocked);
    let mut trainer = FusedTrainer::new(DenseKernel::Blocked);

    let mut rng = Rng::new(3);
    let (replay, _) = replay_fixture(backend, &mut rng);

    let mut rows: Vec<TrainingRow> = Vec::new();
    let mut table = Table::new(&[
        "jobs",
        "sequential",
        "seq /sample",
        "fused",
        "fused /sample",
        "fused vs seq",
    ]);

    for &jobs in &TRAINING_JOBS {
        let batches: Vec<TrainBatch> =
            (0..jobs).map(|_| replay.sample(TRAINING_BATCH, &mut rng)).collect();
        let refs: Vec<&TrainBatch> = batches.iter().collect();
        let total = (jobs * TRAINING_BATCH) as f64;
        // A gradient pass is ~3x a forward: scale sample counts down
        // (deterministically) to keep runtime sane.
        let n = (samples * 8 / (8 + 3 * jobs)).max(10);

        let seq = time(3, n, || {
            for b in &batches {
                opaque(net.train_grads(b, 0.9).unwrap());
            }
        });
        let seq_per = seq.median_us() / total;
        rows.push(TrainingRow {
            mode: "sequential",
            jobs,
            batch: TRAINING_BATCH,
            median_us: seq.median_us(),
            p90_us: seq.p90_us(),
            per_sample_us: seq_per,
        });

        // Warm the pack + scratch, then pin the no-per-round-allocation
        // contract: steady-state rounds must not grow the footprint.
        opaque(trainer.train_grads(&net.params, &refs, 0.9).unwrap());
        let warm_bytes = trainer.scratch_bytes();
        let fused = time(3, n, || {
            opaque(trainer.train_grads(&net.params, &refs, 0.9).unwrap());
        });
        assert_eq!(
            trainer.scratch_bytes(),
            warm_bytes,
            "fused trainer scratch grew across steady-state rounds (jobs={jobs})"
        );
        let fused_per = fused.median_us() / total;
        rows.push(TrainingRow {
            mode: "fused",
            jobs,
            batch: TRAINING_BATCH,
            median_us: fused.median_us(),
            p90_us: fused.p90_us(),
            per_sample_us: fused_per,
        });

        table.row(vec![
            jobs.to_string(),
            format!("{:.1} µs", seq.median_us()),
            format!("{seq_per:.2} µs"),
            format!("{:.1} µs", fused.median_us()),
            format!("{fused_per:.2} µs"),
            format!("{:.2}x", fused_per / seq_per),
        ]);
    }

    println!("=== training-path roofline: sequential vs fused cross-job grads ===");
    table.print();
    println!(
        "one round's gradient passes, batch {TRAINING_BATCH} per job over one shared master;\n\
         fused stacks every job into one tall GEMM per layer through packed panels.\n\
         per-job results are bitwise-identical — see runtime/native/fused.rs\n"
    );
    rows
}

fn write_json(rows: &[RooflineRow], training: &[TrainingRow], quick: bool) -> anyhow::Result<()> {
    let json = obj(vec![
        ("bench", js("dqn_runtime")),
        ("backend", js("coarrays")),
        ("quick", Json::Bool(quick)),
        (
            "roofline",
            arr(rows.iter().map(|r| {
                obj(vec![
                    ("engine", js(r.engine)),
                    ("batch", num(r.batch as f64)),
                    ("median_us", num(r.median_us)),
                    ("p90_us", num(r.p90_us)),
                    ("per_sample_us", num(r.per_sample_us)),
                ])
            })),
        ),
        (
            "training",
            arr(training.iter().map(|r| {
                obj(vec![
                    ("mode", js(r.mode)),
                    ("jobs", num(r.jobs as f64)),
                    ("batch", num(r.batch as f64)),
                    ("median_us", num(r.median_us)),
                    ("p90_us", num(r.p90_us)),
                    ("per_sample_us", num(r.per_sample_us)),
                ])
            })),
        ),
    ]);
    let path = "BENCH_dqn_runtime.json";
    std::fs::write(path, json.to_string() + "\n")?;
    println!("wrote {path} ({} roofline cells, {} training cells)\n", rows.len(), training.len());
    Ok(())
}

/// Time the AOT engine, or explain why it is unavailable (no artifacts
/// / `pjrt` feature off) — the "AOT-stub" row of the ablation table.
fn aot_row(state: &[f32], batch: &TrainBatch, samples: usize) -> anyhow::Result<Vec<String>> {
    let mut qnet = load_aot(&mut Rng::new(0))?;
    let fwd = time(5, samples, || {
        opaque(qnet.q_values(state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(qnet.train_step(batch, 1e-3, 0.9).unwrap());
    });
    Ok(vec![
        "aot (pjrt)".into(),
        format!("{:.1} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "compiled artifacts, coarrays layout".into(),
    ])
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let json = std::env::args().any(|a| a == "--json");
    let samples = if quick { 20 } else { 100 };
    let backend = BackendId::Coarrays;
    let state = vec![0.3f32; backend.state_dim()];
    let mut rng = Rng::new(1);
    let (replay, batch) = replay_fixture(backend, &mut rng);

    // --- kernel roofline ---
    let roofline_rows = roofline(backend, samples);

    // --- training-path roofline: sequential vs fused ---
    let training_rows = training_roofline(backend, samples);
    if json {
        write_json(&roofline_rows, &training_rows, quick)?;
    }

    // --- engine ablation: native vs tabular vs AOT ---
    let mut t = Table::new(&["engine", "q_forward (batch 1)", "q_train (batch 32)", "notes"]);

    let mut init_rng = Rng::new(0);
    let mut native =
        NativeQNet::with_default_shape(backend.state_dim(), backend.num_actions(), &mut init_rng);
    let fwd = time(5, samples, || {
        opaque(native.q_values(&state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(native.train_step(&batch, 1e-3, 0.9).unwrap());
    });
    t.row(vec![
        "native".into(),
        format!("{:.1} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "pure Rust, any backend, no artifacts".into(),
    ]);

    let mut tabular = TabularAgent::new(backend.num_actions());
    let fwd = time(5, samples, || {
        opaque(tabular.q_values(&state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(tabular.train(&batch, 1e-3, 0.9).unwrap());
    });
    t.row(vec![
        "tabular".into(),
        format!("{:.2} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "discretized Q-table (ablation)".into(),
    ]);

    t.row(aot_row(&state, &batch, samples).unwrap_or_else(|e| {
        vec!["aot (stub)".into(), "—".into(), "—".into(), format!("unavailable: {e}")]
    }));

    println!("=== Q-engine ablation: native vs tabular vs AOT ===");
    t.print();

    // --- tuning-overhead context (L3 + the simulated run) ---
    let mut t = Table::new(&["operation", "median", "p90", "iters"]);
    let tracker = RelativeTracker::new();
    let stats = aituning::mpi_t::PvarStats::default();
    let cv = CvarSet::vanilla();
    let machine = Machine::cheyenne();
    let s = time(10, samples * 10, || {
        opaque(build_state(&stats, &tracker, &cv, &machine, 256, 3, 0.5));
    });
    t.row(vec![
        "build_state (L3)".into(),
        format!("{:.2} µs", s.median_us()),
        format!("{:.2} µs", s.p90_us()),
        s.iters.to_string(),
    ]);

    let s = time(10, samples * 10, || {
        opaque(replay.sample(32, &mut rng));
    });
    t.row(vec![
        "replay sample (32)".into(),
        format!("{:.2} µs", s.median_us()),
        format!("{:.2} µs", s.p90_us()),
        s.iters.to_string(),
    ]);

    let images = if quick { 16 } else { 64 };
    let s = time(1, if quick { 3 } else { 10 }, || {
        opaque(
            run_episode(WorkloadKind::LatticeBoltzmann, images, &machine, &cv, 0.02, 42, 1)
                .unwrap(),
        );
    });
    t.row(vec![
        format!("one simulated LBM run ({images} img)"),
        format!("{:.1} ms", s.median_ms()),
        format!("{:.1} ms", s.p90_ms()),
        s.iters.to_string(),
    ]);

    println!("\n=== tuning-overhead context ===");
    t.print();
    println!("\ntuning overhead per iteration = forward + train + state build");
    Ok(())
}
