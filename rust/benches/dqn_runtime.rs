#![allow(clippy::unwrap_used)] // test/bench code panics by design
//! Q-engine ablation + tuning-overhead microbenchmarks.
//!
//! Part 1 — the engine ablation: forward (action selection) and one
//! replay train step (batch 32) on the native MLP engine, the tabular
//! fallback, and the AOT/PJRT artifact path (reported as unavailable
//! when the `pjrt` feature or the artifacts are absent — the stub row
//! documents exactly what the native engine replaces).
//!
//! Part 2 — §Perf context: state construction, replay sampling, and
//! one simulated application run. Tuning overhead (forward + train +
//! state build) must stay negligible against the run itself.

use aituning::backend::BackendId;
use aituning::coordinator::{
    build_state, run_episode, Agent, RelativeTracker, ReplayBuffer, TabularAgent, Transition,
};
use aituning::mpi_t::CvarSet;
use aituning::runtime::{Manifest, NativeQNet, RuntimeClient, TrainBatch};
use aituning::simmpi::Machine;
use aituning::util::bench::{opaque, time, Table};
use aituning::util::rng::Rng;
use aituning::workloads::WorkloadKind;

/// A 64-transition buffer plus one 32-row minibatch drawn from it —
/// shared by the engine ablation (the batch) and the sampling-overhead
/// timing (the buffer).
fn replay_fixture(backend: BackendId, rng: &mut Rng) -> (ReplayBuffer, TrainBatch) {
    let mut replay = ReplayBuffer::for_backend(
        1024,
        aituning::coordinator::ReplayPolicyKind::Uniform,
        backend,
    );
    for i in 0..64 {
        let mut st = vec![0.0f32; backend.state_dim()];
        st[0] = i as f32 / 64.0;
        replay.push(Transition {
            state: st.clone(),
            action: i % backend.num_actions(),
            reward: 0.1,
            next_state: st,
            done: false,
            workload: None,
        });
    }
    let batch = replay.sample(32, rng);
    (replay, batch)
}

/// Time the AOT engine, or explain why it is unavailable (no artifacts
/// / `pjrt` feature off) — the "AOT-stub" row of the ablation table.
fn aot_row(state: &[f32], batch: &TrainBatch, samples: usize) -> anyhow::Result<Vec<String>> {
    let dir = aituning::runtime::default_artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "artifacts not built");
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(&dir)?;
    let mut qnet = aituning::runtime::AotQNet::load(&client, &manifest, &mut Rng::new(0))?;
    let fwd = time(5, samples, || {
        opaque(qnet.q_values(state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(qnet.train_step(batch, 1e-3, 0.9).unwrap());
    });
    Ok(vec![
        "aot (pjrt)".into(),
        format!("{:.1} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "compiled artifacts, coarrays layout".into(),
    ])
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let samples = if quick { 20 } else { 100 };
    let backend = BackendId::Coarrays;
    let state = vec![0.3f32; backend.state_dim()];
    let mut rng = Rng::new(1);
    let (replay, batch) = replay_fixture(backend, &mut rng);

    // --- engine ablation: native vs tabular vs AOT ---
    let mut t = Table::new(&["engine", "q_forward (batch 1)", "q_train (batch 32)", "notes"]);

    let mut init_rng = Rng::new(0);
    let mut native =
        NativeQNet::with_default_shape(backend.state_dim(), backend.num_actions(), &mut init_rng);
    let fwd = time(5, samples, || {
        opaque(native.q_values(&state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(native.train_step(&batch, 1e-3, 0.9).unwrap());
    });
    t.row(vec![
        "native".into(),
        format!("{:.1} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "pure Rust, any backend, no artifacts".into(),
    ]);

    let mut tabular = TabularAgent::new(backend.num_actions());
    let fwd = time(5, samples, || {
        opaque(tabular.q_values(&state).unwrap());
    });
    let trn = time(3, samples, || {
        opaque(tabular.train(&batch, 1e-3, 0.9).unwrap());
    });
    t.row(vec![
        "tabular".into(),
        format!("{:.2} µs", fwd.median_us()),
        format!("{:.1} µs", trn.median_us()),
        "discretized Q-table (ablation)".into(),
    ]);

    t.row(aot_row(&state, &batch, samples).unwrap_or_else(|e| {
        vec!["aot (stub)".into(), "—".into(), "—".into(), format!("unavailable: {e}")]
    }));

    println!("=== Q-engine ablation: native vs tabular vs AOT ===");
    t.print();

    // --- tuning-overhead context (L3 + the simulated run) ---
    let mut t = Table::new(&["operation", "median", "p90", "iters"]);
    let tracker = RelativeTracker::new();
    let stats = aituning::mpi_t::PvarStats::default();
    let cv = CvarSet::vanilla();
    let machine = Machine::cheyenne();
    let s = time(10, samples * 10, || {
        opaque(build_state(&stats, &tracker, &cv, &machine, 256, 3, 0.5));
    });
    t.row(vec![
        "build_state (L3)".into(),
        format!("{:.2} µs", s.median_us()),
        format!("{:.2} µs", s.p90_ns / 1e3),
        s.iters.to_string(),
    ]);

    let s = time(10, samples * 10, || {
        opaque(replay.sample(32, &mut rng));
    });
    t.row(vec![
        "replay sample (32)".into(),
        format!("{:.2} µs", s.median_us()),
        format!("{:.2} µs", s.p90_ns / 1e3),
        s.iters.to_string(),
    ]);

    let images = if quick { 16 } else { 64 };
    let s = time(1, if quick { 3 } else { 10 }, || {
        opaque(
            run_episode(WorkloadKind::LatticeBoltzmann, images, &machine, &cv, 0.02, 42, 1)
                .unwrap(),
        );
    });
    t.row(vec![
        format!("one simulated LBM run ({images} img)"),
        format!("{:.1} ms", s.median_ms()),
        format!("{:.1} ms", s.p90_ns / 1e6),
        s.iters.to_string(),
    ]);

    println!("\n=== tuning-overhead context ===");
    t.print();
    println!("\ntuning overhead per iteration = forward + train + state build");
    Ok(())
}
