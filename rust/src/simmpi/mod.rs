//! `simmpi` — a discrete-event simulator of an MPI-3 run-time.
//!
//! The paper tunes MPICH-3.2.1 on real supercomputers (Cheyenne/SGI with
//! InfiniBand, Edison/Cray XC30 with Aries). We have neither, so this
//! module implements the *mechanisms its control variables govern* as a
//! process-oriented discrete-event simulation:
//!
//! * **eager vs rendezvous** point-to-point/RMA protocol with the
//!   `CH3_EAGER_MAX_MSG_SIZE` threshold, including the unexpected-message
//!   queue that eager messages land in when the target has not entered
//!   the progress engine ([`protocol`], [`process`]);
//! * **passive-target RMA**: puts/gets with remote completion at
//!   `MPI_Win_flush`, lock piggybacking
//!   (`CH3_RMA_DELAY_ISSUING_FOR_PIGGYBACKING`,
//!   `CH3_RMA_OP_PIGGYBACK_LOCK_DATA_SIZE`);
//! * **asynchronous progress** (`ASYNC_PROGRESS`): a helper thread that
//!   services incoming RMA traffic while the target computes, at a
//!   compute-rate tax ([`polling`]);
//! * **poll/yield** behaviour of blocking waits (`POLLS_BEFORE_YIELD`):
//!   how long a blocked rank busy-polls before yielding the core, which
//!   sets both its own wakeup latency and its responsiveness to peers
//!   ([`polling`]);
//! * **collectives** with plain vs hierarchical algorithms
//!   (`CH3_ENABLE_HCOLL`, [`collective`]);
//! * **network models** for an InfiniBand and an Aries fabric with
//!   scale-dependent contention ([`network`], [`config`]).
//!
//! The RL agent only ever observes end-of-run performance-variable
//! statistics as a function of (cvars × workload × images); the
//! simulator's job is to preserve the *shape* of that landscape — who
//! wins, which knob matters for which pattern, where crossovers fall —
//! not absolute wall-clock numbers (DESIGN.md, substitution table).

pub mod collective;
pub mod config;
pub mod engine;
pub mod network;
pub mod polling;
pub mod process;
pub mod protocol;
pub mod stats;

pub use config::{Machine, SimConfig};
pub use engine::Engine;
pub use process::{Op, Program};
pub use stats::RunStats;
