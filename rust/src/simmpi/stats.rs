//! Per-run measurements collected by the simulator, feeding the MPI_T
//! performance variables.

use crate::metrics::stats::Summary;

/// Raw observations from one simulated run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// Wall-clock of the whole run (max image finish time), µs.
    pub total_time_us: f64,
    /// Per-flush durations (origin-side), µs.
    pub flush_times: Vec<f64>,
    /// Per-put origin-side issue→local-completion durations, µs.
    pub put_times: Vec<f64>,
    /// Per-get origin-side blocking durations, µs.
    pub get_times: Vec<f64>,
    /// Unexpected-message-queue length samples (at eager arrivals).
    pub umq_samples: Vec<f64>,
    /// Counters.
    pub eager_msgs: u64,
    pub rendezvous_msgs: u64,
    pub piggybacked_ops: u64,
    pub bytes_sent: u64,
    pub yields: u64,
    pub events_processed: u64,
    pub collectives: u64,
}

impl RunStats {
    pub fn flush_summary(&self) -> Summary {
        Summary::of(&self.flush_times)
    }

    pub fn put_summary(&self) -> Summary {
        Summary::of(&self.put_times)
    }

    pub fn get_summary(&self) -> Summary {
        Summary::of(&self.get_times)
    }

    pub fn umq_summary(&self) -> Summary {
        Summary::of(&self.umq_samples)
    }

    /// Fraction of point-to-point traffic that went eager.
    pub fn eager_fraction(&self) -> f64 {
        let total = self.eager_msgs + self.rendezvous_msgs;
        if total == 0 {
            0.0
        } else {
            self.eager_msgs as f64 / total as f64
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn eager_fraction_handles_empty() {
        let s = RunStats::default();
        assert_eq!(s.eager_fraction(), 0.0);
    }

    #[test]
    fn summaries_reflect_samples() {
        let mut s = RunStats::default();
        s.flush_times = vec![2.0, 4.0];
        assert_eq!(s.flush_summary().mean, 3.0);
        s.eager_msgs = 3;
        s.rendezvous_msgs = 1;
        assert_eq!(s.eager_fraction(), 0.75);
    }
}
