//! Simulated processes (images) and their operation programs.

/// One operation in an image's program. Times in µs, sizes in bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Local computation for `us` microseconds (subject to noise and the
    /// async-progress compute tax).
    Compute { us: f64 },
    /// One-sided put to `target`'s window (remote completion at flush).
    Put { target: usize, bytes: u64 },
    /// One-sided get from `source` (blocks until data arrives, like
    /// LIBCAF_MPI's get + immediate flush).
    Get { source: usize, bytes: u64 },
    /// `MPI_Win_flush(target)`: wait for remote completion of all
    /// outstanding ops to `target`.
    Flush { target: usize },
    /// `MPI_Win_flush_all`.
    FlushAll,
    /// `sync all`: flush_all + barrier over all images.
    SyncAll,
    /// Post a fine-grain event to `target` (Fortran 2018 events).
    EventPost { target: usize },
    /// Wait until `count` events have been posted to this image.
    EventWait { count: u32 },
    /// `co_sum`-style allreduce of `bytes` per image.
    CoSum { bytes: u64 },
    /// `co_broadcast` of `bytes` from image 1.
    CoBroadcast { bytes: u64 },
    /// Team-scoped barrier (Fortran 2018 teams, `sync team`).
    /// `team` identifies the group; `size` is its member count.
    TeamBarrier { team: u32, size: u32 },
    /// Team-scoped allreduce (`co_sum` inside `change team`).
    TeamCoSum { team: u32, size: u32, bytes: u64 },
}

/// An image's full program.
pub type Program = Vec<Op>;

/// What a process is currently blocked on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Waiting {
    /// Executing ops / computing; not blocked.
    None,
    /// In `Flush{target}` until per-target outstanding hits zero.
    Flush { target: usize },
    /// In `FlushAll` until total outstanding hits zero. `then_barrier`
    /// distinguishes `sync all` (proceeds into the barrier).
    FlushAll { then_barrier: bool },
    /// In the barrier, waiting for everyone.
    Barrier,
    /// Waiting for `still_needed` more event posts.
    Event { still_needed: u32 },
    /// Waiting for get data to come back.
    GetData,
    /// In a collective, waiting for completion.
    Collective,
    /// Program exhausted.
    Finished,
}

/// A message parked at a target that has not yet serviced it.
#[derive(Debug, Clone, Copy)]
pub struct Parked {
    pub kind: ParkedKind,
    pub origin: usize,
    pub bytes: u64,
    pub arrived_us: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ParkedKind {
    /// Eager payload waiting to be copied out of the unexpected queue.
    EagerData { put_seq: u64 },
    /// Rendezvous RTS waiting for a CTS reply.
    Rts { put_seq: u64 },
    /// Get request waiting to be served.
    GetReq,
    /// Event post waiting to be accounted.
    EventPost,
}

/// Per-process simulation state.
#[derive(Debug)]
pub struct Proc {
    pub program: Program,
    pub pc: usize,
    pub waiting: Waiting,
    /// When the current blocking wait began (valid while blocked).
    pub block_start_us: f64,
    /// Outstanding (not yet remotely complete) puts per target.
    /// Workloads talk to a handful of peers, so a small sorted-free
    /// vec beats a HashMap on the put/complete hot path.
    pub outstanding_by_target: Vec<(usize, u32)>,
    pub outstanding_total: u32,
    /// Messages awaiting this process's progress engine.
    pub parked: Vec<Parked>,
    /// Unexpected-queue length high-water bookkeeping.
    pub umq_len: usize,
    /// Event counter (Fortran events posted to me, not yet consumed).
    pub events_pending: u32,
    /// Puts delayed for piggybacking, flushed on the next flush/sync:
    /// (target, bytes).
    pub delayed_puts: Vec<(usize, u64)>,
    /// This process is finished executing.
    pub finish_time_us: f64,
}

impl Proc {
    pub fn new(program: Program) -> Proc {
        Proc {
            program,
            pc: 0,
            waiting: Waiting::None,
            block_start_us: 0.0,
            outstanding_by_target: Vec::new(),
            outstanding_total: 0,
            parked: Vec::new(),
            umq_len: 0,
            events_pending: 0,
            delayed_puts: Vec::new(),
            finish_time_us: 0.0,
        }
    }

    pub fn outstanding_to(&self, target: usize) -> u32 {
        self.outstanding_by_target
            .iter()
            .find(|(t, _)| *t == target)
            .map(|(_, n)| *n)
            .unwrap_or(0)
    }

    pub fn add_outstanding(&mut self, target: usize) {
        match self.outstanding_by_target.iter_mut().find(|(t, _)| *t == target) {
            Some((_, n)) => *n += 1,
            None => self.outstanding_by_target.push((target, 1)),
        }
        self.outstanding_total += 1;
    }

    pub fn complete_outstanding(&mut self, target: usize) {
        let e = self
            .outstanding_by_target
            .iter_mut()
            .find(|(t, _)| *t == target)
            .map(|(_, n)| n)
            // detlint: allow(R4) -- simulator invariant: a completion without a matching add is a simulator bug, and this hot-path method has no error channel
            .expect("completion for unknown target");
        assert!(*e > 0, "outstanding underflow");
        *e -= 1;
        self.outstanding_total -= 1;
    }

    /// Is this process currently blocked inside the MPI progress engine
    /// (and therefore able to service incoming messages)?
    pub fn in_mpi(&self) -> bool {
        !matches!(self.waiting, Waiting::None | Waiting::Finished)
    }

    pub fn finished(&self) -> bool {
        matches!(self.waiting, Waiting::Finished)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn outstanding_bookkeeping() {
        let mut p = Proc::new(vec![]);
        p.add_outstanding(3);
        p.add_outstanding(3);
        p.add_outstanding(7);
        assert_eq!(p.outstanding_to(3), 2);
        assert_eq!(p.outstanding_total, 3);
        p.complete_outstanding(3);
        assert_eq!(p.outstanding_to(3), 1);
        assert_eq!(p.outstanding_total, 2);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn completion_underflow_panics() {
        let mut p = Proc::new(vec![]);
        p.add_outstanding(1);
        p.complete_outstanding(1);
        p.complete_outstanding(1);
    }

    #[test]
    fn in_mpi_only_when_blocked() {
        let mut p = Proc::new(vec![]);
        assert!(!p.in_mpi());
        p.waiting = Waiting::Flush { target: 0 };
        assert!(p.in_mpi());
        p.waiting = Waiting::Finished;
        assert!(!p.in_mpi());
        assert!(p.finished());
    }
}
