//! Network timing: message transfer costs under contention.

use super::config::SimConfig;

/// One-way transfer time for `bytes` on the wire (latency + serialized
/// bytes under the run's contention factor).
pub fn transfer_us(cfg: &SimConfig, bytes: u64) -> f64 {
    cfg.machine.latency_us + bytes as f64 / effective_bandwidth(cfg)
}

/// Bandwidth after scale-dependent contention.
pub fn effective_bandwidth(cfg: &SimConfig) -> f64 {
    cfg.machine.bandwidth_bpus / cfg.contention_factor()
}

/// Sender-side cost to hand one message to the NIC.
pub fn send_overhead_us(cfg: &SimConfig) -> f64 {
    cfg.machine.per_msg_overhead_us
}

/// Local memcpy time (eager copies in/out of comm buffers).
pub fn memcpy_us(cfg: &SimConfig, bytes: u64) -> f64 {
    bytes as f64 / cfg.machine.memcpy_bpus
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::CvarSet;
    use crate::simmpi::config::Machine;

    fn cfg(images: usize) -> SimConfig {
        SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), images)
    }

    #[test]
    fn latency_floor() {
        let c = cfg(64);
        assert!((transfer_us(&c, 0) - c.machine.latency_us).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_term_linear_in_bytes() {
        let c = cfg(64);
        let t1 = transfer_us(&c, 1 << 20);
        let t2 = transfer_us(&c, 2 << 20);
        let lat = c.machine.latency_us;
        assert!(((t2 - lat) / (t1 - lat) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn contention_slows_transfers_at_scale() {
        let small = transfer_us(&cfg(64), 1 << 20);
        let large = transfer_us(&cfg(2048), 1 << 20);
        assert!(large > small * 1.3, "small={small} large={large}");
    }

    #[test]
    fn memcpy_faster_than_network() {
        let c = cfg(64);
        assert!(memcpy_us(&c, 1 << 20) < transfer_us(&c, 1 << 20));
    }
}
