//! Point-to-point / RMA transfer protocol selection and cost pieces.
//!
//! **Eager** (`bytes <= CH3_EAGER_MAX_MSG_SIZE`): the sender pushes
//! header+payload immediately — one trip, but the payload is copied
//! through comm buffers on both ends, and if the target is not making
//! progress it parks in the unexpected-message queue.
//!
//! **Rendezvous** (`bytes > threshold`): RTS → (target service) → CTS →
//! zero-copy RDMA transfer. No copies and no unexpected-queue memory,
//! but the handshake needs the *target* to progress, and adds a round
//! trip.
//!
//! **Lock piggybacking**: passive-target RMA epochs open with a lock
//! message. Ops no larger than `CH3_RMA_OP_PIGGYBACK_LOCK_DATA_SIZE`
//! can ride the lock packet (saving the lock trip); with
//! `CH3_RMA_DELAY_ISSUING_FOR_PIGGYBACKING=1` small ops are further
//! delayed and batched onto the next flush.

use super::config::SimConfig;
use super::network;

/// Which protocol a message of `bytes` uses under the current cvars.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    Eager,
    Rendezvous,
}

pub fn select(cfg: &SimConfig, bytes: u64) -> Protocol {
    if bytes as i64 <= cfg.cvars.eager_max() {
        Protocol::Eager
    } else {
        Protocol::Rendezvous
    }
}

/// Does this op qualify for lock piggybacking (saves the lock trip)?
pub fn piggybacks(cfg: &SimConfig, bytes: u64) -> bool {
    bytes as i64 <= cfg.cvars.piggyback_size()
}

/// Is this op's issuing delayed to batch with the next flush?
pub fn delayed_for_piggyback(cfg: &SimConfig, bytes: u64) -> bool {
    cfg.cvars.delay_piggyback() && piggybacks(cfg, bytes)
}

/// Origin-side CPU time to issue a put of `bytes` (before any network
/// flight). Eager pays the buffer copy; rendezvous only posts an RTS.
pub fn put_issue_cost_us(cfg: &SimConfig, bytes: u64, proto: Protocol) -> f64 {
    let lock = if piggybacks(cfg, bytes) { 0.0 } else { cfg.machine.lock_overhead_us };
    match proto {
        Protocol::Eager => {
            network::send_overhead_us(cfg) + network::memcpy_us(cfg, bytes) + lock
        }
        Protocol::Rendezvous => network::send_overhead_us(cfg) + lock,
    }
}

/// Target-side CPU time to apply an eager payload (copy out of the
/// comm buffer into the window).
pub fn eager_apply_cost_us(cfg: &SimConfig, bytes: u64) -> f64 {
    network::memcpy_us(cfg, bytes)
}

/// Wire time of the eager message (header + payload in one trip).
pub fn eager_wire_us(cfg: &SimConfig, bytes: u64) -> f64 {
    network::transfer_us(cfg, bytes)
}

/// Wire time of the rendezvous RTS/CTS control messages.
pub fn control_wire_us(cfg: &SimConfig) -> f64 {
    network::transfer_us(cfg, 64)
}

/// Wire time of the rendezvous bulk data (zero-copy RDMA).
pub fn rendezvous_data_us(cfg: &SimConfig, bytes: u64) -> f64 {
    network::transfer_us(cfg, bytes)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::config::Machine;

    fn cfg(eager_max: i64) -> SimConfig {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(5), eager_max);
        SimConfig::new(Machine::cheyenne(), cv, 64)
    }

    #[test]
    fn threshold_selects_protocol() {
        let c = cfg(131_072);
        assert_eq!(select(&c, 131_072), Protocol::Eager);
        assert_eq!(select(&c, 131_073), Protocol::Rendezvous);
    }

    #[test]
    fn raising_threshold_converts_to_eager() {
        // The paper's human tuning: eager limit ×10 turns ICAR's halos eager.
        let halo = 300_000u64;
        assert_eq!(select(&cfg(131_072), halo), Protocol::Rendezvous);
        assert_eq!(select(&cfg(1_310_720), halo), Protocol::Eager);
    }

    #[test]
    fn eager_issue_costs_more_cpu_than_rendezvous() {
        let c = cfg(1 << 22);
        let big = 1 << 20;
        assert!(
            put_issue_cost_us(&c, big, Protocol::Eager)
                > put_issue_cost_us(&c, big, Protocol::Rendezvous)
        );
    }

    #[test]
    fn piggyback_saves_lock_overhead() {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(3), 4096); // piggyback threshold
        let c = SimConfig::new(Machine::cheyenne(), cv, 64);
        let small = put_issue_cost_us(&c, 1024, Protocol::Eager);
        let over = put_issue_cost_us(&c, 8192, Protocol::Eager);
        // 8 KiB op pays the lock; the 1 KiB op piggybacks it away.
        let memcpy_delta = network::memcpy_us(&c, 8192) - network::memcpy_us(&c, 1024);
        assert!(over - small > memcpy_delta + 0.9 * c.machine.lock_overhead_us);
    }

    #[test]
    fn delay_requires_both_cvar_and_size() {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(2), 1);
        cv.set(CvarId(3), 65_536);
        let c = SimConfig::new(Machine::cheyenne(), cv, 64);
        assert!(delayed_for_piggyback(&c, 1024));
        assert!(!delayed_for_piggyback(&c, 100_000));
        let c2 = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 64);
        assert!(!delayed_for_piggyback(&c2, 1024));
    }
}
