//! Poll/yield cost model (`MPIR_CVAR_POLLS_BEFORE_YIELD`) and the
//! progress rules that decide *when a target CPU services a message*.
//!
//! A blocked MPI rank busy-polls the progress engine; after `k` polls
//! without completion it yields the core and is woken by the scheduler.
//! Three consequences, all modeled here:
//!
//! 1. **Own wakeup latency** — if the awaited completion lands after the
//!    rank has yielded, completion detection costs a scheduler wakeup.
//! 2. **Responsiveness to peers** — an incoming RTS/eager message that
//!    arrives while the rank is still busy-polling is serviced at poll
//!    speed; after the yield it costs a wakeup first. Longer polling
//!    keeps a rank responsive to its *partners* — the effect that grows
//!    with image count and drives the paper's §6.2 observation.
//! 3. **Progress-thread starvation** — with `ASYNC_PROGRESS=1` the main
//!    thread's busy-poll competes with the helper thread, so service
//!    latency creeps up with the poll budget.

use super::config::SimConfig;

/// Time a rank spends busy-polling before it yields.
pub fn poll_window_us(cfg: &SimConfig) -> f64 {
    cfg.cvars.polls_before_yield() as f64 * cfg.machine.poll_cost_us
}

/// Extra time added to a blocking wait of true duration `wait_us`
/// (completion-detection overhead).
pub fn wait_overhead_us(cfg: &SimConfig, wait_us: f64) -> f64 {
    let window = poll_window_us(cfg);
    if wait_us <= window {
        // Completion detected while still polling: within one poll.
        cfg.machine.poll_cost_us
    } else {
        // Already yielded: pay a scheduler wakeup. Repeated sleep/wake
        // cycles add a slowly growing term for very long waits.
        let over = (wait_us - window) / cfg.machine.yield_wakeup_us.max(1e-9);
        cfg.machine.yield_wakeup_us * (1.0 + 0.25 * (1.0 + over).ln())
    }
}

/// Delay before a *blocked* rank services an incoming message that
/// arrived `since_block_us` after it blocked.
pub fn blocked_service_delay_us(cfg: &SimConfig, since_block_us: f64) -> f64 {
    if since_block_us <= poll_window_us(cfg) {
        cfg.machine.mpi_service_us
    } else {
        cfg.machine.yield_wakeup_us + cfg.machine.mpi_service_us
    }
}

/// Service delay through the asynchronous progress thread (only valid
/// when `ASYNC_PROGRESS=1`). The main thread's poll budget starves the
/// helper slightly.
pub fn async_service_delay_us(cfg: &SimConfig) -> f64 {
    let starve = cfg.cvars.polls_before_yield() as f64
        * cfg.machine.poll_cost_us
        * cfg.machine.poll_starve_coeff;
    cfg.machine.async_service_us + starve
}

/// Compute-time multiplier while the async progress thread is enabled.
pub fn compute_tax_factor(cfg: &SimConfig) -> f64 {
    if cfg.cvars.async_progress() {
        1.0 + cfg.machine.async_compute_tax
    } else {
        1.0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::config::Machine;

    fn cfg_with_polls(polls: i64) -> SimConfig {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(4), polls);
        SimConfig::new(Machine::cheyenne(), cv, 256)
    }

    #[test]
    fn short_wait_costs_one_poll() {
        let cfg = cfg_with_polls(1000);
        let w = poll_window_us(&cfg);
        assert_eq!(wait_overhead_us(&cfg, w * 0.5), cfg.machine.poll_cost_us);
    }

    #[test]
    fn long_wait_pays_wakeup() {
        let cfg = cfg_with_polls(100);
        let w = poll_window_us(&cfg);
        let overhead = wait_overhead_us(&cfg, w * 50.0);
        assert!(overhead >= cfg.machine.yield_wakeup_us);
    }

    #[test]
    fn bigger_poll_budget_covers_longer_waits() {
        // A wait of 150µs: k=500 (60µs window) yields; k=2000 (240µs) polls through.
        let wait = 150.0;
        let small = wait_overhead_us(&cfg_with_polls(500), wait);
        let large = wait_overhead_us(&cfg_with_polls(2000), wait);
        assert!(large < small, "large={large} small={small}");
    }

    #[test]
    fn service_delay_jumps_after_window() {
        let cfg = cfg_with_polls(1000);
        let w = poll_window_us(&cfg);
        let fast = blocked_service_delay_us(&cfg, w * 0.9);
        let slow = blocked_service_delay_us(&cfg, w * 1.1);
        assert!(slow > fast + cfg.machine.yield_wakeup_us * 0.9);
    }

    #[test]
    fn async_starvation_grows_with_polls() {
        let a = async_service_delay_us(&cfg_with_polls(0));
        let b = async_service_delay_us(&cfg_with_polls(100_000));
        assert!(b > a);
    }

    #[test]
    fn compute_tax_only_with_async() {
        let mut cv = CvarSet::vanilla();
        let off = SimConfig::new(Machine::cheyenne(), cv.clone(), 64);
        assert_eq!(compute_tax_factor(&off), 1.0);
        cv.set(CvarId(0), 1);
        let on = SimConfig::new(Machine::cheyenne(), cv, 64);
        assert!(compute_tax_factor(&on) > 1.0);
    }
}
