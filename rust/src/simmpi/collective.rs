//! Collective operation timing: plain binomial algorithms vs the
//! hierarchical "HCOLL" family toggled by `CH3_ENABLE_HCOLL`.
//!
//! Plain algorithms pay `2·log2(p)` network rounds for an allreduce and
//! are oblivious to node topology. HCOLL exploits the intra-node tree
//! (cheap shared-memory stage + one inter-node stage per round), cutting
//! the effective round count — at the cost of a per-call setup. Small
//! jobs with few nodes may lose; big collective-heavy jobs win.

use super::config::SimConfig;
use super::network;

/// Time for a barrier (dissemination, log2(p) rounds).
pub fn barrier_us(cfg: &SimConfig, p: usize) -> f64 {
    let rounds = (p.max(2) as f64).log2().ceil();
    rounds * (network::transfer_us(cfg, 64) + cfg.machine.mpi_service_us)
}

/// Time for an allreduce (`co_sum`) of `bytes` across `p` images.
pub fn allreduce_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    let per_round = network::transfer_us(cfg, bytes) + cfg.machine.mpi_service_us;
    if cfg.cvars.enable_hcoll() {
        // Hierarchical: intra-node reduce (memcpy-speed) + inter-node
        // rounds over node leaders only.
        let nodes = cfg.nodes().max(1);
        let intra = network::memcpy_us(cfg, bytes) * 2.0
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil()
                * cfg.machine.mpi_service_us;
        let inter = (nodes.max(2) as f64).log2().ceil() * per_round;
        cfg.machine.hcoll_setup_us + intra + inter
    } else {
        // Recursive doubling: 2·log2(p) rounds end-to-end.
        2.0 * (p.max(2) as f64).log2().ceil() * per_round
    }
}

/// Time for a broadcast of `bytes` across `p` images.
pub fn broadcast_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    let per_round = network::transfer_us(cfg, bytes) + cfg.machine.mpi_service_us;
    if cfg.cvars.enable_hcoll() {
        let nodes = cfg.nodes().max(1);
        let intra = network::memcpy_us(cfg, bytes)
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil() * 0.2;
        let inter = (nodes.max(2) as f64).log2().ceil() * per_round;
        cfg.machine.hcoll_setup_us + intra + inter
    } else {
        (p.max(2) as f64).log2().ceil() * per_round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::config::Machine;

    fn cfg(images: usize, hcoll: bool) -> SimConfig {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(1), i64::from(hcoll));
        SimConfig::new(Machine::cheyenne(), cv, images)
    }

    #[test]
    fn barrier_scales_logarithmically() {
        let c = cfg(64, false);
        let b64 = barrier_us(&c, 64);
        let c1024 = cfg(1024, false);
        let b1024 = barrier_us(&c1024, 1024);
        assert!(b1024 > b64);
        assert!(b1024 < b64 * 4.0, "should be log-ish: {b64} vs {b1024}");
    }

    #[test]
    fn hcoll_wins_at_scale() {
        // 1024 images over 29 nodes: hierarchical allreduce beats flat.
        let plain = allreduce_us(&cfg(1024, false), 1024, 8192);
        let hcoll = allreduce_us(&cfg(1024, true), 1024, 8192);
        assert!(hcoll < plain, "hcoll={hcoll} plain={plain}");
    }

    #[test]
    fn hcoll_setup_can_lose_on_tiny_jobs() {
        // 2 images on one node: plain recursive doubling is one round.
        let plain = allreduce_us(&cfg(2, false), 2, 64);
        let hcoll = allreduce_us(&cfg(2, true), 2, 64);
        assert!(hcoll > plain, "hcoll={hcoll} plain={plain}");
    }

    #[test]
    fn broadcast_cheaper_than_allreduce() {
        let c = cfg(512, false);
        assert!(broadcast_us(&c, 512, 4096) < allreduce_us(&c, 512, 4096));
    }
}
