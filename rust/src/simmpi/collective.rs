//! Collective operation timing.
//!
//! Two layers live here:
//!
//! * The **engine-facing** costs the coarray simulator charges for
//!   `co_sum` / `co_broadcast` / barriers: plain binomial /
//!   recursive-doubling algorithms vs the hierarchical "HCOLL" family
//!   toggled by `CH3_ENABLE_HCOLL`. Plain algorithms pay `2·log2(p)`
//!   network rounds for an allreduce and are oblivious to node
//!   topology; HCOLL exploits the intra-node tree — at the cost of a
//!   per-call setup. Small jobs with few nodes may lose; big
//!   collective-heavy jobs win.
//! * The **algorithm-parameterized** costs the collectives backend
//!   tunes over ([`bcast_alg_us`], [`allreduce_alg_us`]): the
//!   selectors studied by Hunold & Carpen-Amarie's performance
//!   guidelines (binomial vs scatter+allgather broadcast,
//!   recursive-doubling vs ring allreduce, pipeline segmenting).
//!   These functions never read `cfg.cvars` — the algorithm arrives
//!   explicitly — so they work for any backend's configuration. Ring
//!   phases exchange with fixed nearest neighbours, which dodges the
//!   scale-dependent fabric contention the doubling patterns pay
//!   ([`network::effective_bandwidth`]); that is what makes the
//!   selection scale- and size-sensitive rather than dominated by one
//!   algorithm everywhere.

use super::config::SimConfig;
use super::network;

/// Broadcast algorithm selector (the collectives backend's
/// `MPIR_CVAR_BCAST_INTRA_ALGORITHM`; see
/// [`crate::mpi_t::BCAST_ALGORITHMS`] for the value order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BcastAlgorithm {
    /// Binomial tree, optionally segmented/pipelined.
    Binomial,
    /// Scatter + recursive-doubling allgather.
    ScatterAllgather,
    /// Scatter + ring allgather (nearest-neighbour, contention-free).
    ScatterRingAllgather,
}

impl BcastAlgorithm {
    /// Decode a cvar value (clamped upstream by the Choice domain).
    pub fn from_cvar(v: i64) -> BcastAlgorithm {
        match v {
            0 => BcastAlgorithm::Binomial,
            1 => BcastAlgorithm::ScatterAllgather,
            _ => BcastAlgorithm::ScatterRingAllgather,
        }
    }
}

/// Allreduce algorithm selector
/// (`MPIR_CVAR_ALLREDUCE_INTRA_ALGORITHM`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllreduceAlgorithm {
    /// Recursive doubling: log-rounds of full-size exchanges.
    RecursiveDoubling,
    /// Reduce-scatter + allgather over a ring of neighbours.
    Ring,
}

impl AllreduceAlgorithm {
    pub fn from_cvar(v: i64) -> AllreduceAlgorithm {
        match v {
            0 => AllreduceAlgorithm::RecursiveDoubling,
            _ => AllreduceAlgorithm::Ring,
        }
    }
}

fn log2_rounds(p: usize) -> f64 {
    (p.max(2) as f64).log2().ceil()
}

fn per_round(cfg: &SimConfig, bytes: u64) -> f64 {
    network::transfer_us(cfg, bytes) + cfg.machine.mpi_service_us
}

/// Time for a barrier (dissemination, log2(p) rounds).
pub fn barrier_us(cfg: &SimConfig, p: usize) -> f64 {
    log2_rounds(p) * per_round(cfg, 64)
}

/// Recursive-doubling allreduce: 2·log2(p) rounds of full-size
/// exchanges end-to-end (the engine's plain `co_sum` cost).
pub fn allreduce_recursive_doubling_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    2.0 * log2_rounds(p) * per_round(cfg, bytes)
}

/// Ring allreduce (reduce-scatter + allgather): 2·(p−1) rounds of
/// `bytes/p` chunks between fixed neighbours. Pays many latencies but
/// moves only ~2·bytes per rank over *uncontended* neighbour links —
/// the large-message/large-scale winner.
pub fn allreduce_ring_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    let p = p.max(2);
    let chunk = (bytes as f64 / p as f64).max(1.0);
    let rounds = 2.0 * (p - 1) as f64;
    rounds * (cfg.machine.latency_us + chunk / cfg.machine.bandwidth_bpus)
        + rounds * cfg.machine.mpi_service_us
}

/// Algorithm-parameterized allreduce (the collectives backend's cost).
pub fn allreduce_alg_us(
    cfg: &SimConfig,
    p: usize,
    bytes: u64,
    alg: AllreduceAlgorithm,
    smp: bool,
) -> f64 {
    let flat = |p: usize| match alg {
        AllreduceAlgorithm::RecursiveDoubling => allreduce_recursive_doubling_us(cfg, p, bytes),
        AllreduceAlgorithm::Ring => allreduce_ring_us(cfg, p, bytes),
    };
    if smp {
        // Hierarchical: intra-node reduce at memcpy speed, then the
        // selected algorithm across node leaders only.
        let nodes = cfg.nodes().max(2);
        let intra = network::memcpy_us(cfg, bytes) * 2.0
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil()
                * cfg.machine.mpi_service_us;
        cfg.machine.hcoll_setup_us + intra + flat(nodes)
    } else {
        flat(p)
    }
}

/// Segmented binomial-tree broadcast: `log2(p)` tree levels pipelined
/// over `ceil(bytes / segment)` segments — the classic
/// `(rounds + segments − 1) · per_segment` pipeline. An unsegmented
/// call (`segment >= bytes`) degenerates to the engine's plain cost.
pub fn bcast_binomial_us(cfg: &SimConfig, p: usize, bytes: u64, segment: u64) -> f64 {
    let rounds = log2_rounds(p);
    if segment >= bytes.max(1) {
        return rounds * per_round(cfg, bytes);
    }
    let seg = segment.max(1);
    let segments = bytes.div_ceil(seg) as f64;
    (rounds + segments - 1.0) * per_round(cfg, seg)
}

/// Scatter + allgather broadcast. The scatter phase (log2(p) rounds,
/// halving payloads) moves `bytes·(p−1)/p` through the contended
/// fabric; the allgather phase reassembles either by recursive
/// doubling (contended) or over the neighbour ring (uncontended).
pub fn bcast_scatter_allgather_us(
    cfg: &SimConfig,
    p: usize,
    bytes: u64,
    ring_allgather: bool,
) -> f64 {
    let p = p.max(2);
    let l = log2_rounds(p);
    let moved = bytes as f64 * (p - 1) as f64 / p as f64;
    let scatter = l * (cfg.machine.latency_us + cfg.machine.mpi_service_us)
        + moved / network::effective_bandwidth(cfg);
    let allgather = if ring_allgather {
        (p - 1) as f64 * (cfg.machine.latency_us + cfg.machine.mpi_service_us)
            + moved / cfg.machine.bandwidth_bpus
    } else {
        l * (cfg.machine.latency_us + cfg.machine.mpi_service_us)
            + moved / network::effective_bandwidth(cfg)
    };
    scatter + allgather
}

/// Algorithm-parameterized broadcast (the collectives backend's cost).
pub fn bcast_alg_us(
    cfg: &SimConfig,
    p: usize,
    bytes: u64,
    alg: BcastAlgorithm,
    segment: u64,
    smp: bool,
) -> f64 {
    let flat = |p: usize| match alg {
        BcastAlgorithm::Binomial => bcast_binomial_us(cfg, p, bytes, segment),
        BcastAlgorithm::ScatterAllgather => bcast_scatter_allgather_us(cfg, p, bytes, false),
        BcastAlgorithm::ScatterRingAllgather => bcast_scatter_allgather_us(cfg, p, bytes, true),
    };
    if smp {
        let nodes = cfg.nodes().max(2);
        let intra = network::memcpy_us(cfg, bytes)
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil() * 0.2;
        cfg.machine.hcoll_setup_us + intra + flat(nodes)
    } else {
        flat(p)
    }
}

/// Time for an allreduce (`co_sum`) of `bytes` across `p` images — the
/// coarray engine's cost, steered by `CH3_ENABLE_HCOLL`.
pub fn allreduce_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    if cfg.cvars.enable_hcoll() {
        // Hierarchical: intra-node reduce (memcpy-speed) + inter-node
        // rounds over node leaders only.
        let nodes = cfg.nodes().max(1);
        let intra = network::memcpy_us(cfg, bytes) * 2.0
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil()
                * cfg.machine.mpi_service_us;
        let inter = (nodes.max(2) as f64).log2().ceil() * per_round(cfg, bytes);
        cfg.machine.hcoll_setup_us + intra + inter
    } else {
        allreduce_recursive_doubling_us(cfg, p, bytes)
    }
}

/// Time for a broadcast of `bytes` across `p` images — the coarray
/// engine's cost, steered by `CH3_ENABLE_HCOLL`.
pub fn broadcast_us(cfg: &SimConfig, p: usize, bytes: u64) -> f64 {
    if cfg.cvars.enable_hcoll() {
        let nodes = cfg.nodes().max(1);
        let intra = network::memcpy_us(cfg, bytes)
            + (cfg.machine.cores_per_node.min(p) as f64).log2().ceil() * 0.2;
        let inter = (nodes.max(2) as f64).log2().ceil() * per_round(cfg, bytes);
        cfg.machine.hcoll_setup_us + intra + inter
    } else {
        bcast_binomial_us(cfg, p, bytes, u64::MAX)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::config::Machine;

    fn cfg(images: usize, hcoll: bool) -> SimConfig {
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(1), i64::from(hcoll));
        SimConfig::new(Machine::cheyenne(), cv, images)
    }

    #[test]
    fn barrier_scales_logarithmically() {
        let c = cfg(64, false);
        let b64 = barrier_us(&c, 64);
        let c1024 = cfg(1024, false);
        let b1024 = barrier_us(&c1024, 1024);
        assert!(b1024 > b64);
        assert!(b1024 < b64 * 4.0, "should be log-ish: {b64} vs {b1024}");
    }

    #[test]
    fn hcoll_wins_at_scale() {
        // 1024 images over 29 nodes: hierarchical allreduce beats flat.
        let plain = allreduce_us(&cfg(1024, false), 1024, 8192);
        let hcoll = allreduce_us(&cfg(1024, true), 1024, 8192);
        assert!(hcoll < plain, "hcoll={hcoll} plain={plain}");
    }

    #[test]
    fn hcoll_setup_can_lose_on_tiny_jobs() {
        // 2 images on one node: plain recursive doubling is one round.
        let plain = allreduce_us(&cfg(2, false), 2, 64);
        let hcoll = allreduce_us(&cfg(2, true), 2, 64);
        assert!(hcoll > plain, "hcoll={hcoll} plain={plain}");
    }

    #[test]
    fn broadcast_cheaper_than_allreduce() {
        let c = cfg(512, false);
        assert!(broadcast_us(&c, 512, 4096) < allreduce_us(&c, 512, 4096));
    }

    #[test]
    fn engine_costs_equal_their_parameterized_twins() {
        // The refactor onto the algorithm-parameterized functions must
        // not move the coarray engine's numbers by a single bit.
        let c = cfg(256, false);
        assert_eq!(
            allreduce_us(&c, 256, 8192).to_bits(),
            allreduce_recursive_doubling_us(&c, 256, 8192).to_bits()
        );
        assert_eq!(
            broadcast_us(&c, 256, 8192).to_bits(),
            bcast_binomial_us(&c, 256, 8192, u64::MAX).to_bits()
        );
    }

    #[test]
    fn ring_allreduce_wins_large_messages_loses_small_ones() {
        let c = cfg(512, false);
        let big = 1 << 20;
        let rd_big = allreduce_alg_us(&c, 512, big, AllreduceAlgorithm::RecursiveDoubling, false);
        let ring_big = allreduce_alg_us(&c, 512, big, AllreduceAlgorithm::Ring, false);
        assert!(ring_big < rd_big, "ring={ring_big} rd={rd_big} (1 MiB, 512 ranks)");
        let small = 2048;
        let rd_small =
            allreduce_alg_us(&c, 512, small, AllreduceAlgorithm::RecursiveDoubling, false);
        let ring_small = allreduce_alg_us(&c, 512, small, AllreduceAlgorithm::Ring, false);
        assert!(rd_small < ring_small, "rd={rd_small} ring={ring_small} (2 KiB, 512 ranks)");
    }

    #[test]
    fn scatter_allgather_bcast_wins_large_messages_loses_small_ones() {
        let c = cfg(256, false);
        let big = 1 << 20;
        let binomial = bcast_alg_us(&c, 256, big, BcastAlgorithm::Binomial, u64::MAX, false);
        let sag = bcast_alg_us(&c, 256, big, BcastAlgorithm::ScatterAllgather, u64::MAX, false);
        assert!(sag < binomial, "sag={sag} binomial={binomial} (1 MiB, 256 ranks)");
        let small = 1024;
        let binomial_s = bcast_alg_us(&c, 256, small, BcastAlgorithm::Binomial, u64::MAX, false);
        let sag_s = bcast_alg_us(&c, 256, small, BcastAlgorithm::ScatterAllgather, u64::MAX, false);
        assert!(binomial_s < sag_s, "binomial={binomial_s} sag={sag_s} (1 KiB)");
    }

    #[test]
    fn segmenting_pipelines_large_binomial_broadcasts() {
        let c = cfg(256, false);
        let whole = bcast_binomial_us(&c, 256, 1 << 20, u64::MAX);
        let segmented = bcast_binomial_us(&c, 256, 1 << 20, 64 * 1024);
        assert!(segmented < whole, "segmented={segmented} whole={whole}");
        // Over-segmenting (per-segment latency dominates) backfires.
        let shredded = bcast_binomial_us(&c, 256, 1 << 20, 256);
        assert!(shredded > segmented, "shredded={shredded} segmented={segmented}");
    }

    #[test]
    fn smp_hierarchy_helps_multi_node_allreduce() {
        let c = cfg(1024, false);
        let flat =
            allreduce_alg_us(&c, 1024, 8192, AllreduceAlgorithm::RecursiveDoubling, false);
        let smp = allreduce_alg_us(&c, 1024, 8192, AllreduceAlgorithm::RecursiveDoubling, true);
        assert!(smp < flat, "smp={smp} flat={flat}");
    }

    #[test]
    fn algorithm_selectors_decode_cvar_values() {
        assert_eq!(BcastAlgorithm::from_cvar(0), BcastAlgorithm::Binomial);
        assert_eq!(BcastAlgorithm::from_cvar(1), BcastAlgorithm::ScatterAllgather);
        assert_eq!(BcastAlgorithm::from_cvar(2), BcastAlgorithm::ScatterRingAllgather);
        assert_eq!(AllreduceAlgorithm::from_cvar(0), AllreduceAlgorithm::RecursiveDoubling);
        assert_eq!(AllreduceAlgorithm::from_cvar(1), AllreduceAlgorithm::Ring);
    }
}
