//! The discrete-event engine: executes one program per image against the
//! protocol/polling/collective cost models and produces [`RunStats`].
//!
//! Event-driven, process-oriented: each image runs its op list; blocking
//! ops park the image until a completion event fires. Progress semantics
//! (who services an incoming message, and when) are the heart of the
//! model — see [`super::polling`].

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use super::collective;
use super::config::SimConfig;
use super::polling;
use super::process::{Op, Parked, ParkedKind, Proc, Program, Waiting};
use super::protocol::{self, Protocol};
use super::stats::RunStats;
use crate::util::rng::Rng;

/// Scheduled event kinds.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Image `p` is ready to execute its next op.
    Resume { p: usize },
    /// Eager payload arrives at `dst`.
    EagerArrive { origin: usize, dst: usize, bytes: u64, put_seq: u64 },
    /// Rendezvous RTS arrives at `dst`.
    RtsArrive { origin: usize, dst: usize, bytes: u64, put_seq: u64 },
    /// CTS arrives back at `origin`; bulk data departs.
    CtsArrive { origin: usize, dst: usize, bytes: u64, put_seq: u64 },
    /// Rendezvous bulk data lands in `dst`'s window (RDMA, no CPU).
    DataArrive { origin: usize, dst: usize, put_seq: u64 },
    /// Remote completion acknowledged at the origin.
    PutComplete { origin: usize, dst: usize, put_seq: u64 },
    /// Get request arrives at the source image.
    GetReqArrive { origin: usize, src: usize, bytes: u64 },
    /// Get data arrives back at the origin.
    GetDataArrive { origin: usize },
    /// Event post lands at `dst`.
    EventArrive { dst: usize },
    /// A collective/barrier epoch completes.
    CollectiveDone { epoch: u64 },
    /// A team-scoped epoch completes.
    TeamDone { team: u32 },
}

/// Time-ordered event queue entry (seq breaks ties deterministically).
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    at: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.total_cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Barrier / collective rendezvous bookkeeping.
#[derive(Debug, Default)]
struct EpochState {
    epoch: u64,
    arrived: usize,
    last_arrival_us: f64,
    /// Cost function result captured at completion scheduling.
    participants: Vec<usize>,
    /// For collectives: per-epoch op cost (barrier = 0 extra).
    op_cost_us: f64,
}

/// The simulator.
pub struct Engine {
    cfg: SimConfig,
    procs: Vec<Proc>,
    queue: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    put_seq: u64,
    clock: f64,
    barrier: EpochState,
    collective: EpochState,
    /// Team-scoped rendezvous states, keyed by team id (Fortran 2018
    /// teams: OpenCoarrays ships a partial implementation, §4.2).
    /// BTreeMap keeps any future enumeration of teams in key order.
    teams: BTreeMap<u32, EpochState>,
    rng: Rng,
    /// Per-image NIC send/receive availability: bulk transfers
    /// serialize among sends at the origin and among receives at the
    /// destination (full-duplex endpoint congestion model — tx and rx
    /// are independent so no transitive convoy forms across a ring).
    nic_tx_us: Vec<f64>,
    nic_rx_us: Vec<f64>,
    pub stats: RunStats,
}

impl Engine {
    /// Build an engine for `programs` (one per image).
    pub fn new(cfg: SimConfig, programs: Vec<Program>) -> Engine {
        assert_eq!(
            programs.len(),
            cfg.images,
            "program count {} != images {}",
            programs.len(),
            cfg.images
        );
        let rng = Rng::new(cfg.seed);
        let nic_tx_us = vec![0.0; cfg.images];
        let nic_rx_us = vec![0.0; cfg.images];
        // Pre-size the event queue and stat buffers from the program
        // shapes (no reallocation in the event loop hot path).
        let total_ops: usize = programs.iter().map(|p| p.len()).sum();
        let mut stats = RunStats::default();
        stats.flush_times.reserve(total_ops / 4);
        stats.put_times.reserve(total_ops / 2);
        stats.umq_samples.reserve(total_ops / 4);
        let procs = programs.into_iter().map(Proc::new).collect();
        Engine {
            cfg,
            procs,
            queue: BinaryHeap::with_capacity(1024 + total_ops / 8),
            seq: 0,
            put_seq: 0,
            clock: 0.0,
            barrier: EpochState::default(),
            collective: EpochState::default(),
            teams: BTreeMap::new(),
            rng,
            nic_tx_us,
            nic_rx_us,
            stats,
        }
    }

    /// Reserve both endpoints' NICs for a bulk transfer of `bytes`
    /// starting no earlier than `t`; returns the arrival time at `dst`.
    fn reserve_transfer(&mut self, t: f64, origin: usize, dst: usize, bytes: u64) -> f64 {
        // tx and rx are reserved as *independent* queues: the arrival
        // respects both endpoints' serialization, but neither queue
        // inherits the other's backlog (otherwise delays propagate
        // transitively around communication rings — a convoy artifact
        // real shared-bandwidth NICs don't exhibit).
        let dur = bytes as f64 / super::network::effective_bandwidth(&self.cfg);
        let start_tx = t.max(self.nic_tx_us[origin]);
        let start_rx = t.max(self.nic_rx_us[dst]);
        self.nic_tx_us[origin] = start_tx + dur;
        self.nic_rx_us[dst] = start_rx + dur;
        start_tx.max(start_rx) + dur + self.cfg.machine.latency_us
    }

    fn push(&mut self, at: f64, ev: Ev) {
        debug_assert!(at >= self.clock - 1e-9, "event scheduled in the past");
        self.seq += 1;
        self.queue.push(Reverse(Scheduled { at, seq: self.seq, ev }));
    }

    /// Run to completion; returns the collected statistics.
    pub fn run(mut self) -> RunStats {
        for p in 0..self.procs.len() {
            self.push(0.0, Ev::Resume { p });
        }
        let mut guard: u64 = 0;
        let budget = 500_000_000u64;
        while let Some(Reverse(Scheduled { at, ev, .. })) = self.queue.pop() {
            self.clock = at;
            self.dispatch(at, ev);
            guard += 1;
            assert!(guard < budget, "event budget exceeded — livelock in simulation?");
        }
        let unfinished: Vec<usize> = self
            .procs
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.finished())
            .map(|(i, _)| i)
            .collect();
        assert!(
            unfinished.is_empty(),
            "deadlock: images {unfinished:?} never finished (pc/waiting: {:?})",
            unfinished
                .iter()
                .take(4)
                .map(|&i| (self.procs[i].pc, self.procs[i].waiting))
                .collect::<Vec<_>>()
        );
        self.stats.total_time_us =
            self.procs.iter().map(|p| p.finish_time_us).fold(0.0, f64::max);
        self.stats
    }

    fn dispatch(&mut self, t: f64, ev: Ev) {
        match ev {
            Ev::Resume { p } => self.execute(p, t),
            Ev::EagerArrive { origin, dst, bytes, put_seq } => {
                self.incoming(t, dst, Parked { kind: ParkedKind::EagerData { put_seq }, origin, bytes, arrived_us: t });
            }
            Ev::RtsArrive { origin, dst, bytes, put_seq } => {
                self.incoming(t, dst, Parked { kind: ParkedKind::Rts { put_seq }, origin, bytes, arrived_us: t });
            }
            Ev::CtsArrive { origin, dst, bytes, put_seq } => {
                // Bulk data departs origin via RDMA (no origin CPU), but
                // serializes on both endpoints' NICs.
                let arrival = self.reserve_transfer(t, origin, dst, bytes);
                self.push(arrival, Ev::DataArrive { origin, dst, put_seq });
            }
            Ev::DataArrive { origin, dst, put_seq } => {
                // RDMA write into the preposted window: no target CPU.
                let ack = protocol::control_wire_us(&self.cfg);
                self.push(t + ack, Ev::PutComplete { origin, dst, put_seq });
            }
            Ev::PutComplete { origin, dst, put_seq } => {
                self.put_complete(t, origin, dst, put_seq);
            }
            Ev::GetReqArrive { origin, src, bytes } => {
                self.incoming(t, src, Parked { kind: ParkedKind::GetReq, origin, bytes, arrived_us: t });
            }
            Ev::GetDataArrive { origin } => self.get_data_arrived(t, origin),
            Ev::EventArrive { dst } => {
                self.incoming(t, dst, Parked { kind: ParkedKind::EventPost, origin: usize::MAX, bytes: 16, arrived_us: t });
            }
            Ev::CollectiveDone { epoch } => self.collective_done(t, epoch),
            Ev::TeamDone { team } => self.team_done(t, team),
        }
    }

    // ------------------------------------------------------------------
    // Program execution
    // ------------------------------------------------------------------

    /// Execute ops for image `p` starting at time `t` until it blocks,
    /// computes, or finishes.
    fn execute(&mut self, p: usize, t: f64) {
        let mut now = t;
        self.procs[p].waiting = Waiting::None;
        loop {
            // Entering the MPI layer (any op but Compute) drains parked
            // messages first — a plain MPI call also polls the engine.
            let op = match self.procs[p].program.get(self.procs[p].pc) {
                Some(op) => op.clone(),
                None => {
                    self.procs[p].waiting = Waiting::Finished;
                    self.procs[p].finish_time_us = now;
                    return;
                }
            };
            if !matches!(op, Op::Compute { .. }) {
                now = self.drain_parked(p, now);
            }
            match op {
                Op::Compute { us } => {
                    let jitter = 1.0 + self.cfg.noise * self.rng.normal();
                    let dur = us * jitter.max(0.05) * polling::compute_tax_factor(&self.cfg);
                    self.procs[p].pc += 1;
                    self.push(now + dur, Ev::Resume { p });
                    return;
                }
                Op::Put { target, bytes } => {
                    now = self.do_put(p, target, bytes, now);
                    self.procs[p].pc += 1;
                }
                Op::Get { source, bytes } => {
                    self.procs[p].pc += 1;
                    // Request needs source-side service; data returns after.
                    let wire = protocol::control_wire_us(&self.cfg);
                    self.push(now + wire, Ev::GetReqArrive { origin: p, src: source, bytes });
                    self.block(p, Waiting::GetData, now);
                    return;
                }
                Op::Flush { target } => {
                    now = self.issue_delayed_puts(p, Some(target), now);
                    if self.procs[p].outstanding_to(target) == 0 {
                        self.stats.flush_times.push(self.cfg.machine.mpi_service_us);
                        now += self.cfg.machine.mpi_service_us;
                        self.procs[p].pc += 1;
                    } else {
                        self.procs[p].pc += 1;
                        self.block(p, Waiting::Flush { target }, now);
                        return;
                    }
                }
                Op::FlushAll => {
                    now = self.issue_delayed_puts(p, None, now);
                    if self.procs[p].outstanding_total == 0 {
                        self.stats.flush_times.push(self.cfg.machine.mpi_service_us);
                        now += self.cfg.machine.mpi_service_us;
                        self.procs[p].pc += 1;
                    } else {
                        self.procs[p].pc += 1;
                        self.block(p, Waiting::FlushAll { then_barrier: false }, now);
                        return;
                    }
                }
                Op::SyncAll => {
                    now = self.issue_delayed_puts(p, None, now);
                    if self.procs[p].outstanding_total == 0 {
                        self.procs[p].pc += 1;
                        self.enter_barrier(p, now);
                    } else {
                        self.procs[p].pc += 1;
                        self.block(p, Waiting::FlushAll { then_barrier: true }, now);
                    }
                    return;
                }
                Op::EventPost { target } => {
                    let wire = protocol::control_wire_us(&self.cfg);
                    now += self.cfg.machine.per_msg_overhead_us;
                    self.push(now + wire, Ev::EventArrive { dst: target });
                    self.procs[p].pc += 1;
                }
                Op::EventWait { count } => {
                    let have = self.procs[p].events_pending;
                    if have >= count {
                        self.procs[p].events_pending -= count;
                        self.procs[p].pc += 1;
                        now += self.cfg.machine.mpi_service_us;
                    } else {
                        let still = count - have;
                        self.procs[p].events_pending = 0;
                        self.procs[p].pc += 1;
                        self.block(p, Waiting::Event { still_needed: still }, now);
                        return;
                    }
                }
                Op::TeamBarrier { team, size } => {
                    now = self.issue_delayed_puts(p, None, now);
                    self.procs[p].pc += 1;
                    self.enter_team(p, now, team, size as usize, 0.0);
                    return;
                }
                Op::TeamCoSum { team, size, bytes } => {
                    let cost = collective::allreduce_us(&self.cfg, size as usize, bytes);
                    self.procs[p].pc += 1;
                    self.enter_team(p, now, team, size as usize, cost);
                    return;
                }
                Op::CoSum { bytes } | Op::CoBroadcast { bytes } => {
                    let cost = match op {
                        Op::CoSum { .. } => {
                            collective::allreduce_us(&self.cfg, self.cfg.images, bytes)
                        }
                        _ => collective::broadcast_us(&self.cfg, self.cfg.images, bytes),
                    };
                    self.procs[p].pc += 1;
                    self.enter_collective(p, now, cost);
                    return;
                }
            }
        }
    }

    fn block(&mut self, p: usize, waiting: Waiting, now: f64) {
        self.procs[p].waiting = waiting;
        self.procs[p].block_start_us = now;
    }

    /// Resume a blocked image at `completion`, charging poll/yield
    /// overhead for a wait that lasted since `block_start`.
    ///
    /// Clears `waiting` immediately: the proc is logically released the
    /// moment its condition is met, so a message arriving in the
    /// wake-up window must not observe the stale blocked state (it
    /// would double-release the proc — e.g. two event posts landing
    /// within one yield latency).
    fn unblock(&mut self, p: usize, completion: f64) -> f64 {
        let wait = (completion - self.procs[p].block_start_us).max(0.0);
        self.procs[p].waiting = Waiting::None;
        let overhead = polling::wait_overhead_us(&self.cfg, wait);
        if wait > polling::poll_window_us(&self.cfg) {
            self.stats.yields += 1;
        }
        let resume_at = completion + overhead;
        self.push(resume_at, Ev::Resume { p });
        resume_at
    }

    // ------------------------------------------------------------------
    // Puts
    // ------------------------------------------------------------------

    fn do_put(&mut self, origin: usize, target: usize, bytes: u64, now: f64) -> f64 {
        if protocol::delayed_for_piggyback(&self.cfg, bytes) {
            // Queued locally; issued (batched) at the next flush.
            self.procs[origin].delayed_puts.push((target, bytes));
            self.stats.piggybacked_ops += 1;
            return now + 0.05; // negligible local queuing cost
        }
        self.issue_put(origin, target, bytes, now)
    }

    /// Issue one put on the wire; returns the origin-side completion
    /// time of the *local* call.
    fn issue_put(&mut self, origin: usize, target: usize, bytes: u64, now: f64) -> f64 {
        self.put_seq += 1;
        let seq = self.put_seq;
        let proto = protocol::select(&self.cfg, bytes);
        let issue = protocol::put_issue_cost_us(&self.cfg, bytes, proto);
        let done_local = now + issue;
        self.procs[origin].add_outstanding(target);
        self.stats.bytes_sent += bytes;
        match proto {
            Protocol::Eager => {
                self.stats.eager_msgs += 1;
                let arrival = self.reserve_transfer(done_local, origin, target, bytes);
                self.push(arrival, Ev::EagerArrive { origin, dst: target, bytes, put_seq: seq });
            }
            Protocol::Rendezvous => {
                self.stats.rendezvous_msgs += 1;
                let wire = protocol::control_wire_us(&self.cfg);
                self.push(done_local + wire, Ev::RtsArrive { origin, dst: target, bytes, put_seq: seq });
            }
        }
        self.stats.put_times.push(issue);
        done_local
    }

    /// Issue delayed (piggybacked) puts for `target` (or all targets) as
    /// batched messages; returns the new local time.
    fn issue_delayed_puts(&mut self, origin: usize, target: Option<usize>, now: f64) -> f64 {
        let delayed = std::mem::take(&mut self.procs[origin].delayed_puts);
        let (mine, keep): (Vec<_>, Vec<_>) = delayed
            .into_iter()
            .partition(|(t, _)| target.map(|tt| *t == tt).unwrap_or(true));
        self.procs[origin].delayed_puts = keep;
        if mine.is_empty() {
            return now;
        }
        // Batch per destination: one combined message per target (the
        // piggybacking win: one overhead + one lock for many small ops).
        // BTreeMap so the issue order below is destination order by
        // construction — never hash order.
        let mut by_dst: std::collections::BTreeMap<usize, u64> = std::collections::BTreeMap::new();
        for (t, b) in mine {
            *by_dst.entry(t).or_insert(0) += b;
        }
        let mut cursor = now;
        for (dst, bytes) in by_dst {
            cursor = self.issue_put(origin, dst, bytes, cursor);
        }
        cursor
    }

    fn put_complete(&mut self, t: f64, origin: usize, dst: usize, put_seq: u64) {
        let _ = put_seq;
        self.procs[origin].complete_outstanding(dst);
        match self.procs[origin].waiting {
            Waiting::Flush { target } if target == dst => {
                if self.procs[origin].outstanding_to(dst) == 0 {
                    let wait = t - self.procs[origin].block_start_us;
                    self.stats.flush_times.push(wait.max(0.0));
                    self.unblock(origin, t);
                }
            }
            Waiting::Flush { .. } => {}
            Waiting::FlushAll { then_barrier } => {
                if self.procs[origin].outstanding_total == 0 {
                    let wait = t - self.procs[origin].block_start_us;
                    self.stats.flush_times.push(wait.max(0.0));
                    if then_barrier {
                        // No separate resume: step into the barrier now.
                        let overhead = polling::wait_overhead_us(&self.cfg, wait.max(0.0));
                        self.enter_barrier(origin, t + overhead);
                    } else {
                        self.unblock(origin, t);
                    }
                }
            }
            _ => {}
        }
    }

    // ------------------------------------------------------------------
    // Incoming message service (the progress model)
    // ------------------------------------------------------------------

    /// An incoming message lands at `dst`: decide when it is serviced.
    fn incoming(&mut self, t: f64, dst: usize, msg: Parked) {
        if matches!(msg.kind, ParkedKind::EagerData { .. }) {
            // Sample UMQ length at arrival (the MPICH pvar).
            let unexpected = self.procs[dst].umq_len + 1;
            self.stats.umq_samples.push(unexpected as f64);
        }
        if self.cfg.cvars.async_progress() {
            // Progress thread services regardless of what dst is doing.
            let delay = polling::async_service_delay_us(&self.cfg);
            self.service(t + delay, dst, msg);
        } else if self.procs[dst].in_mpi() {
            let since = t - self.procs[dst].block_start_us;
            let delay = polling::blocked_service_delay_us(&self.cfg, since);
            self.service(t + delay, dst, msg);
        } else {
            // Target is computing (or finished): park until it next
            // enters MPI. Eager payloads sit in the unexpected queue.
            if matches!(msg.kind, ParkedKind::EagerData { .. }) {
                self.procs[dst].umq_len += 1;
            }
            self.procs[dst].parked.push(msg);
        }
    }

    /// Drain messages parked at `p` (called when `p` enters MPI);
    /// returns the time after servicing.
    fn drain_parked(&mut self, p: usize, now: f64) -> f64 {
        if self.procs[p].parked.is_empty() {
            return now;
        }
        let parked = std::mem::take(&mut self.procs[p].parked);
        let mut cursor = now;
        for msg in parked {
            if matches!(msg.kind, ParkedKind::EagerData { .. }) {
                self.procs[p].umq_len = self.procs[p].umq_len.saturating_sub(1);
            }
            cursor += self.cfg.machine.mpi_service_us;
            self.service(cursor, p, msg);
        }
        cursor
    }

    /// Actually process a serviced message at time `t` on image `dst`.
    fn service(&mut self, t: f64, dst: usize, msg: Parked) {
        match msg.kind {
            ParkedKind::EagerData { put_seq } => {
                // Copy out of the comm buffer into the window, then ack.
                let apply = protocol::eager_apply_cost_us(&self.cfg, msg.bytes);
                let ack = protocol::control_wire_us(&self.cfg);
                self.push(t + apply + ack, Ev::PutComplete { origin: msg.origin, dst, put_seq });
            }
            ParkedKind::Rts { put_seq } => {
                // Reply CTS; bulk data flows when it reaches the origin.
                let wire = protocol::control_wire_us(&self.cfg);
                self.push(
                    t + wire,
                    Ev::CtsArrive { origin: msg.origin, dst, bytes: msg.bytes, put_seq },
                );
            }
            ParkedKind::GetReq => {
                // Serve the data back to the origin (bulk, NIC-bound).
                let arrival = self.reserve_transfer(t, dst, msg.origin, msg.bytes);
                self.push(arrival, Ev::GetDataArrive { origin: msg.origin });
            }
            ParkedKind::EventPost => {
                self.stats.events_processed += 1;
                self.event_arrived(t, dst);
            }
        }
    }

    // ------------------------------------------------------------------
    // Gets, events, barriers, collectives
    // ------------------------------------------------------------------

    fn get_data_arrived(&mut self, t: f64, origin: usize) {
        debug_assert!(matches!(self.procs[origin].waiting, Waiting::GetData));
        let wait = t - self.procs[origin].block_start_us;
        self.stats.get_times.push(wait.max(0.0));
        self.unblock(origin, t);
    }

    fn event_arrived(&mut self, t: f64, dst: usize) {
        if let Waiting::Event { still_needed } = self.procs[dst].waiting {
            if still_needed <= 1 {
                self.unblock(dst, t);
            } else {
                self.procs[dst].waiting = Waiting::Event { still_needed: still_needed - 1 };
            }
        } else {
            self.procs[dst].events_pending += 1;
        }
    }

    fn enter_barrier(&mut self, p: usize, now: f64) {
        self.block(p, Waiting::Barrier, now);
        self.barrier.arrived += 1;
        self.barrier.last_arrival_us = self.barrier.last_arrival_us.max(now);
        self.barrier.participants.push(p);
        if self.barrier.arrived == self.cfg.images {
            let cost = collective::barrier_us(&self.cfg, self.cfg.images);
            let done = self.barrier.last_arrival_us + cost;
            let epoch = self.barrier.epoch;
            self.push(done, Ev::CollectiveDone { epoch: epoch << 1 }); // even = barrier
        }
    }

    fn enter_collective(&mut self, p: usize, now: f64, op_cost_us: f64) {
        self.block(p, Waiting::Collective, now);
        self.collective.arrived += 1;
        self.collective.last_arrival_us = self.collective.last_arrival_us.max(now);
        self.collective.op_cost_us = self.collective.op_cost_us.max(op_cost_us);
        self.collective.participants.push(p);
        if self.collective.arrived == self.cfg.images {
            self.stats.collectives += 1;
            let done = self.collective.last_arrival_us + self.collective.op_cost_us;
            let epoch = self.collective.epoch;
            self.push(done, Ev::CollectiveDone { epoch: (epoch << 1) | 1 }); // odd = collective
        }
    }

    fn enter_team(&mut self, p: usize, now: f64, team: u32, size: usize, op_cost_us: f64) {
        assert!(size >= 1, "empty team");
        self.block(p, Waiting::Collective, now);
        let state = self.teams.entry(team).or_default();
        state.arrived += 1;
        state.last_arrival_us = state.last_arrival_us.max(now);
        state.op_cost_us = state.op_cost_us.max(op_cost_us);
        state.participants.push(p);
        assert!(
            state.arrived <= size,
            "team {team} overfilled: {} arrivals for size {size}",
            state.arrived
        );
        if state.arrived == size {
            let cost = collective::barrier_us(&self.cfg, size) + state.op_cost_us;
            let done = state.last_arrival_us + cost;
            self.push(done, Ev::TeamDone { team });
        }
    }

    fn team_done(&mut self, t: f64, team: u32) {
        // A TeamDone event is only ever scheduled by team_arrive, which
        // inserts the epoch state first; an unknown team would be a
        // scheduling bug, caught in debug builds.
        let Some(state) = self.teams.get_mut(&team) else {
            debug_assert!(false, "TeamDone for unknown team {team}");
            return;
        };
        let participants = std::mem::take(&mut state.participants);
        state.arrived = 0;
        state.last_arrival_us = 0.0;
        state.op_cost_us = 0.0;
        state.epoch += 1;
        for p in participants {
            self.unblock(p, t);
        }
    }

    fn collective_done(&mut self, t: f64, epoch: u64) {
        let is_collective = epoch & 1 == 1;
        let state = if is_collective { &mut self.collective } else { &mut self.barrier };
        let participants = std::mem::take(&mut state.participants);
        state.arrived = 0;
        state.last_arrival_us = 0.0;
        state.op_cost_us = 0.0;
        state.epoch += 1;
        for p in participants {
            self.unblock(p, t);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::config::Machine;

    fn cfg(images: usize) -> SimConfig {
        let mut c = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), images);
        c.noise = 0.0;
        c
    }

    #[test]
    fn empty_programs_finish_at_zero() {
        let stats = Engine::new(cfg(4), vec![vec![]; 4]).run();
        assert_eq!(stats.total_time_us, 0.0);
    }

    #[test]
    fn compute_only_sets_total_time() {
        let progs = vec![vec![Op::Compute { us: 100.0 }]; 2];
        let stats = Engine::new(cfg(2), progs).run();
        assert!((stats.total_time_us - 100.0).abs() < 1e-6);
    }

    #[test]
    fn put_flush_round_trip_completes() {
        // Image 0 puts to image 1 and flushes; image 1 waits in sync.
        let progs = vec![
            vec![Op::Put { target: 1, bytes: 1024 }, Op::Flush { target: 1 }, Op::SyncAll],
            vec![Op::SyncAll],
        ];
        let stats = Engine::new(cfg(2), progs).run();
        assert_eq!(stats.eager_msgs, 1);
        assert!(stats.total_time_us > 0.0);
        assert_eq!(stats.flush_times.len(), 1);
    }

    #[test]
    fn rendezvous_for_big_messages() {
        let progs = vec![
            vec![Op::Put { target: 1, bytes: 1 << 20 }, Op::Flush { target: 1 }, Op::SyncAll],
            vec![Op::SyncAll],
        ];
        let stats = Engine::new(cfg(2), progs).run();
        assert_eq!(stats.rendezvous_msgs, 1);
        assert_eq!(stats.eager_msgs, 0);
    }

    #[test]
    fn barrier_synchronizes_all() {
        // One image computes 1000µs; everyone leaves the barrier after.
        let progs = vec![
            vec![Op::Compute { us: 1000.0 }, Op::SyncAll],
            vec![Op::SyncAll],
            vec![Op::SyncAll],
        ];
        let stats = Engine::new(cfg(3), progs).run();
        assert!(stats.total_time_us >= 1000.0);
    }

    #[test]
    fn events_post_and_wait() {
        let progs = vec![
            vec![Op::EventPost { target: 1 }, Op::SyncAll],
            vec![Op::EventWait { count: 1 }, Op::SyncAll],
        ];
        let stats = Engine::new(cfg(2), progs).run();
        assert_eq!(stats.events_processed, 1);
    }

    #[test]
    fn event_wait_before_post_blocks_then_resumes() {
        let progs = vec![
            vec![Op::Compute { us: 500.0 }, Op::EventPost { target: 1 }],
            vec![Op::EventWait { count: 1 }],
        ];
        let stats = Engine::new(cfg(2), progs).run();
        assert!(stats.total_time_us >= 500.0);
    }

    #[test]
    fn get_blocks_until_served() {
        let progs = vec![
            vec![Op::Get { source: 1, bytes: 4096 }],
            vec![Op::Compute { us: 300.0 }, Op::FlushAll],
        ];
        // Image 1 computes 300µs before entering MPI (flush), so without
        // async progress the get waits for it.
        let mut c = cfg(2);
        c.cvars.set(CvarId(0), 0);
        let progs2 = vec![progs[0].clone(), progs[1].clone()];
        let stats = Engine::new(c, progs2).run();
        assert_eq!(stats.get_times.len(), 1);
        assert!(stats.get_times[0] >= 290.0, "get should stall ~300µs: {:?}", stats.get_times);
    }

    #[test]
    fn async_progress_unstalls_gets() {
        let progs = vec![
            vec![Op::Get { source: 1, bytes: 4096 }],
            vec![Op::Compute { us: 300.0 }, Op::FlushAll],
        ];
        let mut c = cfg(2);
        c.cvars.set(CvarId(0), 1);
        let stats = Engine::new(c, progs).run();
        assert!(stats.get_times[0] < 50.0, "async progress should serve the get: {:?}", stats.get_times);
    }

    #[test]
    fn collectives_complete() {
        let progs = vec![vec![Op::CoSum { bytes: 4096 }]; 4];
        let stats = Engine::new(cfg(4), progs).run();
        assert_eq!(stats.collectives, 1);
        assert!(stats.total_time_us > 0.0);
    }

    #[test]
    fn umq_grows_when_target_computes() {
        // Image 0 sends 5 eager puts while image 1 computes.
        let progs = vec![
            vec![
                Op::Put { target: 1, bytes: 1024 },
                Op::Put { target: 1, bytes: 1024 },
                Op::Put { target: 1, bytes: 1024 },
                Op::Put { target: 1, bytes: 1024 },
                Op::Put { target: 1, bytes: 1024 },
                Op::Flush { target: 1 },
                Op::SyncAll,
            ],
            vec![Op::Compute { us: 5000.0 }, Op::SyncAll],
        ];
        let stats = Engine::new(cfg(2), progs).run();
        let umq = stats.umq_summary();
        assert!(umq.max >= 2.0, "UMQ should build up: {umq:?}");
    }

    #[test]
    fn piggyback_delay_batches_small_puts() {
        let mut c = cfg(2);
        c.cvars.set(CvarId(2), 1); // delay issuing
        let progs = vec![
            vec![
                Op::Put { target: 1, bytes: 512 },
                Op::Put { target: 1, bytes: 512 },
                Op::Put { target: 1, bytes: 512 },
                Op::Flush { target: 1 },
                Op::SyncAll,
            ],
            vec![Op::SyncAll],
        ];
        let stats = Engine::new(c, progs).run();
        assert_eq!(stats.piggybacked_ops, 3);
        // Batched: one combined eager message instead of three.
        assert_eq!(stats.eager_msgs, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let progs = || {
            vec![
                vec![Op::Compute { us: 50.0 }, Op::Put { target: 1, bytes: 2048 }, Op::SyncAll],
                vec![Op::Compute { us: 60.0 }, Op::SyncAll],
            ]
        };
        let mut c1 = cfg(2);
        c1.noise = 0.1;
        c1.seed = 99;
        let mut c2 = cfg(2);
        c2.noise = 0.1;
        c2.seed = 99;
        let a = Engine::new(c1, progs()).run();
        let b = Engine::new(c2, progs()).run();
        assert_eq!(a.total_time_us, b.total_time_us);
    }
}
