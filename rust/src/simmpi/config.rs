//! Machine models and per-run simulation configuration.
//!
//! Two presets mirror the paper's testbeds: *Cheyenne* (SGI ICE XA,
//! EDR InfiniBand, 36 cores/node) and *Edison* (Cray XC30, Aries
//! dragonfly, 24 cores/node). Parameters are calibrated for landscape
//! shape, not absolute fidelity (see module docs).

use crate::mpi_t::CvarSet;

/// Hardware/OS cost model for one machine. All times in microseconds,
/// bandwidths in bytes/µs.
#[derive(Debug, Clone)]
pub struct Machine {
    pub name: &'static str,
    pub cores_per_node: usize,
    /// Largest image (process) count the testbed supports — the
    /// normalization ceiling of the RL scale feature
    /// ([`crate::backend::scale_feature`]). Both paper testbeds ran up
    /// to 2048 images (§6); a larger deployment raises this instead of
    /// silently pushing the feature past 1.0.
    pub max_images: usize,
    /// Base one-way network latency.
    pub latency_us: f64,
    /// Large-message network bandwidth (bytes per µs).
    pub bandwidth_bpus: f64,
    /// Sender-side software/NIC overhead per message.
    pub per_msg_overhead_us: f64,
    /// Scale-dependent contention: effective bandwidth divides by
    /// `1 + contention * log2(images / 64)` above 64 images.
    pub contention: f64,
    /// Local memory-copy bandwidth (eager buffer copies), bytes/µs.
    pub memcpy_bpus: f64,
    /// Cost of one progress-engine poll iteration.
    pub poll_cost_us: f64,
    /// Latency to be rescheduled after yielding the core.
    pub yield_wakeup_us: f64,
    /// Progress-thread service latency for one incoming message.
    pub async_service_us: f64,
    /// Compute slowdown factor while the async progress thread runs
    /// (it steals a hyperthread / memory bandwidth).
    pub async_compute_tax: f64,
    /// Cost to service one incoming message while blocked inside MPI.
    pub mpi_service_us: f64,
    /// Extra per-poll starvation of the progress thread while the main
    /// thread busy-polls (only with ASYNC_PROGRESS=1).
    pub poll_starve_coeff: f64,
    /// One-way cost of an RMA lock message that could not piggyback.
    pub lock_overhead_us: f64,
    /// Setup cost of hierarchical (HCOLL) collectives per call.
    pub hcoll_setup_us: f64,
}

impl Machine {
    /// NCAR Cheyenne: SGI ICE XA, EDR InfiniBand (~6 GB/s effective
    /// per-rank), 36-core Broadwell nodes.
    pub fn cheyenne() -> Machine {
        Machine {
            name: "cheyenne",
            cores_per_node: 36,
            max_images: 2048,
            latency_us: 1.3,
            bandwidth_bpus: 6_000.0,
            per_msg_overhead_us: 0.45,
            contention: 0.22,
            memcpy_bpus: 40_000.0,
            poll_cost_us: 0.12,
            yield_wakeup_us: 18.0,
            async_service_us: 1.1,
            async_compute_tax: 0.035,
            mpi_service_us: 0.5,
            poll_starve_coeff: 0.004,
            lock_overhead_us: 1.3,
            hcoll_setup_us: 4.0,
        }
    }

    /// NERSC Edison: Cray XC30, Aries dragonfly (~5 GB/s effective
    /// per-rank), 24-core Ivy Bridge nodes.
    pub fn edison() -> Machine {
        Machine {
            name: "edison",
            cores_per_node: 24,
            max_images: 2048,
            latency_us: 1.0,
            bandwidth_bpus: 5_000.0,
            per_msg_overhead_us: 0.35,
            contention: 0.12,
            memcpy_bpus: 35_000.0,
            poll_cost_us: 0.10,
            yield_wakeup_us: 14.0,
            async_service_us: 0.9,
            async_compute_tax: 0.045,
            mpi_service_us: 0.45,
            poll_starve_coeff: 0.0045,
            lock_overhead_us: 1.0,
            hcoll_setup_us: 3.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Machine> {
        match name {
            "cheyenne" => Some(Machine::cheyenne()),
            "edison" => Some(Machine::edison()),
            _ => None,
        }
    }
}

/// Everything one simulated application run needs.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub machine: Machine,
    pub cvars: CvarSet,
    /// Number of images (MPI processes).
    pub images: usize,
    /// Run-to-run multiplicative compute noise (std-dev fraction;
    /// paper §5.5 explores up to 0.30).
    pub noise: f64,
    /// RNG seed for this run.
    pub seed: u64,
}

impl SimConfig {
    pub fn new(machine: Machine, cvars: CvarSet, images: usize) -> SimConfig {
        SimConfig { machine, cvars, images, noise: 0.02, seed: 0 }
    }

    /// Scale-dependent network contention multiplier (≥ 1).
    pub fn contention_factor(&self) -> f64 {
        let base = (self.images as f64 / 64.0).log2().max(0.0);
        1.0 + self.machine.contention * base
    }

    /// Nodes occupied by this run.
    pub fn nodes(&self) -> usize {
        self.images.div_ceil(self.machine.cores_per_node)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        assert_eq!(Machine::cheyenne().name, "cheyenne");
        assert_eq!(Machine::edison().cores_per_node, 24);
        assert!(Machine::by_name("cheyenne").is_some());
        assert!(Machine::by_name("summit").is_none());
    }

    #[test]
    fn contention_grows_with_images() {
        let mk = |n| SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), n);
        let c64 = mk(64).contention_factor();
        let c512 = mk(512).contention_factor();
        let c2048 = mk(2048).contention_factor();
        assert_eq!(c64, 1.0);
        assert!(c512 > c64);
        assert!(c2048 > c512);
    }

    #[test]
    fn node_count() {
        let cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 256);
        assert_eq!(cfg.nodes(), 8); // 256 / 36 -> 8 nodes
    }
}
