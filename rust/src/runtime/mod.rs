//! The Q-network runtime layer: the [`QBackend`] seam plus its two
//! engines.
//!
//! * [`native`] — the default: a pure-Rust, dependency-free MLP engine
//!   (forward, backprop, Huber loss, Adam) constructed straight from a
//!   backend's `(state_dim, num_actions)`. Dimension-generic, so
//!   `--agent dqn` works on every [`crate::backend::TunableRuntime`],
//!   and it exposes per-sample TD errors and raw gradients (adaptive
//!   PER; gradient-level hub merging).
//! * [`aot`] — the original AOT/PJRT path: `make artifacts` lowers the
//!   JAX Q-network (with its Pallas fused-dense kernel) to
//!   `artifacts/*.hlo.txt`; [`AotQNet`] compiles those modules once on
//!   the PJRT CPU client and executes them at tuning time (requires the
//!   `pjrt` cargo feature + the external `xla` bindings; offline builds
//!   get a fail-fast stub). Python never runs at tuning time.
//!
//! [`QNet`] is the coordinator-facing dispatcher over the seam.

mod aot;
mod artifact;
mod client;
pub mod native;
pub(crate) mod params;
mod qnet;
pub(crate) mod xla;

pub use aot::AotQNet;
pub use artifact::{default_artifacts_dir, ArtifactSpec, Manifest, TensorSpec};
pub use client::{Executable, RuntimeClient};
pub use native::{
    adam_step, q_values_batch_of, DenseKernel, FusedGrads, FusedTrainer, NativeQNet, PackedWeights,
};
pub use params::{
    average_adam, average_params, layer_dims as params_layer_dims, AdamState, QParams,
};
pub use qnet::{argmax, LossRing, QBackend, QNet, TrainBatch, TrainOutcome};
