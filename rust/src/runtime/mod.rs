//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only bridge between the Rust coordinator and the L2/L1
//! compute: `make artifacts` lowers the JAX Q-network (with its Pallas
//! fused-dense kernel) to `artifacts/*.hlo.txt`; this module compiles
//! those modules once on the PJRT CPU client and executes them on the
//! tuning path. Python never runs at tuning time.

mod artifact;
mod client;
mod params;
mod qnet;
pub(crate) mod xla;

pub use artifact::{default_artifacts_dir, ArtifactSpec, Manifest, TensorSpec};
pub use client::{Executable, RuntimeClient};
pub use params::{
    average_adam, average_params, layer_dims as params_layer_dims, AdamState, QParams,
};
pub use qnet::{argmax, QNet, TrainBatch};
