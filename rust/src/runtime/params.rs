//! Q-network parameter and optimizer-state containers.
//!
//! The train-step artifact is fully functional (params in → params out),
//! so Rust owns all state between steps as flat `Vec<f32>` buffers in the
//! canonical order `(w1, b1, w2, b2, w3, b3)` matching
//! `python/compile/model.py::param_specs()`.

use anyhow::Result;

use super::xla;
use crate::util::fnv::Fnv64;
use crate::util::rng::Rng;

/// Layer dims of the Q-net MLP; must match `model.LAYER_DIMS`.
pub fn layer_dims(state_dim: usize, hidden: &[usize], num_actions: usize) -> Vec<(usize, usize)> {
    let mut dims = Vec::new();
    let mut prev = state_dim;
    for &h in hidden {
        dims.push((prev, h));
        prev = h;
    }
    dims.push((prev, num_actions));
    dims
}

/// Flat parameter set: weights and biases in calling order.
#[derive(Debug, Clone, PartialEq)]
pub struct QParams {
    /// `[(data, shape)]` in `(w1, b1, w2, b2, w3, b3)` order.
    pub tensors: Vec<(Vec<f32>, Vec<usize>)>,
}

impl QParams {
    /// He-uniform init matching `model.init_params` semantics (not
    /// bit-identical — different PRNG — but same distribution family).
    pub fn init(state_dim: usize, hidden: &[usize], num_actions: usize, rng: &mut Rng) -> QParams {
        let mut tensors = Vec::new();
        for (d_in, d_out) in layer_dims(state_dim, hidden, num_actions) {
            let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.he_uniform(d_in)).collect();
            tensors.push((w, vec![d_in, d_out]));
            tensors.push((vec![0.0; d_out], vec![d_out]));
        }
        QParams { tensors }
    }

    /// Zeroed clone with identical shapes (Adam moment buffers).
    pub fn zeros_like(&self) -> QParams {
        QParams {
            tensors: self
                .tensors
                .iter()
                .map(|(data, shape)| (vec![0.0; data.len()], shape.clone()))
                .collect(),
        }
    }

    /// Build from flat per-tensor data with explicit shapes.
    pub fn from_flat(tensors: Vec<(Vec<f32>, Vec<usize>)>) -> Result<QParams> {
        for (data, shape) in &tensors {
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                data.len() == want,
                "tensor data {} != shape product {want}",
                data.len()
            );
        }
        Ok(QParams { tensors })
    }

    /// Convert every tensor to an XLA literal (reshaped to its rank).
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.tensors
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            })
            .collect()
    }

    /// Rebuild from output literals (shape metadata kept from self).
    pub fn update_from_literals(&mut self, literals: &[xla::Literal]) -> Result<()> {
        anyhow::ensure!(
            literals.len() == self.tensors.len(),
            "expected {} tensors, got {}",
            self.tensors.len(),
            literals.len()
        );
        for ((data, _), lit) in self.tensors.iter_mut().zip(literals) {
            let v = lit.to_vec::<f32>()?;
            anyhow::ensure!(v.len() == data.len(), "tensor size changed across update");
            *data = v;
        }
        Ok(())
    }

    pub fn num_parameters(&self) -> usize {
        self.tensors.iter().map(|(d, _)| d.len()).sum()
    }

    /// Do `other`'s tensors have exactly this parameter set's shapes?
    pub fn same_shape(&self, other: &QParams) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(&other.tensors)
                .all(|((_, a), (_, b))| a == b)
    }

    /// Serialize to one flat `f32` vector in canonical tensor order
    /// (the hub's wire format for pushing/pulling weight snapshots).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_parameters());
        for (data, _) in &self.tensors {
            out.extend_from_slice(data);
        }
        out
    }

    /// Rebuild from [`QParams::flatten`] output, taking shapes from
    /// `self` (the deserialization half of the hub wire format).
    pub fn unflatten_like(&self, flat: &[f32]) -> Result<QParams> {
        anyhow::ensure!(
            flat.len() == self.num_parameters(),
            "flat parameter vector has {} values, expected {}",
            flat.len(),
            self.num_parameters()
        );
        let mut tensors = Vec::with_capacity(self.tensors.len());
        let mut offset = 0;
        for (data, shape) in &self.tensors {
            tensors.push((flat[offset..offset + data.len()].to_vec(), shape.clone()));
            offset += data.len();
        }
        Ok(QParams { tensors })
    }

    /// Order-sensitive FNV-1a digest over every parameter's raw bits
    /// (feeds the campaign fingerprint that pins hub determinism).
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        for (data, shape) in &self.tensors {
            for &d in shape {
                h.mix(d as u64);
            }
            for &x in data {
                h.mix(x.to_bits() as u64);
            }
        }
        h.finish()
    }

    /// Mean absolute value across all parameters (drift diagnostics).
    pub fn mean_abs(&self) -> f32 {
        let (sum, n) = self.tensors.iter().fold((0.0f64, 0usize), |(s, n), (d, _)| {
            (s + d.iter().map(|x| x.abs() as f64).sum::<f64>(), n + d.len())
        });
        (sum / n.max(1) as f64) as f32
    }
}

/// Deterministic elementwise average of parameter sets.
///
/// Accumulation runs in **input order** with `f64` partial sums, so the
/// result is a pure function of the slice order — the hub passes
/// contributions in job-index order, which is what makes shared-learning
/// merges bit-identical at any worker count. Averaging one parameter set
/// returns it unchanged (bitwise: `f64::from(x) / 1.0` round-trips).
pub fn average_params(params: &[&QParams]) -> Result<QParams> {
    anyhow::ensure!(!params.is_empty(), "cannot average zero parameter sets");
    let first = params[0];
    for p in &params[1..] {
        anyhow::ensure!(p.same_shape(first), "parameter shape mismatch in average");
    }
    let inv = 1.0 / params.len() as f64;
    let mut tensors = Vec::with_capacity(first.tensors.len());
    for (ti, (data0, shape)) in first.tensors.iter().enumerate() {
        let mut acc: Vec<f64> = data0.iter().map(|&x| x as f64).collect();
        for p in &params[1..] {
            for (a, &x) in acc.iter_mut().zip(&p.tensors[ti].0) {
                *a += x as f64;
            }
        }
        let avg: Vec<f32> = acc.into_iter().map(|a| (a * inv) as f32).collect();
        tensors.push((avg, shape.clone()));
    }
    Ok(QParams { tensors })
}

/// Adam optimizer state: first/second moments + step count.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: QParams,
    pub v: QParams,
    pub step: f32,
}

impl AdamState {
    pub fn new(params: &QParams) -> AdamState {
        AdamState { m: params.zeros_like(), v: params.zeros_like(), step: 0.0 }
    }

    /// Order-sensitive digest over moments and step: `m` and `v` fold
    /// in sequence (not symmetrically), so exchanging the two moment
    /// tensors changes the digest.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.mix(self.m.digest());
        h.mix(self.v.digest());
        h.mix(self.step.to_bits() as u64);
        h.finish()
    }
}

/// Deterministic average of Adam states (moments elementwise, step as
/// the plain mean), same ordering contract as [`average_params`].
pub fn average_adam(states: &[&AdamState]) -> Result<AdamState> {
    anyhow::ensure!(!states.is_empty(), "cannot average zero optimizer states");
    let m = average_params(&states.iter().map(|s| &s.m).collect::<Vec<_>>())?;
    let v = average_params(&states.iter().map(|s| &s.v).collect::<Vec<_>>())?;
    let step = (states.iter().map(|s| s.step as f64).sum::<f64>() / states.len() as f64) as f32;
    Ok(AdamState { m, v, step })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn layer_dims_chain() {
        assert_eq!(layer_dims(18, &[64, 64], 13), vec![(18, 64), (64, 64), (64, 13)]);
        assert_eq!(layer_dims(4, &[], 2), vec![(4, 2)]);
    }

    #[test]
    fn init_shapes_and_bounds() {
        let mut rng = Rng::new(0);
        let p = QParams::init(18, &[64, 64], 13, &mut rng);
        assert_eq!(p.tensors.len(), 6);
        assert_eq!(p.num_parameters(), 18 * 64 + 64 + 64 * 64 + 64 + 64 * 13 + 13);
        // weight bound respected, biases zero
        let bound = (6.0f32 / 18.0).sqrt();
        assert!(p.tensors[0].0.iter().all(|w| w.abs() <= bound));
        assert!(p.tensors[1].0.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let mut rng = Rng::new(1);
        let p = QParams::init(8, &[16], 4, &mut rng);
        let z = p.zeros_like();
        assert_eq!(z.num_parameters(), p.num_parameters());
        assert!(z.tensors.iter().all(|(d, _)| d.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn from_flat_validates() {
        assert!(QParams::from_flat(vec![(vec![0.0; 6], vec![2, 3])]).is_ok());
        assert!(QParams::from_flat(vec![(vec![0.0; 5], vec![2, 3])]).is_err());
    }

    #[test]
    fn flatten_roundtrips() {
        let mut rng = Rng::new(3);
        let p = QParams::init(4, &[8], 3, &mut rng);
        let flat = p.flatten();
        assert_eq!(flat.len(), p.num_parameters());
        let q = p.unflatten_like(&flat).unwrap();
        assert_eq!(p, q);
        assert!(p.unflatten_like(&flat[1..]).is_err());
    }

    #[test]
    fn average_of_one_is_bitwise_identity() {
        let mut rng = Rng::new(5);
        let p = QParams::init(6, &[10], 4, &mut rng);
        let avg = average_params(&[&p]).unwrap();
        for ((a, _), (b, _)) in avg.tensors.iter().zip(&p.tensors) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn average_is_elementwise_mean() {
        let a = QParams::from_flat(vec![(vec![1.0, 3.0], vec![2])]).unwrap();
        let b = QParams::from_flat(vec![(vec![3.0, 5.0], vec![2])]).unwrap();
        let avg = average_params(&[&a, &b]).unwrap();
        assert_eq!(avg.tensors[0].0, vec![2.0, 4.0]);
        // Shape mismatch is rejected, not silently truncated.
        let c = QParams::from_flat(vec![(vec![0.0; 3], vec![3])]).unwrap();
        assert!(average_params(&[&a, &c]).is_err());
        assert!(average_params(&[]).is_err());
    }

    #[test]
    fn digest_is_order_and_value_sensitive() {
        let a = QParams::from_flat(vec![(vec![1.0, 2.0], vec![2])]).unwrap();
        let b = QParams::from_flat(vec![(vec![2.0, 1.0], vec![2])]).unwrap();
        assert_eq!(a.digest(), a.clone().digest());
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn adam_average_covers_moments_and_step() {
        let p = QParams::from_flat(vec![(vec![0.0, 0.0], vec![2])]).unwrap();
        let mut s1 = AdamState::new(&p);
        let mut s2 = AdamState::new(&p);
        s1.m.tensors[0].0 = vec![2.0, 0.0];
        s2.m.tensors[0].0 = vec![0.0, 4.0];
        s1.step = 10.0;
        s2.step = 20.0;
        let avg = average_adam(&[&s1, &s2]).unwrap();
        assert_eq!(avg.m.tensors[0].0, vec![1.0, 2.0]);
        assert_eq!(avg.step, 15.0);
        assert_ne!(s1.digest(), avg.digest());
        // Exchanging the two moment tensors must change the digest
        // (regression: an xor-combined digest was m/v-symmetric).
        let swapped = AdamState { m: s1.v.clone(), v: s1.m.clone(), step: s1.step };
        assert_ne!(swapped.digest(), s1.digest());
    }
}
