//! Q-network parameter and optimizer-state containers.
//!
//! The train-step artifact is fully functional (params in → params out),
//! so Rust owns all state between steps as flat `Vec<f32>` buffers in the
//! canonical order `(w1, b1, w2, b2, w3, b3)` matching
//! `python/compile/model.py::param_specs()`.

use anyhow::Result;

use super::xla;
use crate::util::rng::Rng;

/// Layer dims of the Q-net MLP; must match `model.LAYER_DIMS`.
pub fn layer_dims(state_dim: usize, hidden: &[usize], num_actions: usize) -> Vec<(usize, usize)> {
    let mut dims = Vec::new();
    let mut prev = state_dim;
    for &h in hidden {
        dims.push((prev, h));
        prev = h;
    }
    dims.push((prev, num_actions));
    dims
}

/// Flat parameter set: weights and biases in calling order.
#[derive(Debug, Clone, PartialEq)]
pub struct QParams {
    /// `[(data, shape)]` in `(w1, b1, w2, b2, w3, b3)` order.
    pub tensors: Vec<(Vec<f32>, Vec<usize>)>,
}

impl QParams {
    /// He-uniform init matching `model.init_params` semantics (not
    /// bit-identical — different PRNG — but same distribution family).
    pub fn init(state_dim: usize, hidden: &[usize], num_actions: usize, rng: &mut Rng) -> QParams {
        let mut tensors = Vec::new();
        for (d_in, d_out) in layer_dims(state_dim, hidden, num_actions) {
            let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.he_uniform(d_in)).collect();
            tensors.push((w, vec![d_in, d_out]));
            tensors.push((vec![0.0; d_out], vec![d_out]));
        }
        QParams { tensors }
    }

    /// Zeroed clone with identical shapes (Adam moment buffers).
    pub fn zeros_like(&self) -> QParams {
        QParams {
            tensors: self
                .tensors
                .iter()
                .map(|(data, shape)| (vec![0.0; data.len()], shape.clone()))
                .collect(),
        }
    }

    /// Build from flat per-tensor data with explicit shapes.
    pub fn from_flat(tensors: Vec<(Vec<f32>, Vec<usize>)>) -> Result<QParams> {
        for (data, shape) in &tensors {
            let want: usize = shape.iter().product();
            anyhow::ensure!(
                data.len() == want,
                "tensor data {} != shape product {want}",
                data.len()
            );
        }
        Ok(QParams { tensors })
    }

    /// Convert every tensor to an XLA literal (reshaped to its rank).
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.tensors
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            })
            .collect()
    }

    /// Rebuild from output literals (shape metadata kept from self).
    pub fn update_from_literals(&mut self, literals: &[xla::Literal]) -> Result<()> {
        anyhow::ensure!(
            literals.len() == self.tensors.len(),
            "expected {} tensors, got {}",
            self.tensors.len(),
            literals.len()
        );
        for ((data, _), lit) in self.tensors.iter_mut().zip(literals) {
            let v = lit.to_vec::<f32>()?;
            anyhow::ensure!(v.len() == data.len(), "tensor size changed across update");
            *data = v;
        }
        Ok(())
    }

    pub fn num_parameters(&self) -> usize {
        self.tensors.iter().map(|(d, _)| d.len()).sum()
    }

    /// Mean absolute value across all parameters (drift diagnostics).
    pub fn mean_abs(&self) -> f32 {
        let (sum, n) = self.tensors.iter().fold((0.0f64, 0usize), |(s, n), (d, _)| {
            (s + d.iter().map(|x| x.abs() as f64).sum::<f64>(), n + d.len())
        });
        (sum / n.max(1) as f64) as f32
    }
}

/// Adam optimizer state: first/second moments + step count.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: QParams,
    pub v: QParams,
    pub step: f32,
}

impl AdamState {
    pub fn new(params: &QParams) -> AdamState {
        AdamState { m: params.zeros_like(), v: params.zeros_like(), step: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_dims_chain() {
        assert_eq!(layer_dims(18, &[64, 64], 13), vec![(18, 64), (64, 64), (64, 13)]);
        assert_eq!(layer_dims(4, &[], 2), vec![(4, 2)]);
    }

    #[test]
    fn init_shapes_and_bounds() {
        let mut rng = Rng::new(0);
        let p = QParams::init(18, &[64, 64], 13, &mut rng);
        assert_eq!(p.tensors.len(), 6);
        assert_eq!(p.num_parameters(), 18 * 64 + 64 + 64 * 64 + 64 + 64 * 13 + 13);
        // weight bound respected, biases zero
        let bound = (6.0f32 / 18.0).sqrt();
        assert!(p.tensors[0].0.iter().all(|w| w.abs() <= bound));
        assert!(p.tensors[1].0.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let mut rng = Rng::new(1);
        let p = QParams::init(8, &[16], 4, &mut rng);
        let z = p.zeros_like();
        assert_eq!(z.num_parameters(), p.num_parameters());
        assert!(z.tensors.iter().all(|(d, _)| d.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn from_flat_validates() {
        assert!(QParams::from_flat(vec![(vec![0.0; 6], vec![2, 3])]).is_ok());
        assert!(QParams::from_flat(vec![(vec![0.0; 5], vec![2, 3])]).is_err());
    }
}
