//! The AOT/PJRT Q-network engine: compiled PJRT executables plus
//! Rust-owned parameters and optimizer state — the original deep-Q path,
//! preserved behind the [`crate::runtime::QBackend::Aot`] variant.
//!
//! Three entry points (see `python/compile/aot.py`):
//! * `q_forward_1` — Q(s, ·) for one state (ε-greedy action selection);
//! * `q_forward_b` — Q(s, ·) for a replay batch (diagnostics);
//! * `q_train`     — one replay-minibatch Q-learning update (Bellman
//!   targets from the same network — the paper does not use Q-targets —
//!   Huber loss, Adam), returning updated params + moments + loss.
//!
//! Artifacts are compiled for one fixed `(state_dim, num_actions)`
//! layout; [`crate::coordinator::DqnAgent::load`] validates the
//! manifest against the chosen backend. For a dimension-generic engine
//! that needs no artifacts at all, see [`crate::runtime::NativeQNet`].

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::client::{literal_f32_1d, literal_f32_2d, literal_f32_scalar, Executable, RuntimeClient};
use super::params::{AdamState, QParams};
use super::qnet::{argmax, LossRing, TrainBatch};
use super::xla;
use crate::util::rng::Rng;

/// Compiled Q-network + owned training state.
pub struct AotQNet {
    forward_1: Executable,
    forward_b: Executable,
    train: Executable,
    /// Fixed-Q-targets ablation entry point (the paper does not use
    /// Q-targets, §5.2; this exists for the ablation bench).
    train_target: Option<Executable>,
    /// Frozen target-network parameters (ablation only).
    target_params: Option<QParams>,
    pub params: QParams,
    pub opt: AdamState,
    pub state_dim: usize,
    pub num_actions: usize,
    pub replay_batch: usize,
    /// Bounded per-step loss diagnostics (ring + running stats).
    pub loss_history: LossRing,
    /// Device-literal cache of (params, m, v): rebuilt only when the
    /// training step replaces them (§Perf: avoids re-marshalling ~25k
    /// floats on every action selection / train call).
    cached: Option<CachedLiterals>,
}

struct CachedLiterals {
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
}

impl AotQNet {
    /// Compile all three artifacts and initialize parameters.
    pub fn load(client: &RuntimeClient, manifest: &Manifest, rng: &mut Rng) -> Result<AotQNet> {
        let forward_1 = client.load_hlo_text(manifest.hlo_path("q_forward_1")?)?;
        let forward_b = client.load_hlo_text(manifest.hlo_path("q_forward_b")?)?;
        let train = client.load_hlo_text(manifest.hlo_path("q_train")?)?;
        let train_target = match manifest.hlo_path("q_train_target") {
            Ok(path) if path.exists() => Some(client.load_hlo_text(path)?),
            _ => None,
        };
        let params =
            QParams::init(manifest.state_dim, &manifest.hidden, manifest.num_actions, rng);
        let opt = AdamState::new(&params);
        Ok(AotQNet {
            forward_1,
            forward_b,
            train,
            train_target,
            target_params: None,
            params,
            opt,
            state_dim: manifest.state_dim,
            num_actions: manifest.num_actions,
            replay_batch: manifest.replay_batch,
            loss_history: LossRing::default(),
            cached: None,
        })
    }

    /// Replace parameters (e.g. restored from a checkpoint / golden test).
    pub fn set_params(&mut self, params: QParams) {
        self.opt = AdamState::new(&params);
        self.params = params;
        self.cached = None;
        self.target_params = None;
    }

    /// Replace parameters *and* optimizer state together — the hub-pull
    /// entry point for shared learning, where the merged Adam moments
    /// must survive the swap (unlike [`AotQNet::set_params`], which resets
    /// them). Validates shapes (same contract as
    /// [`crate::runtime::NativeQNet::set_state`]) so a mismatched pull
    /// fails here, not as an opaque PJRT arity error mid-train.
    /// Invalidates the device-literal cache; the frozen target network
    /// (ablation mode) is left untouched on purpose, since its refresh
    /// cadence is owned by the agent.
    pub fn set_state(&mut self, params: QParams, opt: AdamState) -> Result<()> {
        anyhow::ensure!(
            params.same_shape(&self.params),
            "replacement parameters do not match this network's shapes"
        );
        anyhow::ensure!(
            opt.m.same_shape(&params) && opt.v.same_shape(&params),
            "replacement optimizer moments do not match the parameters"
        );
        self.params = params;
        self.opt = opt;
        self.cached = None;
        Ok(())
    }

    /// Is the fixed-Q-targets artifact available?
    pub fn has_target_network(&self) -> bool {
        self.train_target.is_some()
    }

    /// Copy the online network into the frozen target (ablation).
    pub fn sync_target(&mut self) {
        self.target_params = Some(self.params.clone());
    }

    /// Ensure the device-literal cache is populated.
    fn ensure_cache(&mut self) -> Result<()> {
        if self.cached.is_none() {
            self.cached = Some(CachedLiterals {
                params: self.params.to_literals()?,
                m: self.opt.m.to_literals()?,
                v: self.opt.v.to_literals()?,
            });
        }
        Ok(())
    }

    /// The populated device-literal cache (call [`AotQNet::ensure_cache`]
    /// first; split so callers can hold `&self` borrows of the cache).
    fn cache(&self) -> Result<&CachedLiterals> {
        self.cached.as_ref().context("device-literal cache not populated")
    }

    /// Q(s, ·) for a single state.
    pub fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            state.len() == self.state_dim,
            "state has {} features, expected {}",
            state.len(),
            self.state_dim
        );
        let state_lit = literal_f32_2d(state, 1, self.state_dim)?;
        self.ensure_cache()?;
        let cache = self.cache()?;
        let mut inputs: Vec<&xla::Literal> = cache.params.iter().collect();
        inputs.push(&state_lit);
        let out = self.forward_1.run_refs(&inputs)?;
        let q = out[0].to_vec::<f32>().context("q_forward_1 output")?;
        anyhow::ensure!(q.len() == self.num_actions, "bad q length {}", q.len());
        Ok(q)
    }

    /// Greedy action for a state (argmax over Q).
    pub fn greedy_action(&mut self, state: &[f32]) -> Result<usize> {
        let q = self.q_values(state)?;
        Ok(argmax(&q))
    }

    /// Q(s, ·) for a full replay batch (`[B, state_dim]` flat).
    pub fn q_values_batch(&mut self, states: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            states.len() == self.replay_batch * self.state_dim,
            "batch states size {} != {}",
            states.len(),
            self.replay_batch * self.state_dim
        );
        let states_lit = literal_f32_2d(states, self.replay_batch, self.state_dim)?;
        self.ensure_cache()?;
        let cache = self.cache()?;
        let mut inputs: Vec<&xla::Literal> = cache.params.iter().collect();
        inputs.push(&states_lit);
        let out = self.forward_b.run_refs(&inputs)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// One Q-learning update on a replay minibatch. Returns the loss.
    pub fn train_step(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<f32> {
        batch.validate(self.replay_batch, self.state_dim, self.num_actions)?;
        let b = self.replay_batch;

        let step_lit = literal_f32_scalar(self.opt.step);
        let batch_lits = [
            literal_f32_2d(&batch.states, b, self.state_dim)?,
            literal_f32_2d(&batch.actions_onehot, b, self.num_actions)?,
            literal_f32_1d(&batch.rewards),
            literal_f32_2d(&batch.next_states, b, self.state_dim)?,
            literal_f32_1d(&batch.done),
            literal_f32_scalar(lr),
            literal_f32_scalar(gamma),
        ];
        self.ensure_cache()?;
        let cache = self.cache()?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(26);
        inputs.extend(cache.params.iter());
        inputs.extend(cache.m.iter());
        inputs.extend(cache.v.iter());
        inputs.push(&step_lit);
        inputs.extend(batch_lits.iter());

        let mut out = self.train.run_refs(&inputs)?;
        let n = self.params.tensors.len();
        anyhow::ensure!(
            out.len() == 3 * n + 2,
            "train output arity {} != {}",
            out.len(),
            3 * n + 2
        );

        self.params.update_from_literals(&out[..n])?;
        self.opt.m.update_from_literals(&out[n..2 * n])?;
        self.opt.v.update_from_literals(&out[2 * n..3 * n])?;
        self.opt.step = out[3 * n].to_vec::<f32>()?[0];
        let loss = out[3 * n + 1].to_vec::<f32>()?[0];
        anyhow::ensure!(loss.is_finite(), "train step produced non-finite loss {loss}");
        self.loss_history.push(loss);
        // Recycle the output literals as the new device cache: the next
        // call uploads nothing but the batch.
        let v: Vec<xla::Literal> = out.drain(2 * n..3 * n).collect();
        let m: Vec<xla::Literal> = out.drain(n..2 * n).collect();
        let params: Vec<xla::Literal> = out.drain(..n).collect();
        self.cached = Some(CachedLiterals { params, m, v });
        Ok(loss)
    }

    /// One Q-learning update with Bellman targets from the *frozen*
    /// target network (fixed-Q-targets ablation; not in the paper).
    /// Call [`AotQNet::sync_target`] periodically to refresh the target.
    pub fn train_step_with_target(
        &mut self,
        batch: &TrainBatch,
        lr: f32,
        gamma: f32,
    ) -> Result<f32> {
        anyhow::ensure!(
            self.train_target.is_some(),
            "q_train_target artifact not built (re-run `make artifacts`)"
        );
        batch.validate(self.replay_batch, self.state_dim, self.num_actions)?;
        if self.target_params.is_none() {
            self.target_params = Some(self.params.clone());
        }
        let b = self.replay_batch;

        let target_lits = match self.target_params.as_ref() {
            Some(target) => target.to_literals()?,
            None => anyhow::bail!("target network not initialized"),
        };
        let step_lit = literal_f32_scalar(self.opt.step);
        let batch_lits = [
            literal_f32_2d(&batch.states, b, self.state_dim)?,
            literal_f32_2d(&batch.actions_onehot, b, self.num_actions)?,
            literal_f32_1d(&batch.rewards),
            literal_f32_2d(&batch.next_states, b, self.state_dim)?,
            literal_f32_1d(&batch.done),
            literal_f32_scalar(lr),
            literal_f32_scalar(gamma),
        ];
        self.ensure_cache()?;
        let cache = self.cache()?;
        let exe = self
            .train_target
            .as_ref()
            .context("q_train_target artifact not built")?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(32);
        inputs.extend(cache.params.iter());
        inputs.extend(target_lits.iter());
        inputs.extend(cache.m.iter());
        inputs.extend(cache.v.iter());
        inputs.push(&step_lit);
        inputs.extend(batch_lits.iter());

        let mut out = exe.run_refs(&inputs)?;
        let n = self.params.tensors.len();
        anyhow::ensure!(out.len() == 3 * n + 2, "target train output arity {}", out.len());
        self.params.update_from_literals(&out[..n])?;
        self.opt.m.update_from_literals(&out[n..2 * n])?;
        self.opt.v.update_from_literals(&out[2 * n..3 * n])?;
        self.opt.step = out[3 * n].to_vec::<f32>()?[0];
        let loss = out[3 * n + 1].to_vec::<f32>()?[0];
        anyhow::ensure!(loss.is_finite(), "non-finite loss {loss}");
        self.loss_history.push(loss);
        let v: Vec<xla::Literal> = out.drain(2 * n..3 * n).collect();
        let m: Vec<xla::Literal> = out.drain(n..2 * n).collect();
        let params: Vec<xla::Literal> = out.drain(..n).collect();
        self.cached = Some(CachedLiterals { params, m, v });
        Ok(loss)
    }
}
