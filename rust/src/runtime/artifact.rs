//! Artifact manifest: shapes/dtypes of every AOT-lowered entry point.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` alongside the
//! HLO text; we validate it at load time so a stale artifact directory
//! fails fast with a clear message instead of a shape error deep in PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .at(&["shape"])?
            .as_arr()
            .context("shape not an array")?
            .iter()
            .map(|d| d.as_usize().context("non-integer dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.at(&["dtype"])?.as_str().context("dtype not a string")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One entry point (HLO module) in the artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.json`: model constants + per-artifact signatures.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub state_dim: usize,
    pub num_actions: usize,
    pub hidden: Vec<usize>,
    pub replay_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut artifacts = BTreeMap::new();
        for (name, entry) in v.at(&["artifacts"])?.as_obj().context("artifacts not an object")? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .at(&[key])?
                    .as_arr()
                    .with_context(|| format!("{key} not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: entry.at(&["file"])?.as_str().context("file")?.to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }

        let usize_at = |key: &str| -> Result<usize> {
            v.at(&[key])?.as_usize().with_context(|| format!("{key} not an integer"))
        };
        let man = Manifest {
            state_dim: usize_at("state_dim")?,
            num_actions: usize_at("num_actions")?,
            hidden: v
                .at(&["hidden"])?
                .as_arr()
                .context("hidden")?
                .iter()
                .map(|d| d.as_usize().context("hidden dim"))
                .collect::<Result<Vec<_>>>()?,
            replay_batch: usize_at("replay_batch")?,
            artifacts,
            dir,
        };
        man.validate()?;
        Ok(man)
    }

    /// Structural validation. Whether the dimensions fit a particular
    /// backend's state/action layout is checked where the network is
    /// constructed ([`crate::coordinator::DqnAgent::load`]), since
    /// artifacts are compiled per backend.
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.state_dim > 0, "artifact state_dim must be positive");
        anyhow::ensure!(self.num_actions > 0, "artifact num_actions must be positive");
        for required in ["q_forward_1", "q_forward_b", "q_train"] {
            anyhow::ensure!(
                self.artifacts.contains_key(required),
                "manifest missing artifact {required:?}"
            );
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

/// Locate the artifacts directory: `$AITUNING_ARTIFACTS` or `./artifacts`
/// relative to the crate root / current dir.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("AITUNING_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // Fall back to the manifest dir relative to the compiled crate, so
    // `cargo test` works from any working directory.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parses() {
        let v = Json::parse(r#"{"shape": [2, 3], "dtype": "float32"}"#).unwrap();
        let t = TensorSpec::from_json(&v).unwrap();
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.dtype, "float32");
    }

    #[test]
    fn scalar_spec_counts_one() {
        let v = Json::parse(r#"{"shape": [], "dtype": "float32"}"#).unwrap();
        assert_eq!(TensorSpec::from_json(&v).unwrap().element_count(), 1);
    }

    #[test]
    fn manifest_load_real_artifacts() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        // 3 paper-faithful entry points + the Q-target ablation.
        assert_eq!(man.artifacts.len(), 4);
        assert!(man.artifacts.contains_key("q_train_target"));
        let train = man.artifact("q_train").unwrap();
        assert_eq!(train.inputs.len(), 26);
        assert_eq!(train.outputs.len(), 20);
    }
}
