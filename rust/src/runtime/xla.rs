//! Indirection over the XLA/PJRT bindings.
//!
//! With the `pjrt` cargo feature enabled this re-exports the external
//! `xla` bindings crate (which must be supplied to the build — it is
//! not vendored in the offline image). Without the feature, a stub with
//! the same surface is compiled instead: every entry point that would
//! touch PJRT returns a descriptive error, starting with
//! [`PjRtClient::cpu`], so the DQN path fails fast with a clear message
//! while the tabular agent and the whole simulator stack stay fully
//! usable offline.
//!
//! Contract note (shared learning): the hub's param-averaging and
//! serialization entry points ([`crate::runtime::average_params`],
//! `QParams::flatten`/`unflatten_like`) operate on the host-side
//! `Vec<f32>` buffers only and deliberately never touch this surface —
//! merged state re-enters PJRT through the existing
//! `QParams::to_literals` upload path, so the stub needs no new entry
//! points and stays in sync with the real binding by construction.
//! Keep it that way if the averaging ops grow.

#[cfg(feature = "pjrt")]
pub use ::xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error surfaced by every stubbed PJRT entry point.
    #[derive(Debug, Clone)]
    pub struct Error(String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(&self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable<T>() -> Result<T, Error> {
        Err(Error(
            "XLA/PJRT backend not compiled in (build with the `pjrt` feature and the \
             external `xla` crate); use the native DQN engine (--agent dqn) or the \
             tabular agent instead — neither needs PJRT"
                .to_string(),
        ))
    }

    /// Host-side tensor stand-in.
    #[derive(Debug, Clone)]
    pub struct Literal;

    impl Literal {
        pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            unavailable()
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            unavailable()
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
            unavailable()
        }
    }

    impl From<f32> for Literal {
        fn from(_v: f32) -> Literal {
            Literal
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            unavailable()
        }

        pub fn platform_name(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unavailable()
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
            unavailable()
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unavailable()
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unavailable()
        }
    }
}
