//! Dense-layer forward/backward entry points for the native Q-network —
//! thin wrappers over the [`kernels`](super::kernels) seam — plus the
//! Huber loss.
//!
//! Determinism discipline (shared with `runtime/params.rs`): parameters
//! and activations are stored as `f32`; every dot product and batch
//! reduction accumulates partial sums in `f64` **in index order** and
//! casts back to `f32` exactly once per output element. Both kernels
//! behind the seam honor this identically (the blocked one by the
//! construction proved in `kernels.rs`), the code is single-threaded
//! and branch-free over data values (apart from the ReLU max), so two
//! calls with identical inputs are bit-identical on any machine the
//! workspace targets — the property the campaign engine's
//! 1-vs-N-worker fingerprint contract rests on.
//!
//! Weight layout matches [`crate::runtime::QParams::init`]: a layer's
//! weight tensor is row-major `[d_in, d_out]` (`w[i * d_out + j]`
//! connects input `i` to output `j`), biases are `[d_out]`.

use super::kernels::{self, DenseKernel};

/// Huber transition point (standard DQN choice; matches
/// `python/compile/model.py::HUBER_DELTA`).
pub(super) const HUBER_DELTA: f32 = 1.0;

/// Huber loss of one residual.
pub(super) fn huber(err: f32) -> f32 {
    let a = err.abs();
    let quad = a.min(HUBER_DELTA);
    0.5 * quad * quad + HUBER_DELTA * (a - quad)
}

/// d huber(err) / d err — the clipped residual.
pub(super) fn huber_grad(err: f32) -> f32 {
    err.clamp(-HUBER_DELTA, HUBER_DELTA)
}

/// `y[b, j] = act(Σ_i x[b, i] · w[i, j] + bias[j])` for a
/// `[batch, d_in]` input and a row-major `[d_in, d_out]` weight matrix,
/// with optional ReLU, evaluated by `kernel`.
#[allow(clippy::too_many_arguments)]
pub(super) fn dense_forward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    kernels::dense_forward(kernel, x, batch, d_in, w, bias, d_out, relu)
}

/// [`dense_forward`] into a caller-owned buffer (cleared and resized) —
/// the allocation-free layer step the no-store batched forward
/// ping-pongs through.
#[allow(clippy::too_many_arguments)]
pub(super) fn dense_forward_into(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
    y: &mut Vec<f32>,
) {
    kernels::dense_forward_into(kernel, x, batch, d_in, w, bias, d_out, relu, y);
}

/// Backward pass of one dense layer given `dz = dL/d(pre-activation
/// output)` (`[batch, d_out]`) and the layer's input activations `x`
/// (`[batch, d_in]`), evaluated by `kernel`. Returns `(dw, db, dx)`;
/// the caller applies the previous layer's ReLU mask to `dx` before
/// recursing.
pub(super) fn dense_backward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    kernels::dense_backward(kernel, x, batch, d_in, w, d_out, dz)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn forward_matches_hand_computation() {
        // x = [1, 2], w = [[1, 2], [3, 4]] (row-major), b = [0.5, -0.5]:
        // y = [1·1 + 2·3 + 0.5, 1·2 + 2·4 − 0.5] = [7.5, 9.5].
        for kernel in DenseKernel::ALL {
            let y = dense_forward(
                kernel,
                &[1.0, 2.0],
                1,
                2,
                &[1.0, 2.0, 3.0, 4.0],
                &[0.5, -0.5],
                2,
                false,
            );
            assert_eq!(y, vec![7.5, 9.5], "{}", kernel.name());
        }
    }

    #[test]
    fn relu_clamps_negative_preactivations() {
        for kernel in DenseKernel::ALL {
            let y = dense_forward(kernel, &[1.0], 1, 1, &[-2.0], &[0.5], 1, true);
            assert_eq!(y, vec![0.0], "{}", kernel.name());
            let lin = dense_forward(kernel, &[1.0], 1, 1, &[-2.0], &[0.5], 1, false);
            assert_eq!(lin, vec![-1.5], "{}", kernel.name());
        }
    }

    #[test]
    fn backward_matches_hand_computation() {
        // One sample, x = [1, 2], dz = [1, -1], w = [[1, 2], [3, 4]]:
        // dw = xᵀ dz = [[1, -1], [2, -2]], db = [1, -1],
        // dx = dz · wᵀ = [1·1 − 1·2, 1·3 − 1·4] = [-1, -1].
        for kernel in DenseKernel::ALL {
            let (dw, db, dx) = dense_backward(
                kernel,
                &[1.0, 2.0],
                1,
                2,
                &[1.0, 2.0, 3.0, 4.0],
                2,
                &[1.0, -1.0],
            );
            assert_eq!(dw, vec![1.0, -1.0, 2.0, -2.0], "{}", kernel.name());
            assert_eq!(db, vec![1.0, -1.0], "{}", kernel.name());
            assert_eq!(dx, vec![-1.0, -1.0], "{}", kernel.name());
        }
    }

    #[test]
    fn batch_reductions_sum_over_samples() {
        // Two identical samples double dw and db but keep per-sample dx.
        let x = [1.0, 2.0, 1.0, 2.0];
        let dz = [1.0, -1.0, 1.0, -1.0];
        for kernel in DenseKernel::ALL {
            let (dw, db, dx) = dense_backward(kernel, &x, 2, 2, &[1.0, 2.0, 3.0, 4.0], 2, &dz);
            assert_eq!(dw, vec![2.0, -2.0, 4.0, -4.0], "{}", kernel.name());
            assert_eq!(db, vec![2.0, -2.0], "{}", kernel.name());
            assert_eq!(dx, vec![-1.0, -1.0, -1.0, -1.0], "{}", kernel.name());
        }
    }

    #[test]
    fn huber_is_quadratic_then_linear() {
        assert_eq!(huber(0.5), 0.125);
        assert_eq!(huber(-0.5), 0.125);
        assert_eq!(huber(1.0), 0.5);
        assert_eq!(huber(3.5), 3.0); // 0.5 + (3.5 − 1)
        assert_eq!(huber_grad(0.25), 0.25);
        assert_eq!(huber_grad(5.0), 1.0);
        assert_eq!(huber_grad(-5.0), -1.0);
    }
}
