//! Dense-layer forward/backward primitives for the native Q-network.
//!
//! Determinism discipline (shared with `runtime/params.rs`): parameters
//! and activations are stored as `f32`; every dot product and batch
//! reduction accumulates partial sums in `f64` **in index order** and
//! casts back to `f32` exactly once per output element. The code is
//! single-threaded and branch-free over data values (apart from the
//! ReLU max), so two calls with identical inputs are bit-identical on
//! any machine the workspace targets — the property the campaign
//! engine's 1-vs-N-worker fingerprint contract rests on.
//!
//! Weight layout matches [`crate::runtime::QParams::init`]: a layer's
//! weight tensor is row-major `[d_in, d_out]` (`w[i * d_out + j]`
//! connects input `i` to output `j`), biases are `[d_out]`.

/// Huber transition point (standard DQN choice; matches
/// `python/compile/model.py::HUBER_DELTA`).
pub(super) const HUBER_DELTA: f32 = 1.0;

/// Huber loss of one residual.
pub(super) fn huber(err: f32) -> f32 {
    let a = err.abs();
    let quad = a.min(HUBER_DELTA);
    0.5 * quad * quad + HUBER_DELTA * (a - quad)
}

/// d huber(err) / d err — the clipped residual.
pub(super) fn huber_grad(err: f32) -> f32 {
    err.clamp(-HUBER_DELTA, HUBER_DELTA)
}

/// `y[b, j] = act(Σ_i x[b, i] · w[i, j] + bias[j])` for a
/// `[batch, d_in]` input and a row-major `[d_in, d_out]` weight matrix,
/// with optional ReLU.
pub(super) fn dense_forward(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    let mut y = vec![0.0f32; batch * d_out];
    for b in 0..batch {
        let row = &x[b * d_in..(b + 1) * d_in];
        let out = &mut y[b * d_out..(b + 1) * d_out];
        for (j, slot) in out.iter_mut().enumerate() {
            let mut acc = bias[j] as f64;
            for (i, &xi) in row.iter().enumerate() {
                acc += xi as f64 * w[i * d_out + j] as f64;
            }
            let v = acc as f32;
            *slot = if relu { v.max(0.0) } else { v };
        }
    }
    y
}

/// Backward pass of one dense layer given `dz = dL/d(pre-activation
/// output)` (`[batch, d_out]`) and the layer's input activations `x`
/// (`[batch, d_in]`). Returns `(dw, db, dx)`; the caller applies the
/// previous layer's ReLU mask to `dx` before recursing.
pub(super) fn dense_backward(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dz.len(), batch * d_out);
    // dw[i, j] = Σ_b x[b, i] · dz[b, j] — f64 partials in batch order.
    let mut dw = vec![0.0f32; d_in * d_out];
    for i in 0..d_in {
        for j in 0..d_out {
            let mut acc = 0.0f64;
            for b in 0..batch {
                acc += x[b * d_in + i] as f64 * dz[b * d_out + j] as f64;
            }
            dw[i * d_out + j] = acc as f32;
        }
    }
    // db[j] = Σ_b dz[b, j].
    let mut db = vec![0.0f32; d_out];
    for (j, slot) in db.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for b in 0..batch {
            acc += dz[b * d_out + j] as f64;
        }
        *slot = acc as f32;
    }
    // dx[b, i] = Σ_j dz[b, j] · w[i, j].
    let mut dx = vec![0.0f32; batch * d_in];
    for b in 0..batch {
        for i in 0..d_in {
            let mut acc = 0.0f64;
            for j in 0..d_out {
                acc += dz[b * d_out + j] as f64 * w[i * d_out + j] as f64;
            }
            dx[b * d_in + i] = acc as f32;
        }
    }
    (dw, db, dx)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn forward_matches_hand_computation() {
        // x = [1, 2], w = [[1, 2], [3, 4]] (row-major), b = [0.5, -0.5]:
        // y = [1·1 + 2·3 + 0.5, 1·2 + 2·4 − 0.5] = [7.5, 9.5].
        let y = dense_forward(&[1.0, 2.0], 1, 2, &[1.0, 2.0, 3.0, 4.0], &[0.5, -0.5], 2, false);
        assert_eq!(y, vec![7.5, 9.5]);
    }

    #[test]
    fn relu_clamps_negative_preactivations() {
        let y = dense_forward(&[1.0], 1, 1, &[-2.0], &[0.5], 1, true);
        assert_eq!(y, vec![0.0]);
        let lin = dense_forward(&[1.0], 1, 1, &[-2.0], &[0.5], 1, false);
        assert_eq!(lin, vec![-1.5]);
    }

    #[test]
    fn backward_matches_hand_computation() {
        // One sample, x = [1, 2], dz = [1, -1], w = [[1, 2], [3, 4]]:
        // dw = xᵀ dz = [[1, -1], [2, -2]], db = [1, -1],
        // dx = dz · wᵀ = [1·1 − 1·2, 1·3 − 1·4] = [-1, -1].
        let (dw, db, dx) =
            dense_backward(&[1.0, 2.0], 1, 2, &[1.0, 2.0, 3.0, 4.0], 2, &[1.0, -1.0]);
        assert_eq!(dw, vec![1.0, -1.0, 2.0, -2.0]);
        assert_eq!(db, vec![1.0, -1.0]);
        assert_eq!(dx, vec![-1.0, -1.0]);
    }

    #[test]
    fn batch_reductions_sum_over_samples() {
        // Two identical samples double dw and db but keep per-sample dx.
        let x = [1.0, 2.0, 1.0, 2.0];
        let dz = [1.0, -1.0, 1.0, -1.0];
        let (dw, db, dx) = dense_backward(&x, 2, 2, &[1.0, 2.0, 3.0, 4.0], 2, &dz);
        assert_eq!(dw, vec![2.0, -2.0, 4.0, -4.0]);
        assert_eq!(db, vec![2.0, -2.0]);
        assert_eq!(dx, vec![-1.0, -1.0, -1.0, -1.0]);
    }

    #[test]
    fn huber_is_quadratic_then_linear() {
        assert_eq!(huber(0.5), 0.125);
        assert_eq!(huber(-0.5), 0.125);
        assert_eq!(huber(1.0), 0.5);
        assert_eq!(huber(3.5), 3.0); // 0.5 + (3.5 − 1)
        assert_eq!(huber_grad(0.25), 0.25);
        assert_eq!(huber_grad(5.0), 1.0);
        assert_eq!(huber_grad(-5.0), -1.0);
    }
}
