//! The native Q-network engine: a pure-Rust, dependency-free MLP with
//! backprop, Huber loss and Adam — the default [`crate::runtime::QNet`]
//! backend.
//!
//! Why it exists: the AOT/PJRT path executes artifacts compiled for one
//! fixed `(state_dim, num_actions)` layout, so deep-RL tuning used to
//! work only on backends that had a compiled artifact set (historically
//! just the coarrays 18×13). The native engine is **dimension-generic**
//! — construct it straight from any
//! [`crate::backend::TunableRuntime`]'s `state_dim`/`num_actions`, no
//! manifest, no Python, no PJRT — which puts the paper's actual
//! algorithm (deep Q-network, experience replay, no Q-target, §5.2) on
//! every backend.
//!
//! Determinism rules (the campaign fingerprint contract):
//!
//! * He-uniform init draws from the caller's [`Rng`] in canonical
//!   `(w1, b1, w2, b2, …)` order — same seed, same weights, bitwise.
//! * All math is `f32` storage with **order-sequenced `f64`
//!   accumulation** ([`mlp`]), the same discipline as
//!   [`crate::runtime::average_params`]; no parallelism, no
//!   hash-ordered iteration anywhere.
//! * [`NativeQNet::train_grads`] is a pure function of
//!   `(params, batch, gamma)`; [`adam_step`] is a pure function of
//!   `(params, opt, grads, lr)`. Training is their composition, so two
//!   identically-seeded sessions replay each other exactly.
//!
//! Beyond parity with the fused `q_train` artifact, the native engine
//! exposes what the fused artifact cannot: realized **per-sample TD
//! errors** (adaptive prioritized replay feedback) and **raw
//! gradients** without applying them ([`NativeQNet::train_grads`]),
//! which is what the hub's gradient-level `MergeMode::Grads` merge
//! consumes.

mod adam;
mod fused;
mod kernels;
mod mlp;

pub use adam::{adam_step, ADAM_BETA1, ADAM_BETA2, ADAM_EPS};
pub use fused::{FusedGrads, FusedTrainer};
pub use kernels::{DenseKernel, PackedWeights, DX_LANES, FWD_LANES};

use anyhow::{Context, Result};

use crate::runtime::params::layer_dims;
use crate::runtime::{AdamState, LossRing, QParams, TrainBatch, TrainOutcome};
use crate::util::rng::Rng;

/// Hidden-layer widths used when a caller does not specify them —
/// matching the AOT model (`python/compile/model.py::HIDDEN`), so the
/// native and artifact engines train the same architecture.
pub const DEFAULT_HIDDEN: [usize; 2] = [64, 64];

/// Default replay minibatch size (matches `model.REPLAY_BATCH`).
pub const DEFAULT_REPLAY_BATCH: usize = 32;

/// The native deep Q-network: parameters, Adam state and the layer
/// plan, everything host-side.
#[derive(Debug, Clone)]
pub struct NativeQNet {
    pub params: QParams,
    pub opt: AdamState,
    state_dim: usize,
    num_actions: usize,
    hidden: Vec<usize>,
    pub replay_batch: usize,
    /// Bounded training-loss diagnostics (ring + running stats).
    pub losses: LossRing,
    /// Which dense kernel evaluates forward/backward passes. Not part
    /// of any digest or snapshot: both kernels are bit-identical
    /// (`kernels.rs`), so this is a pure throughput knob.
    kernel: DenseKernel,
}

impl NativeQNet {
    /// Fresh network with He-uniform weights drawn from `rng`.
    pub fn new(
        state_dim: usize,
        hidden: &[usize],
        num_actions: usize,
        replay_batch: usize,
        rng: &mut Rng,
    ) -> NativeQNet {
        assert!(state_dim > 0 && num_actions > 0 && replay_batch > 0);
        let params = QParams::init(state_dim, hidden, num_actions, rng);
        let opt = AdamState::new(&params);
        NativeQNet {
            params,
            opt,
            state_dim,
            num_actions,
            hidden: hidden.to_vec(),
            replay_batch,
            losses: LossRing::default(),
            kernel: DenseKernel::default(),
        }
    }

    /// Standard-architecture network for a backend's dimensions.
    pub fn with_default_shape(state_dim: usize, num_actions: usize, rng: &mut Rng) -> NativeQNet {
        NativeQNet::new(state_dim, &DEFAULT_HIDDEN, num_actions, DEFAULT_REPLAY_BATCH, rng)
    }

    pub fn state_dim(&self) -> usize {
        self.state_dim
    }

    pub fn num_actions(&self) -> usize {
        self.num_actions
    }

    pub fn hidden(&self) -> &[usize] {
        &self.hidden
    }

    /// The dense kernel this network dispatches to.
    pub fn kernel(&self) -> DenseKernel {
        self.kernel
    }

    /// Switch the dense kernel. Safe at any point in training: the
    /// kernels are bitwise-identical, so this can never change a
    /// trajectory or a fingerprint — only how fast it is produced.
    pub fn set_kernel(&mut self, kernel: DenseKernel) {
        self.kernel = kernel;
    }

    /// Replace parameters *and* optimizer state together (the hub-pull
    /// entry point; merged Adam moments survive the swap).
    pub fn set_state(&mut self, params: QParams, opt: AdamState) -> Result<()> {
        anyhow::ensure!(
            params.same_shape(&self.params),
            "replacement parameters do not match this network's shapes"
        );
        anyhow::ensure!(
            opt.m.same_shape(&params) && opt.v.same_shape(&params),
            "replacement optimizer moments do not match the parameters"
        );
        self.params = params;
        self.opt = opt;
        Ok(())
    }

    /// `(d_in, d_out)` per layer, in parameter order.
    fn dims(&self) -> Vec<(usize, usize)> {
        layer_dims(self.state_dim, &self.hidden, self.num_actions)
    }

    /// Forward pass keeping every layer's activations (`acts[0]` is the
    /// input; `acts[l + 1]` is layer `l`'s output, post-ReLU for hidden
    /// layers).
    fn forward_acts(&self, states: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let dims = self.dims();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.len() + 1);
        acts.push(states.to_vec());
        for (l, &(d_in, d_out)) in dims.iter().enumerate() {
            let relu = l + 1 < dims.len();
            let w = &self.params.tensors[2 * l].0;
            let b = &self.params.tensors[2 * l + 1].0;
            let y = mlp::dense_forward(
                self.kernel,
                acts[l].as_slice(),
                batch,
                d_in,
                w,
                b,
                d_out,
                relu,
            );
            acts.push(y);
        }
        acts
    }

    /// One full forward pass over a `[batch, state_dim]` matrix,
    /// returning the `[batch, num_actions]` Q-value matrix. One blocked
    /// GEMM per layer instead of `batch` single-state passes — the
    /// throughput entry point the batched action-selection stack
    /// ([`crate::coordinator::Agent::q_values_batch`] and the campaign
    /// round's shared greedy selection) bottoms out in. Row `r` of the
    /// result is bit-identical to `q_values(&states[r * state_dim..])`.
    ///
    /// Selection-only, so no intermediate activation survives the call:
    /// layers ping-pong between two buffers instead of materializing
    /// the full `forward_acts` stack (which only training needs).
    pub fn forward_batch(&self, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch > 0 && states.len() == batch * self.state_dim,
            "batch states size {} != {} x {}",
            states.len(),
            batch,
            self.state_dim
        );
        let dims = self.dims();
        let mut act = states.to_vec();
        let mut hold = Vec::new();
        for (l, &(d_in, d_out)) in dims.iter().enumerate() {
            let relu = l + 1 < dims.len();
            let w = &self.params.tensors[2 * l].0;
            let b = &self.params.tensors[2 * l + 1].0;
            mlp::dense_forward_into(self.kernel, &act, batch, d_in, w, b, d_out, relu, &mut hold);
            std::mem::swap(&mut act, &mut hold);
        }
        Ok(act)
    }

    /// Q(s, ·) for a `[batch, state_dim]` flat slice of states.
    pub fn q_values_batch(&self, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.forward_batch(states, batch)
    }

    /// Q(s, ·) for a single state.
    pub fn q_values(&self, state: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            state.len() == self.state_dim,
            "state has {} features, expected {}",
            state.len(),
            self.state_dim
        );
        self.q_values_batch(state, 1)
    }

    /// The Q-learning loss of `batch` under the current parameters
    /// (no gradients, no state change) — diagnostics and the
    /// finite-difference gradient checks.
    pub fn loss(&self, batch: &TrainBatch, gamma: f32) -> Result<f32> {
        let (_, loss, _) = self.per_sample_grads(batch, gamma, false)?;
        Ok(loss)
    }

    /// Raw gradients of the Q-learning loss on `batch` — Bellman
    /// targets from the same network (no Q-target, §5.2), Huber loss —
    /// **without applying them**. Returns `(grads, loss, td_errors)`;
    /// `td_errors[i] = pred_i − target_i` in batch row order. Pure:
    /// touches no network state.
    pub fn train_grads(&self, batch: &TrainBatch, gamma: f32) -> Result<(QParams, f32, Vec<f32>)> {
        let (grads, loss, td) = self.per_sample_grads(batch, gamma, true)?;
        Ok((grads.context("gradients requested but not produced")?, loss, td))
    }

    /// One Q-learning update: compute gradients, apply one [`adam_step`]
    /// and record the loss. Returns the outcome (with realized per-
    /// sample TD errors — the adaptive-PER feedback signal the fused
    /// AOT artifact cannot produce) plus the raw gradients that were
    /// applied (the gradient-merge push payload).
    pub fn train_step(
        &mut self,
        batch: &TrainBatch,
        lr: f32,
        gamma: f32,
    ) -> Result<(TrainOutcome, QParams)> {
        let (grads, loss, td_errors) = self.train_grads(batch, gamma)?;
        anyhow::ensure!(loss.is_finite(), "train step produced non-finite loss {loss}");
        adam_step(&mut self.params, &mut self.opt, &grads, lr)?;
        self.losses.push(loss);
        Ok((TrainOutcome { loss, td_errors: Some(td_errors) }, grads))
    }

    /// Apply externally computed gradients exactly as [`train_step`]
    /// would apply its own: finiteness gate, one [`adam_step`], record
    /// the loss. The fused-trainer completion path — a worker whose
    /// round gradients were produced by
    /// [`FusedTrainer::train_grads`] finishes its update here, and
    /// because the sequence below mirrors `train_step` line for line
    /// after the gradient computation, `train_step(batch, …)` and
    /// `train_grads(batch, …) → apply_train(…)` leave bit-identical
    /// network state.
    ///
    /// [`train_step`]: NativeQNet::train_step
    pub fn apply_train(&mut self, grads: &QParams, loss: f32, lr: f32) -> Result<()> {
        anyhow::ensure!(loss.is_finite(), "train step produced non-finite loss {loss}");
        adam_step(&mut self.params, &mut self.opt, grads, lr)?;
        self.losses.push(loss);
        Ok(())
    }

    /// Shared loss/gradient core. `want_grads = false` skips the
    /// backward pass (loss-only probes).
    fn per_sample_grads(
        &self,
        batch: &TrainBatch,
        gamma: f32,
        want_grads: bool,
    ) -> Result<(Option<QParams>, f32, Vec<f32>)> {
        let b = batch.rewards.len();
        anyhow::ensure!(b > 0, "empty train batch");
        batch.validate(b, self.state_dim, self.num_actions)?;
        let a = self.num_actions;

        let acts = self.forward_acts(&batch.states, b);
        let q = acts.last().context("forward produced no activations")?;
        let q_next = self.q_values_batch(&batch.next_states, b)?;

        // Per-sample targets, residuals and dL/dq rows.
        let mut dq = vec![0.0f32; b * a];
        let mut td_errors = Vec::with_capacity(b);
        let mut loss_acc = 0.0f64;
        for i in 0..b {
            let max_next = q_next[i * a..(i + 1) * a]
                .iter()
                .cloned()
                .fold(f32::NEG_INFINITY, f32::max);
            let target = batch.rewards[i] + gamma * (1.0 - batch.done[i]) * max_next;
            let mut pred = 0.0f64;
            for j in 0..a {
                pred += q[i * a + j] as f64 * batch.actions_onehot[i * a + j] as f64;
            }
            let err = pred as f32 - target;
            td_errors.push(err);
            loss_acc += mlp::huber(err) as f64;
            if want_grads {
                // d mean-Huber / d pred_i, routed to the acted entry.
                let g = mlp::huber_grad(err) / b as f32;
                for j in 0..a {
                    dq[i * a + j] = g * batch.actions_onehot[i * a + j];
                }
            }
        }
        let loss = (loss_acc / b as f64) as f32;
        if !want_grads {
            return Ok((None, loss, td_errors));
        }

        // Backprop through the layers, newest first; ReLU masks come
        // from the stored post-activation outputs (h > 0 ⇔ pre > 0).
        let dims = self.dims();
        let mut grads = self.params.zeros_like();
        let mut dz = dq;
        for l in (0..dims.len()).rev() {
            let (d_in, d_out) = dims[l];
            let w = &self.params.tensors[2 * l].0;
            let (dw, db, dx) = mlp::dense_backward(self.kernel, &acts[l], b, d_in, w, d_out, &dz);
            grads.tensors[2 * l].0 = dw;
            grads.tensors[2 * l + 1].0 = db;
            if l > 0 {
                dz = dx;
                for (z, &h) in dz.iter_mut().zip(&acts[l]) {
                    if h <= 0.0 {
                        *z = 0.0;
                    }
                }
            }
        }
        Ok((Some(grads), loss, td_errors))
    }
}

/// Q(s, ·) for a `[batch, state_dim]` matrix of states evaluated
/// directly over a raw parameter set — no optimizer state, no network
/// object. This is the campaign round's batched-greedy entry point:
/// the hub's dense master parameters are evaluated for every live
/// job's pending state in one blocked pass. The layer plan is derived
/// from the tensor shapes, so any `(w, b)*` chain produced by
/// [`QParams::init`] works.
///
/// Determinism: pure; row `r` of the result is bit-identical to a
/// single-state forward of that row through a [`NativeQNet`] holding
/// `params` under the same `kernel` (both kernels are themselves
/// bit-identical, see `kernels.rs`).
pub fn q_values_batch_of(
    params: &QParams,
    states: &[f32],
    batch: usize,
    kernel: DenseKernel,
) -> Result<Vec<f32>> {
    let dims = infer_layer_dims(params)?;
    let state_dim = dims[0].0;
    anyhow::ensure!(
        batch > 0 && states.len() == batch * state_dim,
        "batch states size {} != {} x {}",
        states.len(),
        batch,
        state_dim
    );
    let mut act = states.to_vec();
    let mut hold = Vec::new();
    for (l, &(d_in, d_out)) in dims.iter().enumerate() {
        let relu = l + 1 < dims.len();
        let w = &params.tensors[2 * l].0;
        let b = &params.tensors[2 * l + 1].0;
        mlp::dense_forward_into(kernel, &act, batch, d_in, w, b, d_out, relu, &mut hold);
        std::mem::swap(&mut act, &mut hold);
    }
    Ok(act)
}

/// `(d_in, d_out)` per layer recovered from a `(w1, b1, w2, b2, …)`
/// tensor chain, validating that the shapes actually form one.
fn infer_layer_dims(params: &QParams) -> Result<Vec<(usize, usize)>> {
    anyhow::ensure!(
        !params.tensors.is_empty() && params.tensors.len() % 2 == 0,
        "parameter set is not a (weight, bias) chain: {} tensors",
        params.tensors.len()
    );
    let mut dims: Vec<(usize, usize)> = Vec::with_capacity(params.tensors.len() / 2);
    for pair in params.tensors.chunks(2) {
        let (w_shape, b_shape) = (&pair[0].1, &pair[1].1);
        anyhow::ensure!(
            w_shape.len() == 2 && b_shape.len() == 1 && w_shape[1] == b_shape[0],
            "tensor pair shapes {w_shape:?} / {b_shape:?} are not a dense layer"
        );
        if let Some(&(_, prev_out)) = dims.last() {
            anyhow::ensure!(
                prev_out == w_shape[0],
                "layer input {} does not match previous output {prev_out}",
                w_shape[0]
            );
        }
        dims.push((w_shape[0], w_shape[1]));
    }
    Ok(dims)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coordinator::one_hot;

    /// Single linear layer (2 → 2) with hand-set weights:
    /// w = [[1, 2], [3, 4]], b = [0.5, −0.5].
    fn tiny_net() -> NativeQNet {
        let mut rng = Rng::new(0);
        let mut net = NativeQNet::new(2, &[], 2, 1, &mut rng);
        let params = QParams::from_flat(vec![
            (vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]),
            (vec![0.5, -0.5], vec![2]),
        ])
        .unwrap();
        let opt = AdamState::new(&params);
        net.set_state(params, opt).unwrap();
        net
    }

    #[test]
    fn forward_is_exact_on_the_tiny_net() {
        let net = tiny_net();
        // q = [1·1 + 1·3 + 0.5, 1·2 + 1·4 − 0.5] = [4.5, 5.5].
        assert_eq!(net.q_values(&[1.0, 1.0]).unwrap(), vec![4.5, 5.5]);
        assert!(net.q_values(&[1.0]).is_err(), "wrong state width rejected");
    }

    #[test]
    fn train_grads_match_the_hand_derivation() {
        // Terminal sample (done = 1): target = r = 1, pred = q[0] = 4.5,
        // err = 3.5, loss = huber(3.5) = 3.0, dpred = clip(3.5) = 1.
        // dW = xᵀ·[1, 0] = [[1, 0], [1, 0]], db = [1, 0].
        let net = tiny_net();
        let batch = TrainBatch {
            states: vec![1.0, 1.0],
            actions_onehot: one_hot(0, 2),
            rewards: vec![1.0],
            next_states: vec![0.0, 0.0],
            done: vec![1.0],
        };
        let (grads, loss, td) = net.train_grads(&batch, 0.9).unwrap();
        assert_eq!(loss, 3.0);
        assert_eq!(td, vec![3.5]);
        assert_eq!(grads.tensors[0].0, vec![1.0, 0.0, 1.0, 0.0]);
        assert_eq!(grads.tensors[1].0, vec![1.0, 0.0]);
        assert_eq!(net.loss(&batch, 0.9).unwrap(), 3.0);
    }

    #[test]
    fn relu_masks_gradients_of_inactive_hidden_units() {
        // 1 → [1] → 1 with w1 = [1]: x = −1 drives the hidden unit
        // inactive, so only the output bias can receive gradient.
        let mut rng = Rng::new(1);
        let mut net = NativeQNet::new(1, &[1], 1, 1, &mut rng);
        let params = QParams::from_flat(vec![
            (vec![1.0], vec![1, 1]),
            (vec![0.0], vec![1]),
            (vec![2.0], vec![1, 1]),
            (vec![0.0], vec![1]),
        ])
        .unwrap();
        let opt = AdamState::new(&params);
        net.set_state(params, opt).unwrap();
        let batch = TrainBatch {
            states: vec![-1.0],
            actions_onehot: vec![1.0],
            rewards: vec![1.0],
            next_states: vec![-1.0],
            done: vec![1.0],
        };
        let (grads, _, _) = net.train_grads(&batch, 0.0).unwrap();
        assert_eq!(grads.tensors[0].0, vec![0.0], "masked w1");
        assert_eq!(grads.tensors[1].0, vec![0.0], "masked b1");
        assert_eq!(grads.tensors[2].0, vec![0.0], "h = 0 kills the w2 gradient");
        assert_ne!(grads.tensors[3].0, vec![0.0], "b2 still learns");
    }

    #[test]
    fn seeded_construction_is_deterministic() {
        let a = NativeQNet::with_default_shape(18, 13, &mut Rng::new(7));
        let b = NativeQNet::with_default_shape(18, 13, &mut Rng::new(7));
        assert_eq!(a.params.digest(), b.params.digest());
        assert_ne!(
            a.params.digest(),
            NativeQNet::with_default_shape(18, 13, &mut Rng::new(8)).params.digest()
        );
        assert_eq!(a.params.num_parameters(), 18 * 64 + 64 + 64 * 64 + 64 + 64 * 13 + 13);
    }

    #[test]
    fn forward_batch_rows_are_bitwise_single_forwards() {
        let mut rng = Rng::new(11);
        let mut net = NativeQNet::new(5, &[7, 9], 3, 4, &mut rng);
        let batch = 6;
        let states: Vec<f32> =
            (0..batch * 5).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        for kernel in DenseKernel::ALL {
            net.set_kernel(kernel);
            let flat = net.forward_batch(&states, batch).unwrap();
            assert_eq!(flat.len(), batch * 3);
            for r in 0..batch {
                let single = net.q_values(&states[r * 5..(r + 1) * 5]).unwrap();
                let row: Vec<u32> =
                    flat[r * 3..(r + 1) * 3].iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = single.iter().map(|x| x.to_bits()).collect();
                assert_eq!(row, want, "row {r} under {}", kernel.name());
            }
        }
        assert!(net.forward_batch(&states, batch + 1).is_err(), "size mismatch rejected");
    }

    #[test]
    fn q_values_batch_of_matches_the_owning_network() {
        // The raw-parameter evaluator (the campaign hint path) must
        // reproduce the network's own forward bitwise.
        let mut rng = Rng::new(21);
        let net = NativeQNet::new(4, &[6], 5, 4, &mut rng);
        let batch = 3;
        let states: Vec<f32> =
            (0..batch * 4).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let via_net = net.q_values_batch(&states, batch).unwrap();
        let via_params =
            q_values_batch_of(&net.params, &states, batch, net.kernel()).unwrap();
        let a: Vec<u32> = via_net.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = via_params.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
        assert!(q_values_batch_of(&net.params, &states, batch + 1, net.kernel()).is_err());
    }

    #[test]
    fn apply_train_replays_train_step_bitwise() {
        // train_step ≡ train_grads → apply_train, including optimizer
        // moments and the loss ring.
        let mut rng = Rng::new(31);
        let mut stepped = NativeQNet::new(4, &[6], 3, 2, &mut rng);
        let mut applied = stepped.clone();
        let batch = TrainBatch {
            states: vec![0.2, -0.4, 0.6, 0.1, -0.3, 0.5, 0.7, -0.2],
            actions_onehot: [one_hot(1, 3), one_hot(2, 3)].concat(),
            rewards: vec![1.0, -0.5],
            next_states: vec![0.1, 0.2, -0.1, 0.4, 0.0, -0.6, 0.3, 0.2],
            done: vec![0.0, 1.0],
        };
        let (outcome, grads) = stepped.train_step(&batch, 1e-3, 0.9).unwrap();
        applied.apply_train(&grads, outcome.loss, 1e-3).unwrap();
        assert_eq!(stepped.params.digest(), applied.params.digest());
        assert_eq!(stepped.opt.m.digest(), applied.opt.m.digest());
        assert_eq!(stepped.opt.v.digest(), applied.opt.v.digest());
        assert_eq!(stepped.losses.len(), applied.losses.len());
        assert!(applied.apply_train(&grads, f32::NAN, 1e-3).is_err(), "non-finite loss gated");
    }

    #[test]
    fn infer_layer_dims_recovers_the_layer_plan() {
        let net = NativeQNet::new(18, &[64, 64], 13, 32, &mut Rng::new(3));
        assert_eq!(infer_layer_dims(&net.params).unwrap(), vec![(18, 64), (64, 64), (64, 13)]);
        let bad = QParams::from_flat(vec![(vec![0.0; 4], vec![2, 2])]).unwrap();
        assert!(infer_layer_dims(&bad).is_err(), "odd tensor chain rejected");
    }
}
