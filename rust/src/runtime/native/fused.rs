//! Round-level fused training for shared campaigns: every live job's
//! first minibatch of a segment, stacked into one tall matrix and
//! pushed through one packed blocked GEMM per layer.
//!
//! # Why this is legal
//!
//! Right after a shared-campaign round's merge, every native-DQN worker
//! adopts the *same* dense master state at its next segment start
//! (`Controller::sync_from_hub`) — in `--merge weights` mode because
//! that is the merge, in `--merge grads` mode because workers pull the
//! hub's post-Adam master each round. So the **first** training
//! minibatch of each job's segment computes gradients over one shared
//! parameter set, and those per-job passes can share their per-layer
//! GEMMs: forward and `dx` run over the stacked `[Σbᵢ, ·]` matrix
//! (amortizing the weight traffic across every job in the round), while
//! `dw`/`db` reduce over each job's own contiguous row range (their
//! reductions run over the batch axis, so there is nothing to share —
//! and each job must keep its own gradient anyway). Later minibatches
//! of a segment sit on top of each worker's *local* Adam updates and
//! are never fused.
//!
//! # Bit-identity argument (the fingerprint contract)
//!
//! [`FusedTrainer::train_grads`] is bit-identical per job to
//! `NativeQNet::train_grads` over the same master, by construction:
//!
//! * forward and `q_next` rows are per-row reductions over the input
//!   features — batch-size-independent, so row `r` of the stacked pass
//!   equals row `r − offset` of the job's own pass (`kernels.rs` proves
//!   packed ≡ blocked ≡ scalar per element);
//! * the per-sample target/residual/`dq` arithmetic is row-local, and
//!   each `dq` row divides by its **own job's** batch size;
//! * per-job loss is an f64 accumulation over that job's rows in
//!   ascending order — exactly the sequential loop;
//! * `dw`/`db` reduce over the job's contiguous row slice in ascending
//!   batch order ([`kernels::backward_dw_db`] on the sub-slice *is* the
//!   sequential call), and `dx` rows are per-row reductions again.
//!
//! Index ranges are reassociated (which rows share a GEMM); no
//! accumulator's summation order ever changes. The property test
//! `rust/tests/proptests.rs::prop_fused_cross_job_grads_match_sequential`
//! pins this across random shapes and batch splits, and every
//! pre-existing 1/2/4-worker campaign fingerprint survives unchanged.
//!
//! # Scratch and packing reuse
//!
//! The trainer owns its tall-matrix, activation and `dz`/`dx` buffers
//! and reuses them across rounds (cleared, never shrunk), and caches
//! the packed weight panels under the master's digest — round hints and
//! fused training over one master re-stride nothing.
//! [`FusedTrainer::scratch_bytes`] exposes the footprint so the bench
//! can assert it stops growing after warmup.

use anyhow::{Context, Result};

use crate::runtime::params::QParams;
use crate::runtime::TrainBatch;

use super::kernels::{self, DenseKernel, PackedLayer, PackedWeights};
use super::{infer_layer_dims, mlp};

/// One job's share of a fused round: the gradients, loss and per-sample
/// TD errors its sequential `train_grads` call would have produced.
#[derive(Debug, Clone)]
pub struct FusedGrads {
    pub grads: QParams,
    pub loss: f32,
    pub td_errors: Vec<f32>,
}

/// Round-persistent buffers; cleared and refilled each call, never
/// shrunk.
#[derive(Debug, Default)]
struct Scratch {
    /// `acts[0]` is the stacked state matrix; `acts[l + 1]` is layer
    /// `l`'s output (post-ReLU for hidden layers).
    acts: Vec<Vec<f32>>,
    /// Ping-pong pair for the no-store next-state forward; `q_next`
    /// holds the final Q rows when the loop ends.
    q_next: Vec<f32>,
    hold: Vec<f32>,
    /// Backprop workspace: `dz` is the live upstream gradient, `dx` the
    /// swap partner it propagates into.
    dz: Vec<f32>,
    dx: Vec<f32>,
}

/// The fused cross-job trainer: packed-panel forward/backward over a
/// stacked multi-job minibatch, plus the packed forward the round's
/// batched greedy hints share.
#[derive(Debug)]
pub struct FusedTrainer {
    kernel: DenseKernel,
    /// Most recent pack, keyed by the digest of the parameters it was
    /// built from (one master per round ⇒ a one-deep cache hits every
    /// reuse that exists).
    pack: Option<PackedWeights>,
    scratch: Scratch,
}

impl FusedTrainer {
    pub fn new(kernel: DenseKernel) -> FusedTrainer {
        FusedTrainer { kernel, pack: None, scratch: Scratch::default() }
    }

    /// Bytes currently held by the scratch buffers and the cached pack.
    /// After one warmup round of a fixed shape this must stop growing —
    /// `benches/dqn_runtime.rs` asserts it.
    pub fn scratch_bytes(&self) -> usize {
        let s = &self.scratch;
        let f32s = s.q_next.capacity()
            + s.hold.capacity()
            + s.dz.capacity()
            + s.dx.capacity()
            + s.acts.iter().map(Vec::capacity).sum::<usize>();
        f32s * std::mem::size_of::<f32>() + self.pack.as_ref().map_or(0, PackedWeights::bytes)
    }

    /// Re-stride `params` into packed panels unless the cached pack was
    /// already built from these exact parameters (digest equality —
    /// O(#params), trivial next to one GEMM).
    fn ensure_pack(&mut self, params: &QParams, dims: &[(usize, usize)]) {
        let digest = params.digest();
        if self.pack.as_ref().map(PackedWeights::digest) == Some(digest) {
            return;
        }
        let layers: Vec<PackedLayer> = dims
            .iter()
            .enumerate()
            .map(|(l, &(d_in, d_out))| PackedLayer::pack(&params.tensors[2 * l].0, d_in, d_out))
            .collect();
        self.pack = Some(PackedWeights::from_layers(digest, layers));
    }

    /// Q(s, ·) for a `[batch, state_dim]` matrix over raw parameters —
    /// the packed, no-store counterpart of
    /// [`crate::runtime::q_values_batch_of`], bit-identical to it row
    /// for row. The campaign round's greedy hints call this so their
    /// pack is warm by the time fused training runs over the same
    /// master.
    pub fn forward(&mut self, params: &QParams, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        let dims = infer_layer_dims(params)?;
        let state_dim = dims[0].0;
        anyhow::ensure!(
            batch > 0 && states.len() == batch * state_dim,
            "batch states size {} != {} x {}",
            states.len(),
            batch,
            state_dim
        );
        self.ensure_pack(params, &dims);
        let pack = self.pack.as_ref().context("weight pack missing after ensure_pack")?;
        let scratch = &mut self.scratch;
        scratch.q_next.clear();
        scratch.q_next.extend_from_slice(states);
        for (l, layer) in pack.layers().iter().enumerate() {
            let relu = l + 1 < dims.len();
            let bias = &params.tensors[2 * l + 1].0;
            layer.forward_into(&scratch.q_next, batch, bias, relu, &mut scratch.hold);
            std::mem::swap(&mut scratch.q_next, &mut scratch.hold);
        }
        Ok(scratch.q_next.clone())
    }

    /// Gradients, losses and TD errors for every job's minibatch in one
    /// fused pass over `params` — per job, bit-identical to
    /// `NativeQNet::train_grads(batch, gamma)` on a network holding
    /// `params` (see the module docs for the argument). Pure in
    /// `(params, batches, gamma)`; only scratch is mutated.
    pub fn train_grads(
        &mut self,
        params: &QParams,
        batches: &[&TrainBatch],
        gamma: f32,
    ) -> Result<Vec<FusedGrads>> {
        anyhow::ensure!(!batches.is_empty(), "fused training needs at least one minibatch");
        let dims = infer_layer_dims(params)?;
        let state_dim = dims[0].0;
        let a = dims.last().context("no layers")?.1;
        let mut total_b = 0usize;
        for batch in batches {
            let bj = batch.rewards.len();
            anyhow::ensure!(bj > 0, "empty train batch in fused round");
            batch.validate(bj, state_dim, a)?;
            total_b += bj;
        }
        self.ensure_pack(params, &dims);
        let pack = self.pack.as_ref().context("weight pack missing after ensure_pack")?;
        let kernel = self.kernel;
        let scratch = &mut self.scratch;

        // Stacked forward, keeping activations (the backward needs
        // every layer's inputs and ReLU masks).
        scratch.acts.resize_with(dims.len() + 1, Vec::new);
        scratch.acts[0].clear();
        for batch in batches {
            scratch.acts[0].extend_from_slice(&batch.states);
        }
        for (l, layer) in pack.layers().iter().enumerate() {
            let relu = l + 1 < dims.len();
            let bias = &params.tensors[2 * l + 1].0;
            let (src, dst) = scratch.acts.split_at_mut(l + 1);
            layer.forward_into(&src[l], total_b, bias, relu, &mut dst[0]);
        }

        // Stacked next-state forward, no store (ping-pong pair).
        scratch.q_next.clear();
        for batch in batches {
            scratch.q_next.extend_from_slice(&batch.next_states);
        }
        for (l, layer) in pack.layers().iter().enumerate() {
            let relu = l + 1 < dims.len();
            let bias = &params.tensors[2 * l + 1].0;
            layer.forward_into(&scratch.q_next, total_b, bias, relu, &mut scratch.hold);
            std::mem::swap(&mut scratch.q_next, &mut scratch.hold);
        }

        // Per-sample targets, residuals and dL/dq rows — row-local
        // except the division by the job's own batch size, and the
        // per-job loss accumulation over that job's rows in order.
        scratch.dz.clear();
        scratch.dz.resize(total_b * a, 0.0);
        let q = scratch.acts.last().context("forward produced no activations")?;
        let mut losses: Vec<f32> = Vec::with_capacity(batches.len());
        let mut tds: Vec<Vec<f32>> = Vec::with_capacity(batches.len());
        let mut off = 0usize;
        for batch in batches {
            let bj = batch.rewards.len();
            let mut loss_acc = 0.0f64;
            let mut td = Vec::with_capacity(bj);
            for i in 0..bj {
                let r = off + i;
                let max_next = scratch.q_next[r * a..(r + 1) * a]
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                let target = batch.rewards[i] + gamma * (1.0 - batch.done[i]) * max_next;
                let mut pred = 0.0f64;
                for j in 0..a {
                    pred += q[r * a + j] as f64 * batch.actions_onehot[i * a + j] as f64;
                }
                let err = pred as f32 - target;
                td.push(err);
                loss_acc += mlp::huber(err) as f64;
                let g = mlp::huber_grad(err) / bj as f32;
                for j in 0..a {
                    scratch.dz[r * a + j] = g * batch.actions_onehot[i * a + j];
                }
            }
            losses.push((loss_acc / bj as f64) as f32);
            tds.push(td);
            off += bj;
        }

        // Backward, newest layer first: dw/db per job over its own row
        // slice; one packed dx pass over the whole stacked batch; ReLU
        // masks from the stored activations.
        let mut grads: Vec<QParams> = batches.iter().map(|_| params.zeros_like()).collect();
        for l in (0..dims.len()).rev() {
            let (d_in, d_out) = dims[l];
            let x = &scratch.acts[l];
            let mut off = 0usize;
            for (k, batch) in batches.iter().enumerate() {
                let bj = batch.rewards.len();
                let xs = &x[off * d_in..(off + bj) * d_in];
                let dzs = &scratch.dz[off * d_out..(off + bj) * d_out];
                let (dw, rest) = grads[k].tensors[2 * l..].split_first_mut().context("dw slot")?;
                let db = rest.first_mut().context("db slot")?;
                kernels::backward_dw_db(kernel, xs, bj, d_in, d_out, dzs, &mut dw.0, &mut db.0);
                off += bj;
            }
            if l > 0 {
                pack.layers()[l].dx_into(&scratch.dz, total_b, &mut scratch.dx);
                std::mem::swap(&mut scratch.dz, &mut scratch.dx);
                for (z, &h) in scratch.dz.iter_mut().zip(&scratch.acts[l]) {
                    if h <= 0.0 {
                        *z = 0.0;
                    }
                }
            }
        }

        let mut out = Vec::with_capacity(batches.len());
        for ((grads, loss), td_errors) in grads.into_iter().zip(losses).zip(tds) {
            anyhow::ensure!(
                loss.is_finite(),
                "fused training produced non-finite loss {loss}"
            );
            out.push(FusedGrads { grads, loss, td_errors });
        }
        Ok(out)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::super::NativeQNet;
    use super::*;
    use crate::coordinator::one_hot;
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, b: usize, d: usize, a: usize) -> TrainBatch {
        TrainBatch {
            states: (0..b * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            actions_onehot: (0..b)
                .flat_map(|_| one_hot(rng.below(a as u64) as usize, a))
                .collect(),
            rewards: (0..b).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            next_states: (0..b * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            done: (0..b).map(|_| if rng.chance(0.2) { 1.0 } else { 0.0 }).collect(),
        }
    }

    #[test]
    fn fused_grads_match_sequential_train_grads_bitwise() {
        let mut rng = Rng::new(90);
        let net = NativeQNet::new(6, &[11, 9], 4, 8, &mut rng);
        let batches: Vec<TrainBatch> =
            [3usize, 1, 5].iter().map(|&b| random_batch(&mut rng, b, 6, 4)).collect();
        let refs: Vec<&TrainBatch> = batches.iter().collect();
        let mut trainer = FusedTrainer::new(net.kernel());
        let fused = trainer.train_grads(&net.params, &refs, 0.9).unwrap();
        assert_eq!(fused.len(), batches.len());
        for (batch, f) in batches.iter().zip(&fused) {
            let (grads, loss, td) = net.train_grads(batch, 0.9).unwrap();
            assert_eq!(grads.digest(), f.grads.digest());
            assert_eq!(loss.to_bits(), f.loss.to_bits());
            let want: Vec<u32> = td.iter().map(|x| x.to_bits()).collect();
            let got: Vec<u32> = f.td_errors.iter().map(|x| x.to_bits()).collect();
            assert_eq!(want, got);
        }
    }

    #[test]
    fn packed_forward_matches_raw_params_evaluator() {
        let mut rng = Rng::new(91);
        let net = NativeQNet::new(5, &[7], 3, 8, &mut rng);
        let states: Vec<f32> = (0..4 * 5).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut trainer = FusedTrainer::new(net.kernel());
        let got = trainer.forward(&net.params, &states, 4).unwrap();
        let want =
            crate::runtime::q_values_batch_of(&net.params, &states, 4, net.kernel()).unwrap();
        let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        assert!(trainer.forward(&net.params, &states, 5).is_err(), "size mismatch rejected");
    }

    #[test]
    fn pack_cache_hits_on_same_params_and_scratch_stabilizes() {
        let mut rng = Rng::new(92);
        let net = NativeQNet::new(6, &[8], 3, 8, &mut rng);
        let batches: Vec<TrainBatch> =
            (0..4).map(|_| random_batch(&mut rng, 4, 6, 3)).collect();
        let refs: Vec<&TrainBatch> = batches.iter().collect();
        let mut trainer = FusedTrainer::new(net.kernel());
        trainer.train_grads(&net.params, &refs, 0.9).unwrap();
        let warm = trainer.scratch_bytes();
        assert!(warm > 0);
        let digest = trainer.pack.as_ref().unwrap().digest();
        for _ in 0..3 {
            trainer.train_grads(&net.params, &refs, 0.9).unwrap();
        }
        assert_eq!(trainer.scratch_bytes(), warm, "scratch grew across identical rounds");
        assert_eq!(trainer.pack.as_ref().unwrap().digest(), digest);
        // A different master re-packs.
        let other = NativeQNet::new(6, &[8], 3, 8, &mut Rng::new(93));
        trainer.train_grads(&other.params, &refs, 0.9).unwrap();
        assert_ne!(trainer.pack.as_ref().unwrap().digest(), digest);
    }
}
