//! Dense-kernel seam for the native Q-engine: one dispatch enum, two
//! interchangeable implementations of the forward/backward primitives.
//!
//! # Why a seam
//!
//! The scalar loops (the PR 5 implementation, preserved verbatim as
//! [`DenseKernel::Scalar`]) walk the row-major `[d_in, d_out]` weight
//! matrix with stride `d_out` in the hot inner loop and carry exactly
//! one f64 dependency chain per output element — they are latency- and
//! cache-bound, not throughput-bound. [`DenseKernel::Blocked`] register
//! -tiles the same computation: a lane of [`FWD_LANES`] (or
//! [`DX_LANES`]) *independent* f64 accumulators walks contiguous weight
//! rows, so each loaded cache line feeds every lane and the FMA chains
//! overlap. A whole `[batch, d_in]` matrix amortizes the weight traffic
//! further — that is what `NativeQNet::forward_batch` and the campaign
//! round's batched greedy selection buy.
//!
//! # Accumulation-order proof (the determinism contract)
//!
//! The campaign fingerprint rests on bitwise reproducibility, and f64
//! addition is not associative — so the blocked kernels are constructed
//! to *reassociate index ranges, never summation order*:
//!
//! * every output element (a `y[b, j]`, `dw[i, j]`, `db[j]` or
//!   `dx[b, i]`) is produced by exactly one accumulator;
//! * that accumulator receives exactly the same addends in exactly the
//!   same ascending-index order as the scalar kernel (`i` order for the
//!   forward, `b` order for `dw`/`db`, `j` order for `dx`), starting
//!   from the same seed value (the bias for the forward, `0.0` else);
//! * the lane structure only changes *which outputs are in flight
//!   concurrently* — lanes never exchange or combine partial sums, and
//!   remainder columns fall back to the scalar column loop, which is
//!   the identical computation.
//!
//! Per output element the two kernels therefore execute the identical
//! sequence of f64 operations and one final `as f32` cast: `Blocked`
//! and `Scalar` are bit-identical on every input, which
//! `rust/tests/proptests.rs::prop_blocked_kernel_is_bitwise_identical_to_scalar`
//! pins across random shapes and batch sizes. No fingerprint
//! re-pinning was needed anywhere.

/// Which dense-kernel implementation the native engine dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DenseKernel {
    /// Reference per-element loops (the original implementation). Kept
    /// as the differential-testing baseline and for the roofline table.
    Scalar,
    /// Register-tiled loops with explicit independent accumulator
    /// lanes (8-wide over output columns, 4-wide over `dx` rows).
    /// Bit-identical to [`DenseKernel::Scalar`]; several times faster.
    #[default]
    Blocked,
}

impl DenseKernel {
    pub const ALL: [DenseKernel; 2] = [DenseKernel::Scalar, DenseKernel::Blocked];

    pub fn name(self) -> &'static str {
        match self {
            DenseKernel::Scalar => "scalar",
            DenseKernel::Blocked => "blocked",
        }
    }
}

/// Output-column lane width of the blocked forward / `dw` / `db`
/// kernels (8 independent f64 accumulators — two AVX2 registers' worth,
/// and enough overlapping add chains to hide FP latency on anything
/// narrower).
pub const FWD_LANES: usize = 8;

/// Input-row lane width of the blocked `dx` kernel (each lane streams
/// its own contiguous weight row while sharing one `dz` load).
pub const DX_LANES: usize = 4;

/// `y[b, j] = act(Σ_i x[b, i] · w[i, j] + bias[j])`, dispatched.
#[allow(clippy::too_many_arguments)]
pub(super) fn dense_forward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    match kernel {
        DenseKernel::Scalar => forward_scalar(x, batch, d_in, w, bias, d_out, relu),
        DenseKernel::Blocked => forward_blocked(x, batch, d_in, w, bias, d_out, relu),
    }
}

/// Backward pass of one dense layer, dispatched. Returns
/// `(dw, db, dx)`; the caller applies the previous layer's ReLU mask
/// to `dx` before recursing.
pub(super) fn dense_backward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dz.len(), batch * d_out);
    match kernel {
        DenseKernel::Scalar => backward_scalar(x, batch, d_in, w, d_out, dz),
        DenseKernel::Blocked => backward_blocked(x, batch, d_in, w, d_out, dz),
    }
}

// --- scalar reference kernels (moved verbatim from mlp.rs) ---

fn forward_scalar(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    let mut y = vec![0.0f32; batch * d_out];
    for b in 0..batch {
        let row = &x[b * d_in..(b + 1) * d_in];
        let out = &mut y[b * d_out..(b + 1) * d_out];
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = forward_column(row, w, bias, d_out, j, relu);
        }
    }
    y
}

/// One output element of the forward pass: bias-seeded f64 accumulation
/// over `i` in ascending order. Shared by the scalar kernel and the
/// blocked kernel's remainder columns, so the two are the same
/// computation by construction.
#[inline]
fn forward_column(row: &[f32], w: &[f32], bias: &[f32], d_out: usize, j: usize, relu: bool) -> f32 {
    let mut acc = bias[j] as f64;
    for (i, &xi) in row.iter().enumerate() {
        acc += xi as f64 * w[i * d_out + j] as f64;
    }
    let v = acc as f32;
    if relu {
        v.max(0.0)
    } else {
        v
    }
}

fn backward_scalar(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    // dw[i, j] = Σ_b x[b, i] · dz[b, j] — f64 partials in batch order.
    let mut dw = vec![0.0f32; d_in * d_out];
    for i in 0..d_in {
        for j in 0..d_out {
            let mut acc = 0.0f64;
            for b in 0..batch {
                acc += x[b * d_in + i] as f64 * dz[b * d_out + j] as f64;
            }
            dw[i * d_out + j] = acc as f32;
        }
    }
    // db[j] = Σ_b dz[b, j].
    let mut db = vec![0.0f32; d_out];
    for (j, slot) in db.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for b in 0..batch {
            acc += dz[b * d_out + j] as f64;
        }
        *slot = acc as f32;
    }
    // dx[b, i] = Σ_j dz[b, j] · w[i, j].
    let mut dx = vec![0.0f32; batch * d_in];
    for b in 0..batch {
        for i in 0..d_in {
            dx[b * d_in + i] = dx_element(w, d_out, dz, b, i);
        }
    }
    (dw, db, dx)
}

/// One `dx[b, i]` element: f64 accumulation over `j` in ascending
/// order. Shared with the blocked kernel's remainder rows.
#[inline]
fn dx_element(w: &[f32], d_out: usize, dz: &[f32], b: usize, i: usize) -> f32 {
    let mut acc = 0.0f64;
    for j in 0..d_out {
        acc += dz[b * d_out + j] as f64 * w[i * d_out + j] as f64;
    }
    acc as f32
}

// --- blocked / register-tiled kernels ---

fn forward_blocked(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    let mut y = vec![0.0f32; batch * d_out];
    let tiles = d_out / FWD_LANES * FWD_LANES;
    for b in 0..batch {
        let row = &x[b * d_in..(b + 1) * d_in];
        let out = &mut y[b * d_out..(b + 1) * d_out];
        let mut j0 = 0;
        while j0 < tiles {
            // 8 independent accumulators, one per output column; every
            // addend lands on its own lane in ascending-i order — the
            // scalar kernel's exact per-element sequence.
            let mut acc = [0.0f64; FWD_LANES];
            for (k, a) in acc.iter_mut().enumerate() {
                *a = bias[j0 + k] as f64;
            }
            for (i, &xi) in row.iter().enumerate() {
                let xi = xi as f64;
                let wrow = &w[i * d_out + j0..i * d_out + j0 + FWD_LANES];
                for (a, &wk) in acc.iter_mut().zip(wrow) {
                    *a += xi * wk as f64;
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                let v = a as f32;
                out[j0 + k] = if relu { v.max(0.0) } else { v };
            }
            j0 += FWD_LANES;
        }
        // Remainder columns take the shared scalar column path.
        for (j, slot) in out.iter_mut().enumerate().skip(tiles) {
            *slot = forward_column(row, w, bias, d_out, j, relu);
        }
    }
    y
}

fn backward_blocked(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let col_tiles = d_out / FWD_LANES * FWD_LANES;

    // dw[i, j] = Σ_b x[b, i] · dz[b, j]: per (i, j-lane) tile, each
    // lane accumulates its own column in ascending-b order; `dz` rows
    // are read contiguously.
    let mut dw = vec![0.0f32; d_in * d_out];
    for i in 0..d_in {
        let mut j0 = 0;
        while j0 < col_tiles {
            let mut acc = [0.0f64; FWD_LANES];
            for b in 0..batch {
                let xi = x[b * d_in + i] as f64;
                let dzrow = &dz[b * d_out + j0..b * d_out + j0 + FWD_LANES];
                for (a, &g) in acc.iter_mut().zip(dzrow) {
                    *a += xi * g as f64;
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                dw[i * d_out + j0 + k] = a as f32;
            }
            j0 += FWD_LANES;
        }
        for j in col_tiles..d_out {
            let mut acc = 0.0f64;
            for b in 0..batch {
                acc += x[b * d_in + i] as f64 * dz[b * d_out + j] as f64;
            }
            dw[i * d_out + j] = acc as f32;
        }
    }

    // db[j] = Σ_b dz[b, j]: j-lanes over contiguous dz rows, b order.
    let mut db = vec![0.0f32; d_out];
    let mut j0 = 0;
    while j0 < col_tiles {
        let mut acc = [0.0f64; FWD_LANES];
        for b in 0..batch {
            let dzrow = &dz[b * d_out + j0..b * d_out + j0 + FWD_LANES];
            for (a, &g) in acc.iter_mut().zip(dzrow) {
                *a += g as f64;
            }
        }
        for (k, &a) in acc.iter().enumerate() {
            db[j0 + k] = a as f32;
        }
        j0 += FWD_LANES;
    }
    for (j, slot) in db.iter_mut().enumerate().skip(col_tiles) {
        let mut acc = 0.0f64;
        for b in 0..batch {
            acc += dz[b * d_out + j] as f64;
        }
        *slot = acc as f32;
    }

    // dx[b, i] = Σ_j dz[b, j] · w[i, j]: i-lanes share each dz load
    // while every lane streams its own contiguous weight row; per
    // (b, i) the adds run in ascending-j order.
    let row_tiles = d_in / DX_LANES * DX_LANES;
    let mut dx = vec![0.0f32; batch * d_in];
    for b in 0..batch {
        let dzrow = &dz[b * d_out..(b + 1) * d_out];
        let mut i0 = 0;
        while i0 < row_tiles {
            let mut acc = [0.0f64; DX_LANES];
            for (j, &g) in dzrow.iter().enumerate() {
                let g = g as f64;
                for (k, a) in acc.iter_mut().enumerate() {
                    *a += g * w[(i0 + k) * d_out + j] as f64;
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                dx[b * d_in + i0 + k] = a as f32;
            }
            i0 += DX_LANES;
        }
        for i in row_tiles..d_in {
            dx[b * d_in + i] = dx_element(w, d_out, dz, b, i);
        }
    }

    (dw, db, dx)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect()
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn blocked_forward_is_bitwise_scalar_across_shapes() {
        // Shapes straddle the lane width: below, at, above and far past
        // FWD_LANES, with and without remainders, batch 1..=9.
        let mut rng = Rng::new(42);
        for &(d_in, d_out) in
            &[(1, 1), (3, 2), (2, 8), (5, 9), (7, 13), (18, 64), (64, 13), (6, 16)]
        {
            for batch in [1, 2, 5, 9] {
                let x = random_vec(&mut rng, batch * d_in);
                let w = random_vec(&mut rng, d_in * d_out);
                let bias = random_vec(&mut rng, d_out);
                for relu in [false, true] {
                    let a = dense_forward(
                        DenseKernel::Scalar,
                        &x,
                        batch,
                        d_in,
                        &w,
                        &bias,
                        d_out,
                        relu,
                    );
                    let b = dense_forward(
                        DenseKernel::Blocked,
                        &x,
                        batch,
                        d_in,
                        &w,
                        &bias,
                        d_out,
                        relu,
                    );
                    assert_eq!(bits(&a), bits(&b), "{d_in}x{d_out} batch {batch} relu {relu}");
                }
            }
        }
    }

    #[test]
    fn blocked_backward_is_bitwise_scalar_across_shapes() {
        let mut rng = Rng::new(7);
        for &(d_in, d_out) in &[(1, 1), (4, 3), (5, 8), (9, 13), (18, 64), (64, 13), (3, 17)] {
            for batch in [1, 2, 6, 11] {
                let x = random_vec(&mut rng, batch * d_in);
                let w = random_vec(&mut rng, d_in * d_out);
                let dz = random_vec(&mut rng, batch * d_out);
                let (dw_s, db_s, dx_s) =
                    dense_backward(DenseKernel::Scalar, &x, batch, d_in, &w, d_out, &dz);
                let (dw_b, db_b, dx_b) =
                    dense_backward(DenseKernel::Blocked, &x, batch, d_in, &w, d_out, &dz);
                assert_eq!(bits(&dw_s), bits(&dw_b), "dw {d_in}x{d_out} batch {batch}");
                assert_eq!(bits(&db_s), bits(&db_b), "db {d_in}x{d_out} batch {batch}");
                assert_eq!(bits(&dx_s), bits(&dx_b), "dx {d_in}x{d_out} batch {batch}");
            }
        }
    }

    #[test]
    fn blocked_forward_matches_hand_computation_with_remainder() {
        // d_out = 2 < FWD_LANES: the whole output is remainder columns,
        // which must be the scalar column computation exactly.
        let y = dense_forward(
            DenseKernel::Blocked,
            &[1.0, 2.0],
            1,
            2,
            &[1.0, 2.0, 3.0, 4.0],
            &[0.5, -0.5],
            2,
            false,
        );
        assert_eq!(y, vec![7.5, 9.5]);
    }

    #[test]
    fn kernel_names_and_default() {
        assert_eq!(DenseKernel::default(), DenseKernel::Blocked);
        assert_eq!(DenseKernel::Scalar.name(), "scalar");
        assert_eq!(DenseKernel::Blocked.name(), "blocked");
        assert_eq!(DenseKernel::ALL.len(), 2);
    }
}
