//! Dense-kernel seam for the native Q-engine: one dispatch enum, two
//! interchangeable implementations of the forward/backward primitives,
//! plus lane-aligned packed weight panels for the hot repeated-forward
//! and fused-training paths.
//!
//! # Why a seam
//!
//! The scalar loops (the PR 5 implementation, preserved verbatim as
//! [`DenseKernel::Scalar`]) walk the row-major `[d_in, d_out]` weight
//! matrix with stride `d_out` in the hot inner loop and carry exactly
//! one f64 dependency chain per output element — they are latency- and
//! cache-bound, not throughput-bound. [`DenseKernel::Blocked`] register
//! -tiles the same computation: a lane of [`FWD_LANES`] (or
//! [`DX_LANES`]) *independent* f64 accumulators walks contiguous weight
//! rows, so each loaded cache line feeds every lane and the FMA chains
//! overlap. A whole `[batch, d_in]` matrix amortizes the weight traffic
//! further — that is what `NativeQNet::forward_batch` and the campaign
//! round's batched greedy selection buy.
//!
//! # Accumulation-order proof (the determinism contract)
//!
//! The campaign fingerprint rests on bitwise reproducibility, and f64
//! addition is not associative — so the blocked kernels are constructed
//! to *reassociate index ranges, never summation order*:
//!
//! * every output element (a `y[b, j]`, `dw[i, j]`, `db[j]` or
//!   `dx[b, i]`) is produced by exactly one accumulator;
//! * that accumulator receives exactly the same addends in exactly the
//!   same ascending-index order as the scalar kernel (`i` order for the
//!   forward, `b` order for `dw`/`db`, `j` order for `dx`), starting
//!   from the same seed value (the bias for the forward, `0.0` else);
//! * the lane structure only changes *which outputs are in flight
//!   concurrently* — lanes never exchange or combine partial sums, and
//!   remainder columns fall back to the scalar column loop, which is
//!   the identical computation.
//!
//! Per output element the two kernels therefore execute the identical
//! sequence of f64 operations and one final `as f32` cast: `Blocked`
//! and `Scalar` are bit-identical on every input, which
//! `rust/tests/proptests.rs::prop_blocked_kernel_is_bitwise_identical_to_scalar`
//! pins across random shapes and batch sizes. No fingerprint
//! re-pinning was needed anywhere.
//!
//! # Packed weight panels
//!
//! The blocked kernels still read the row-major weight matrix with a
//! `d_out`-strided panel start per input row (forward) or a
//! `d_out`-strided element walk per lane (`dx`). [`PackedLayer`]
//! pre-strides a layer once: the forward panels hold each
//! [`FWD_LANES`]-column group contiguously per input row, and the `dx`
//! panels hold each [`DX_LANES`]-row group contiguously per output
//! column, so the hot inner loops stream both operands at unit stride.
//! Packing is a pure permutation — every accumulator reads the *same*
//! weight values in the *same* order as the blocked (and therefore the
//! scalar) kernel, so packed results are bit-identical by the argument
//! above. [`PackedWeights`] bundles a network's packed layers under the
//! parameter digest they were built from; the fused cross-job trainer
//! (`super::fused`) caches one per master so a round's greedy hints and
//! its fused training GEMMs never re-stride the same weights twice.
//!
//! The backward split (`backward_dw_db` / `backward_dx_into`) exists
//! for the same fused path: `dw` and `db` reduce over a *job's own*
//! row range while `dx` propagates through the whole stacked batch, so
//! the trainer needs the halves separately — and the blocked `dw`+`db`
//! half folds the bias reduction into the weight-gradient traversal
//! (one sweep over `dz` instead of two, no accumulator reordered).

/// Which dense-kernel implementation the native engine dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DenseKernel {
    /// Reference per-element loops (the original implementation). Kept
    /// as the differential-testing baseline and for the roofline table.
    Scalar,
    /// Register-tiled loops with explicit independent accumulator
    /// lanes (8-wide over output columns, 4-wide over `dx` rows).
    /// Bit-identical to [`DenseKernel::Scalar`]; several times faster.
    #[default]
    Blocked,
}

impl DenseKernel {
    pub const ALL: [DenseKernel; 2] = [DenseKernel::Scalar, DenseKernel::Blocked];

    pub fn name(self) -> &'static str {
        match self {
            DenseKernel::Scalar => "scalar",
            DenseKernel::Blocked => "blocked",
        }
    }
}

/// Output-column lane width of the blocked forward / `dw` / `db`
/// kernels (8 independent f64 accumulators — two AVX2 registers' worth,
/// and enough overlapping add chains to hide FP latency on anything
/// narrower).
pub const FWD_LANES: usize = 8;

/// Input-row lane width of the blocked `dx` kernel (each lane streams
/// its own contiguous weight row while sharing one `dz` load).
pub const DX_LANES: usize = 4;

/// `y[b, j] = act(Σ_i x[b, i] · w[i, j] + bias[j])`, dispatched.
#[allow(clippy::too_many_arguments)]
pub(super) fn dense_forward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
) -> Vec<f32> {
    let mut y = Vec::new();
    dense_forward_into(kernel, x, batch, d_in, w, bias, d_out, relu, &mut y);
    y
}

/// [`dense_forward`] into a caller-owned buffer (cleared and resized,
/// so a warm buffer is reused allocation-free) — the path the no-store
/// batched forward and the fused trainer ping-pong through.
#[allow(clippy::too_many_arguments)]
pub(super) fn dense_forward_into(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
    y: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    y.clear();
    y.resize(batch * d_out, 0.0);
    match kernel {
        DenseKernel::Scalar => forward_scalar(x, batch, d_in, w, bias, d_out, relu, y),
        DenseKernel::Blocked => forward_blocked(x, batch, d_in, w, bias, d_out, relu, y),
    }
}

/// Backward pass of one dense layer, dispatched. Returns
/// `(dw, db, dx)`; the caller applies the previous layer's ReLU mask
/// to `dx` before recursing. Assembled from the [`backward_dw_db`] and
/// [`backward_dx_into`] halves, so the fused trainer's piecewise calls
/// exercise exactly the code this whole-layer entry point does.
pub(super) fn dense_backward(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    d_out: usize,
    dz: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut dw, mut db, mut dx) = (Vec::new(), Vec::new(), Vec::new());
    backward_dw_db(kernel, x, batch, d_in, d_out, dz, &mut dw, &mut db);
    backward_dx_into(kernel, w, batch, d_in, d_out, dz, &mut dx);
    (dw, db, dx)
}

/// The weight/bias half of the backward pass:
/// `dw[i, j] = Σ_b x[b, i] · dz[b, j]` and `db[j] = Σ_b dz[b, j]`,
/// into caller-owned buffers (cleared and resized). The blocked
/// implementation computes both in a single traversal of `dz`.
#[allow(clippy::too_many_arguments)]
pub(super) fn backward_dw_db(
    kernel: DenseKernel,
    x: &[f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    dz: &[f32],
    dw: &mut Vec<f32>,
    db: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), batch * d_in);
    debug_assert_eq!(dz.len(), batch * d_out);
    debug_assert!(d_in > 0);
    dw.clear();
    dw.resize(d_in * d_out, 0.0);
    db.clear();
    db.resize(d_out, 0.0);
    match kernel {
        DenseKernel::Scalar => dw_db_scalar(x, batch, d_in, d_out, dz, dw, db),
        DenseKernel::Blocked => dw_db_fused_blocked(x, batch, d_in, d_out, dz, dw, db),
    }
}

/// The input-gradient half of the backward pass:
/// `dx[b, i] = Σ_j dz[b, j] · w[i, j]`, into a caller-owned buffer
/// (cleared and resized).
pub(super) fn backward_dx_into(
    kernel: DenseKernel,
    w: &[f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    dz: &[f32],
    dx: &mut Vec<f32>,
) {
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dz.len(), batch * d_out);
    dx.clear();
    dx.resize(batch * d_in, 0.0);
    match kernel {
        DenseKernel::Scalar => dx_scalar(w, batch, d_in, d_out, dz, dx),
        DenseKernel::Blocked => dx_blocked(w, batch, d_in, d_out, dz, dx),
    }
}

// --- scalar reference kernels (moved verbatim from mlp.rs) ---

#[allow(clippy::too_many_arguments)]
fn forward_scalar(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
    y: &mut [f32],
) {
    for b in 0..batch {
        let row = &x[b * d_in..(b + 1) * d_in];
        let out = &mut y[b * d_out..(b + 1) * d_out];
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = forward_column(row, w, bias, d_out, j, relu);
        }
    }
}

/// One output element of the forward pass: bias-seeded f64 accumulation
/// over `i` in ascending order. Shared by the scalar kernel and the
/// blocked kernel's remainder columns, so the two are the same
/// computation by construction.
#[inline]
fn forward_column(row: &[f32], w: &[f32], bias: &[f32], d_out: usize, j: usize, relu: bool) -> f32 {
    let mut acc = bias[j] as f64;
    for (i, &xi) in row.iter().enumerate() {
        acc += xi as f64 * w[i * d_out + j] as f64;
    }
    let v = acc as f32;
    if relu {
        v.max(0.0)
    } else {
        v
    }
}

fn dw_db_scalar(
    x: &[f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    // dw[i, j] = Σ_b x[b, i] · dz[b, j] — f64 partials in batch order.
    for i in 0..d_in {
        for j in 0..d_out {
            let mut acc = 0.0f64;
            for b in 0..batch {
                acc += x[b * d_in + i] as f64 * dz[b * d_out + j] as f64;
            }
            dw[i * d_out + j] = acc as f32;
        }
    }
    // db[j] = Σ_b dz[b, j].
    for (j, slot) in db.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for b in 0..batch {
            acc += dz[b * d_out + j] as f64;
        }
        *slot = acc as f32;
    }
}

fn dx_scalar(w: &[f32], batch: usize, d_in: usize, d_out: usize, dz: &[f32], dx: &mut [f32]) {
    // dx[b, i] = Σ_j dz[b, j] · w[i, j].
    for b in 0..batch {
        for i in 0..d_in {
            dx[b * d_in + i] = dx_element(w, d_out, dz, b, i);
        }
    }
}

/// One `dx[b, i]` element: f64 accumulation over `j` in ascending
/// order. Shared with the blocked kernel's remainder rows.
#[inline]
fn dx_element(w: &[f32], d_out: usize, dz: &[f32], b: usize, i: usize) -> f32 {
    let mut acc = 0.0f64;
    for j in 0..d_out {
        acc += dz[b * d_out + j] as f64 * w[i * d_out + j] as f64;
    }
    acc as f32
}

// --- blocked / register-tiled kernels ---

#[allow(clippy::too_many_arguments)]
fn forward_blocked(
    x: &[f32],
    batch: usize,
    d_in: usize,
    w: &[f32],
    bias: &[f32],
    d_out: usize,
    relu: bool,
    y: &mut [f32],
) {
    let tiles = d_out / FWD_LANES * FWD_LANES;
    for b in 0..batch {
        let row = &x[b * d_in..(b + 1) * d_in];
        let out = &mut y[b * d_out..(b + 1) * d_out];
        let mut j0 = 0;
        while j0 < tiles {
            // 8 independent accumulators, one per output column; every
            // addend lands on its own lane in ascending-i order — the
            // scalar kernel's exact per-element sequence.
            let mut acc = [0.0f64; FWD_LANES];
            for (k, a) in acc.iter_mut().enumerate() {
                *a = bias[j0 + k] as f64;
            }
            for (i, &xi) in row.iter().enumerate() {
                let xi = xi as f64;
                let wrow = &w[i * d_out + j0..i * d_out + j0 + FWD_LANES];
                for (a, &wk) in acc.iter_mut().zip(wrow) {
                    *a += xi * wk as f64;
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                let v = a as f32;
                out[j0 + k] = if relu { v.max(0.0) } else { v };
            }
            j0 += FWD_LANES;
        }
        // Remainder columns take the shared scalar column path.
        for (j, slot) in out.iter_mut().enumerate().skip(tiles) {
            *slot = forward_column(row, w, bias, d_out, j, relu);
        }
    }
}

/// `dw` and `db` in one traversal of `dz`: per output-column panel the
/// `db` lanes accumulate during the `i = 0` pass of the `dw` walk. The
/// `db` accumulators receive the same addends in the same ascending-`b`
/// order the separate loop used — fusing removes a full second sweep
/// over `dz`; it reorders nothing within any single accumulator.
fn dw_db_fused_blocked(
    x: &[f32],
    batch: usize,
    d_in: usize,
    d_out: usize,
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    let col_tiles = d_out / FWD_LANES * FWD_LANES;
    let mut j0 = 0;
    while j0 < col_tiles {
        let mut dbacc = [0.0f64; FWD_LANES];
        for i in 0..d_in {
            let mut acc = [0.0f64; FWD_LANES];
            if i == 0 {
                for b in 0..batch {
                    let xi = x[b * d_in] as f64;
                    let dzrow = &dz[b * d_out + j0..b * d_out + j0 + FWD_LANES];
                    for ((a, d), &g) in acc.iter_mut().zip(dbacc.iter_mut()).zip(dzrow) {
                        let g = g as f64;
                        *a += xi * g;
                        *d += g;
                    }
                }
            } else {
                for b in 0..batch {
                    let xi = x[b * d_in + i] as f64;
                    let dzrow = &dz[b * d_out + j0..b * d_out + j0 + FWD_LANES];
                    for (a, &g) in acc.iter_mut().zip(dzrow) {
                        *a += xi * g as f64;
                    }
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                dw[i * d_out + j0 + k] = a as f32;
            }
        }
        for (k, &a) in dbacc.iter().enumerate() {
            db[j0 + k] = a as f32;
        }
        j0 += FWD_LANES;
    }
    // Remainder columns: scalar accumulators, same single-sweep fusion.
    for j in col_tiles..d_out {
        let mut dbacc = 0.0f64;
        for i in 0..d_in {
            let mut acc = 0.0f64;
            for b in 0..batch {
                let g = dz[b * d_out + j] as f64;
                acc += x[b * d_in + i] as f64 * g;
                if i == 0 {
                    dbacc += g;
                }
            }
            dw[i * d_out + j] = acc as f32;
        }
        db[j] = dbacc as f32;
    }
}

fn dx_blocked(w: &[f32], batch: usize, d_in: usize, d_out: usize, dz: &[f32], dx: &mut [f32]) {
    // dx[b, i] = Σ_j dz[b, j] · w[i, j]: i-lanes share each dz load
    // while every lane streams its own contiguous weight row; per
    // (b, i) the adds run in ascending-j order.
    let row_tiles = d_in / DX_LANES * DX_LANES;
    for b in 0..batch {
        let dzrow = &dz[b * d_out..(b + 1) * d_out];
        let mut i0 = 0;
        while i0 < row_tiles {
            let mut acc = [0.0f64; DX_LANES];
            for (j, &g) in dzrow.iter().enumerate() {
                let g = g as f64;
                for (k, a) in acc.iter_mut().enumerate() {
                    *a += g * w[(i0 + k) * d_out + j] as f64;
                }
            }
            for (k, &a) in acc.iter().enumerate() {
                dx[b * d_in + i0 + k] = a as f32;
            }
            i0 += DX_LANES;
        }
        for i in row_tiles..d_in {
            dx[b * d_in + i] = dx_element(w, d_out, dz, b, i);
        }
    }
}

// --- packed weight panels ---

/// One dense layer's weights re-strided for the blocked kernels: the
/// forward panels hold each [`FWD_LANES`]-column group contiguously per
/// input row; the `dx` panels hold each [`DX_LANES`]-row group
/// contiguously per output column. Values and per-accumulator read
/// order are untouched — packing is a pure permutation of storage, so
/// packed kernels are bit-identical to the blocked (and scalar) ones.
#[derive(Debug, Clone)]
pub(super) struct PackedLayer {
    d_in: usize,
    d_out: usize,
    /// Forward layout: full panels first (panel `p` starts at
    /// `p · d_in · FWD_LANES`; element `i · FWD_LANES + k` is
    /// `w[i · d_out + p · FWD_LANES + k]`), then the remainder columns
    /// packed at width `d_out % FWD_LANES` in the same row walk.
    fwd: Vec<f32>,
    /// `dx` layout: full panels first (panel `p` starts at
    /// `p · d_out · DX_LANES`; element `j · DX_LANES + k` is
    /// `w[(p · DX_LANES + k) · d_out + j]`), then the remainder rows
    /// verbatim row-major (the scalar fallback reads them as-is).
    dx: Vec<f32>,
}

impl PackedLayer {
    pub(super) fn pack(w: &[f32], d_in: usize, d_out: usize) -> PackedLayer {
        debug_assert_eq!(w.len(), d_in * d_out);
        let col_tiles = d_out / FWD_LANES * FWD_LANES;
        let mut fwd = Vec::with_capacity(d_in * d_out);
        let mut j0 = 0;
        while j0 < col_tiles {
            for i in 0..d_in {
                fwd.extend_from_slice(&w[i * d_out + j0..i * d_out + j0 + FWD_LANES]);
            }
            j0 += FWD_LANES;
        }
        if col_tiles < d_out {
            for i in 0..d_in {
                fwd.extend_from_slice(&w[i * d_out + col_tiles..(i + 1) * d_out]);
            }
        }
        let row_tiles = d_in / DX_LANES * DX_LANES;
        let mut dx = Vec::with_capacity(d_in * d_out);
        let mut i0 = 0;
        while i0 < row_tiles {
            for j in 0..d_out {
                for k in 0..DX_LANES {
                    dx.push(w[(i0 + k) * d_out + j]);
                }
            }
            i0 += DX_LANES;
        }
        for i in row_tiles..d_in {
            dx.extend_from_slice(&w[i * d_out..(i + 1) * d_out]);
        }
        PackedLayer { d_in, d_out, fwd, dx }
    }

    pub(super) fn d_in(&self) -> usize {
        self.d_in
    }

    pub(super) fn d_out(&self) -> usize {
        self.d_out
    }

    fn bytes(&self) -> usize {
        (self.fwd.capacity() + self.dx.capacity()) * std::mem::size_of::<f32>()
    }

    /// Forward pass over the packed panels into a caller-owned buffer.
    /// Per output element: the blocked kernel's exact addend sequence
    /// (bias seed, ascending-`i` f64 adds, one `as f32` cast) — only
    /// the weight *addresses* changed, to unit stride.
    pub(super) fn forward_into(
        &self,
        x: &[f32],
        batch: usize,
        bias: &[f32],
        relu: bool,
        y: &mut Vec<f32>,
    ) {
        let (d_in, d_out) = (self.d_in, self.d_out);
        debug_assert_eq!(x.len(), batch * d_in);
        debug_assert_eq!(bias.len(), d_out);
        y.clear();
        y.resize(batch * d_out, 0.0);
        let col_tiles = d_out / FWD_LANES * FWD_LANES;
        let rem = d_out - col_tiles;
        for b in 0..batch {
            let row = &x[b * d_in..(b + 1) * d_in];
            let out = &mut y[b * d_out..(b + 1) * d_out];
            let mut j0 = 0;
            while j0 < col_tiles {
                let panel = &self.fwd[j0 * d_in..(j0 + FWD_LANES) * d_in];
                let mut acc = [0.0f64; FWD_LANES];
                for (k, a) in acc.iter_mut().enumerate() {
                    *a = bias[j0 + k] as f64;
                }
                for (i, &xi) in row.iter().enumerate() {
                    let xi = xi as f64;
                    let wrow = &panel[i * FWD_LANES..i * FWD_LANES + FWD_LANES];
                    for (a, &wk) in acc.iter_mut().zip(wrow) {
                        *a += xi * wk as f64;
                    }
                }
                for (k, &a) in acc.iter().enumerate() {
                    let v = a as f32;
                    out[j0 + k] = if relu { v.max(0.0) } else { v };
                }
                j0 += FWD_LANES;
            }
            if rem > 0 {
                let tail = &self.fwd[col_tiles * d_in..];
                for k in 0..rem {
                    // forward_column's addend sequence for column
                    // col_tiles + k, read from the packed tail.
                    let mut acc = bias[col_tiles + k] as f64;
                    for (i, &xi) in row.iter().enumerate() {
                        acc += xi as f64 * tail[i * rem + k] as f64;
                    }
                    let v = acc as f32;
                    out[col_tiles + k] = if relu { v.max(0.0) } else { v };
                }
            }
        }
    }

    /// `dx[b, i] = Σ_j dz[b, j] · w[i, j]` over the packed row panels
    /// into a caller-owned buffer; per element, the blocked kernel's
    /// ascending-`j` addend sequence.
    pub(super) fn dx_into(&self, dz: &[f32], batch: usize, dx: &mut Vec<f32>) {
        let (d_in, d_out) = (self.d_in, self.d_out);
        debug_assert_eq!(dz.len(), batch * d_out);
        dx.clear();
        dx.resize(batch * d_in, 0.0);
        let row_tiles = d_in / DX_LANES * DX_LANES;
        for b in 0..batch {
            let dzrow = &dz[b * d_out..(b + 1) * d_out];
            let out = &mut dx[b * d_in..(b + 1) * d_in];
            let mut i0 = 0;
            while i0 < row_tiles {
                let panel = &self.dx[i0 * d_out..(i0 + DX_LANES) * d_out];
                let mut acc = [0.0f64; DX_LANES];
                for (j, &g) in dzrow.iter().enumerate() {
                    let g = g as f64;
                    let lanes = &panel[j * DX_LANES..j * DX_LANES + DX_LANES];
                    for (a, &wk) in acc.iter_mut().zip(lanes) {
                        *a += g * wk as f64;
                    }
                }
                for (k, &a) in acc.iter().enumerate() {
                    out[i0 + k] = a as f32;
                }
                i0 += DX_LANES;
            }
            if row_tiles < d_in {
                let tail = &self.dx[row_tiles * d_out..];
                for i in row_tiles..d_in {
                    let wrow = &tail[(i - row_tiles) * d_out..(i - row_tiles + 1) * d_out];
                    let mut acc = 0.0f64;
                    for (j, &g) in dzrow.iter().enumerate() {
                        acc += g as f64 * wrow[j] as f64;
                    }
                    out[i] = acc as f32;
                }
            }
        }
    }
}

/// A whole network's weights packed for the blocked kernels, tagged
/// with the [`crate::runtime::QParams::digest`] they were built from.
/// The fused trainer keeps the most recent pack and re-strides only
/// when the digest changes — within a shared-campaign round, the
/// batched greedy hints and every fused training GEMM run over one
/// master, so they share one pack.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    digest: u64,
    layers: Vec<PackedLayer>,
}

impl PackedWeights {
    pub(super) fn from_layers(digest: u64, layers: Vec<PackedLayer>) -> PackedWeights {
        PackedWeights { digest, layers }
    }

    /// The parameter digest this pack was built from.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    pub(super) fn layers(&self) -> &[PackedLayer] {
        &self.layers
    }

    /// Bytes held by the packed panels (scratch accounting).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(PackedLayer::bytes).sum()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-1.5, 1.5) as f32).collect()
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn blocked_forward_is_bitwise_scalar_across_shapes() {
        // Shapes straddle the lane width: below, at, above and far past
        // FWD_LANES, with and without remainders, batch 1..=9.
        let mut rng = Rng::new(42);
        for &(d_in, d_out) in
            &[(1, 1), (3, 2), (2, 8), (5, 9), (7, 13), (18, 64), (64, 13), (6, 16)]
        {
            for batch in [1, 2, 5, 9] {
                let x = random_vec(&mut rng, batch * d_in);
                let w = random_vec(&mut rng, d_in * d_out);
                let bias = random_vec(&mut rng, d_out);
                for relu in [false, true] {
                    let a = dense_forward(
                        DenseKernel::Scalar,
                        &x,
                        batch,
                        d_in,
                        &w,
                        &bias,
                        d_out,
                        relu,
                    );
                    let b = dense_forward(
                        DenseKernel::Blocked,
                        &x,
                        batch,
                        d_in,
                        &w,
                        &bias,
                        d_out,
                        relu,
                    );
                    assert_eq!(bits(&a), bits(&b), "{d_in}x{d_out} batch {batch} relu {relu}");
                }
            }
        }
    }

    #[test]
    fn blocked_backward_is_bitwise_scalar_across_shapes() {
        let mut rng = Rng::new(7);
        for &(d_in, d_out) in &[(1, 1), (4, 3), (5, 8), (9, 13), (18, 64), (64, 13), (3, 17)] {
            for batch in [1, 2, 6, 11] {
                let x = random_vec(&mut rng, batch * d_in);
                let w = random_vec(&mut rng, d_in * d_out);
                let dz = random_vec(&mut rng, batch * d_out);
                let (dw_s, db_s, dx_s) =
                    dense_backward(DenseKernel::Scalar, &x, batch, d_in, &w, d_out, &dz);
                let (dw_b, db_b, dx_b) =
                    dense_backward(DenseKernel::Blocked, &x, batch, d_in, &w, d_out, &dz);
                assert_eq!(bits(&dw_s), bits(&dw_b), "dw {d_in}x{d_out} batch {batch}");
                assert_eq!(bits(&db_s), bits(&db_b), "db {d_in}x{d_out} batch {batch}");
                assert_eq!(bits(&dx_s), bits(&dx_b), "dx {d_in}x{d_out} batch {batch}");
            }
        }
    }

    #[test]
    fn packed_forward_and_dx_are_bitwise_scalar_across_shapes() {
        // Same shape sweep as the blocked kernels: packing must be a
        // pure permutation of storage, never of arithmetic.
        let mut rng = Rng::new(19);
        for &(d_in, d_out) in
            &[(1, 1), (3, 2), (2, 8), (5, 9), (7, 13), (18, 64), (64, 13), (4, 16)]
        {
            for batch in [1, 2, 5, 9] {
                let x = random_vec(&mut rng, batch * d_in);
                let w = random_vec(&mut rng, d_in * d_out);
                let bias = random_vec(&mut rng, d_out);
                let dz = random_vec(&mut rng, batch * d_out);
                let pl = PackedLayer::pack(&w, d_in, d_out);
                for relu in [false, true] {
                    let want =
                        dense_forward(DenseKernel::Scalar, &x, batch, d_in, &w, &bias, d_out, relu);
                    let mut got = Vec::new();
                    pl.forward_into(&x, batch, &bias, relu, &mut got);
                    assert_eq!(
                        bits(&want),
                        bits(&got),
                        "fwd {d_in}x{d_out} batch {batch} relu {relu}"
                    );
                }
                let (_, _, dx_want) =
                    dense_backward(DenseKernel::Scalar, &x, batch, d_in, &w, d_out, &dz);
                let mut dx_got = Vec::new();
                pl.dx_into(&dz, batch, &mut dx_got);
                assert_eq!(bits(&dx_want), bits(&dx_got), "dx {d_in}x{d_out} batch {batch}");
            }
        }
    }

    #[test]
    fn backward_halves_reuse_warm_buffers() {
        // The _into entry points must fully overwrite whatever a warm
        // buffer held (the fused trainer reuses them across rounds).
        let mut rng = Rng::new(23);
        let (d_in, d_out, batch) = (5, 9, 3);
        let x = random_vec(&mut rng, batch * d_in);
        let w = random_vec(&mut rng, d_in * d_out);
        let dz = random_vec(&mut rng, batch * d_out);
        let (dw_want, db_want, dx_want) =
            dense_backward(DenseKernel::Blocked, &x, batch, d_in, &w, d_out, &dz);
        let mut dw = vec![7.0f32; 99];
        let mut db = vec![7.0f32; 1];
        let mut dx = vec![7.0f32; 2];
        backward_dw_db(DenseKernel::Blocked, &x, batch, d_in, d_out, &dz, &mut dw, &mut db);
        backward_dx_into(DenseKernel::Blocked, &w, batch, d_in, d_out, &dz, &mut dx);
        assert_eq!(bits(&dw_want), bits(&dw));
        assert_eq!(bits(&db_want), bits(&db));
        assert_eq!(bits(&dx_want), bits(&dx));
    }

    #[test]
    fn blocked_forward_matches_hand_computation_with_remainder() {
        // d_out = 2 < FWD_LANES: the whole output is remainder columns,
        // which must be the scalar column computation exactly.
        let y = dense_forward(
            DenseKernel::Blocked,
            &[1.0, 2.0],
            1,
            2,
            &[1.0, 2.0, 3.0, 4.0],
            &[0.5, -0.5],
            2,
            false,
        );
        assert_eq!(y, vec![7.5, 9.5]);
    }

    #[test]
    fn packed_weights_track_digest_and_bytes() {
        let w = vec![1.0f32; 6];
        let pw = PackedWeights::from_layers(0xfeed, vec![PackedLayer::pack(&w, 2, 3)]);
        assert_eq!(pw.digest(), 0xfeed);
        assert_eq!(pw.layers().len(), 1);
        assert!(pw.bytes() >= 2 * 6 * std::mem::size_of::<f32>());
        assert_eq!(pw.layers()[0].d_in(), 2);
        assert_eq!(pw.layers()[0].d_out(), 3);
    }

    #[test]
    fn kernel_names_and_default() {
        assert_eq!(DenseKernel::default(), DenseKernel::Blocked);
        assert_eq!(DenseKernel::Scalar.name(), "scalar");
        assert_eq!(DenseKernel::Blocked.name(), "blocked");
        assert_eq!(DenseKernel::ALL.len(), 2);
    }
}
