//! Adam optimizer step over host-side parameter buffers.
//!
//! The native analogue of the optimizer half of the fused `q_train`
//! artifact (`python/compile/model.py::train_step`), and the primitive
//! the [`crate::coordinator::LearnerHub`] uses in gradient-merge mode
//! (`MergeMode::Grads` applies one step per merge round to the master
//! state). Elementwise arithmetic runs in `f64` and stores back `f32`,
//! sequenced tensor-by-tensor in canonical order — the update is a pure
//! function of `(params, opt, grads, lr)`, with no accumulation-order
//! freedom at all.

use anyhow::Result;

use crate::runtime::{AdamState, QParams};

/// First-moment decay (matches `model.ADAM_B1`).
pub const ADAM_BETA1: f64 = 0.9;
/// Second-moment decay (matches `model.ADAM_B2`).
pub const ADAM_BETA2: f64 = 0.999;
/// Denominator stabilizer (matches `model.ADAM_EPS`).
pub const ADAM_EPS: f64 = 1e-8;

/// One in-place Adam update of `params`/`opt` with the given raw
/// gradients: `m ← β₁m + (1−β₁)g`, `v ← β₂v + (1−β₂)g²`,
/// `p ← p − lr · m̂ / (√v̂ + ε)` with bias-corrected `m̂`, `v̂`, and
/// `opt.step` advanced by one.
pub fn adam_step(
    params: &mut QParams,
    opt: &mut AdamState,
    grads: &QParams,
    lr: f32,
) -> Result<()> {
    anyhow::ensure!(grads.same_shape(params), "gradient shapes do not match the parameters");
    anyhow::ensure!(
        opt.m.same_shape(params) && opt.v.same_shape(params),
        "optimizer moment shapes do not match the parameters"
    );
    let t = opt.step as f64 + 1.0;
    let bc1 = 1.0 - ADAM_BETA1.powf(t);
    let bc2 = 1.0 - ADAM_BETA2.powf(t);
    for ti in 0..params.tensors.len() {
        let g = &grads.tensors[ti].0;
        let p = &mut params.tensors[ti].0;
        let m = &mut opt.m.tensors[ti].0;
        let v = &mut opt.v.tensors[ti].0;
        for k in 0..p.len() {
            let gk = g[k] as f64;
            let mk = ADAM_BETA1 * m[k] as f64 + (1.0 - ADAM_BETA1) * gk;
            let vk = ADAM_BETA2 * v[k] as f64 + (1.0 - ADAM_BETA2) * gk * gk;
            let update = lr as f64 * (mk / bc1) / ((vk / bc2).sqrt() + ADAM_EPS);
            m[k] = mk as f32;
            v[k] = vk as f32;
            p[k] = (p[k] as f64 - update) as f32;
        }
    }
    opt.step = t as f32;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn flat(values: Vec<f32>) -> QParams {
        let n = values.len();
        QParams::from_flat(vec![(values, vec![n])]).unwrap()
    }

    #[test]
    fn first_step_moves_by_lr_in_the_gradient_sign() {
        // At t = 1 the bias corrections cancel the decay factors
        // exactly: m̂ = g, v̂ = g², so the update is lr·g/(|g| + ε) ≈
        // lr·sign(g) for any nonzero gradient.
        let mut p = flat(vec![1.0, -2.0, 3.0]);
        let mut opt = AdamState::new(&p);
        let g = flat(vec![4.0, -0.25, 0.0]);
        adam_step(&mut p, &mut opt, &g, 0.5).unwrap();
        let got = &p.tensors[0].0;
        assert!((got[0] - 0.5).abs() < 1e-6, "{got:?}");
        assert!((got[1] - -1.5).abs() < 1e-6, "{got:?}");
        assert_eq!(got[2], 3.0, "zero gradient leaves the weight untouched");
        assert_eq!(opt.step, 1.0);
        assert!((opt.m.tensors[0].0[0] - 0.4).abs() < 1e-6, "m = (1−β₁)g");
        assert!((opt.v.tensors[0].0[0] - 0.016).abs() < 1e-6, "v = (1−β₂)g²");
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let mut p = flat(vec![0.0, 0.0]);
        let mut opt = AdamState::new(&p);
        let bad = flat(vec![0.0; 3]);
        assert!(adam_step(&mut p, &mut opt, &bad, 0.1).is_err());
        // Moment-shape mismatch is caught too, not just gradient shape.
        let g = flat(vec![1.0, 1.0]);
        opt.m = bad.zeros_like();
        assert!(adam_step(&mut p, &mut opt, &g, 0.1).is_err());
    }

    #[test]
    fn repeated_steps_advance_the_counter_and_stay_finite() {
        let mut p = flat(vec![1.0]);
        let mut opt = AdamState::new(&p);
        let g = flat(vec![1.0]);
        for i in 1..=50 {
            adam_step(&mut p, &mut opt, &g, 0.1).unwrap();
            assert_eq!(opt.step, i as f32);
        }
        assert!(p.tensors[0].0[0].is_finite());
        assert!(p.tensors[0].0[0] < 1.0, "constant positive gradient must descend");
    }
}
