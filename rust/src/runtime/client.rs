//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;

use anyhow::{Context, Result};

use super::xla;

/// Process-wide PJRT client; create once, share by reference.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Construct the PJRT CPU client.
    pub fn cpu() -> Result<RuntimeClient> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the
    /// single device output is a tuple literal which we decompose.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed inputs (hot path: callers keep long-lived
    /// literals — e.g. cached Q-net parameters — and avoid re-uploading
    /// them every call).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        literal
            .to_tuple()
            .with_context(|| format!("decomposing result tuple of {}", self.name))
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Build a rank-2 f32 literal from a flat row-major slice.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(
        data.len() == rows * cols,
        "literal shape mismatch: {} elements for [{rows},{cols}]",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a rank-1 f32 literal.
pub fn literal_f32_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Build a scalar f32 literal.
pub fn literal_f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}
