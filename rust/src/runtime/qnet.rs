//! The deep Q-network as seen from the coordinator: one unified [`QNet`]
//! surface dispatching over the [`QBackend`] seam.
//!
//! Two engines implement the seam:
//!
//! * [`QBackend::Native`] — the default: a pure-Rust MLP
//!   ([`NativeQNet`]) constructed straight from a backend's
//!   `(state_dim, num_actions)`. No artifacts, no manifest, works for
//!   **every** [`crate::backend::TunableRuntime`], and reports realized
//!   per-sample TD errors plus raw gradients (adaptive PER and
//!   gradient-level hub merging need both).
//! * [`QBackend::Aot`] — the original AOT/PJRT artifact path
//!   ([`AotQNet`]), preserved unchanged for layouts that have compiled
//!   artifacts (the coarrays 18×13 today; requires the `pjrt` feature +
//!   `make artifacts` at run time). Its fused `q_train` returns only
//!   the batch loss, so it keeps the |reward| replay-priority proxy.
//!
//! The seam contract both engines honor: `q_values` is a pure function
//! of `(params, state)`; `train` consumes one [`TrainBatch`], applies
//! exactly one optimizer step, records the loss in a **bounded**
//! [`LossRing`], and returns a [`TrainOutcome`]; `set_state` swaps
//! parameters *and* Adam moments together (the hub-pull entry point).

use anyhow::Result;

use super::aot::AotQNet;
use super::native::NativeQNet;
use super::params::{AdamState, QParams};
use crate::util::rng::Rng;

/// One replay minibatch in flat row-major layout.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    /// `[B * state_dim]`
    pub states: Vec<f32>,
    /// `[B * num_actions]` one-hot
    pub actions_onehot: Vec<f32>,
    /// `[B]`
    pub rewards: Vec<f32>,
    /// `[B * state_dim]`
    pub next_states: Vec<f32>,
    /// `[B]` (1.0 = terminal)
    pub done: Vec<f32>,
}

impl TrainBatch {
    pub fn validate(&self, batch: usize, state_dim: usize, num_actions: usize) -> Result<()> {
        anyhow::ensure!(self.states.len() == batch * state_dim, "states size");
        anyhow::ensure!(self.actions_onehot.len() == batch * num_actions, "actions size");
        anyhow::ensure!(self.rewards.len() == batch, "rewards size");
        anyhow::ensure!(self.next_states.len() == batch * state_dim, "next_states size");
        anyhow::ensure!(self.done.len() == batch, "done size");
        Ok(())
    }
}

/// What one training update reports back: the scalar loss, plus — when
/// the engine can produce them — the *realized per-sample TD errors*,
/// in batch row order. The controller feeds those back into the replay
/// layer's priority state (adaptive prioritized replay); `None` means
/// "no per-sample signal available" and the prioritized policy keeps
/// its static `|reward|` proxy.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub loss: f32,
    pub td_errors: Option<Vec<f32>>,
}

/// Fixed-capacity ring of recent training losses plus running
/// count/mean — the bounded replacement for the per-step `loss_history`
/// vector that used to grow without limit over multi-thousand-run
/// campaigns. Keeps the last [`LossRing::capacity`] values for curve
/// diagnostics and exact running statistics over everything observed.
#[derive(Debug, Clone)]
pub struct LossRing {
    recent: Vec<f32>,
    /// Next overwrite position once the window is full.
    head: usize,
    observed: usize,
    sum: f64,
    capacity: usize,
}

impl LossRing {
    /// Default retained-window size (observations, not bytes).
    pub const DEFAULT_CAPACITY: usize = 256;

    pub fn new(capacity: usize) -> LossRing {
        assert!(capacity > 0);
        LossRing { recent: Vec::new(), head: 0, observed: 0, sum: 0.0, capacity }
    }

    pub fn push(&mut self, loss: f32) {
        self.sum += loss as f64;
        self.observed += 1;
        if self.recent.len() < self.capacity {
            self.recent.push(loss);
        } else {
            self.recent[self.head] = loss;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Losses observed over the lifetime (not just those retained).
    pub fn len(&self) -> usize {
        self.observed
    }

    pub fn is_empty(&self) -> bool {
        self.observed == 0
    }

    /// How many observations the window currently retains.
    pub fn retained(&self) -> usize {
        self.recent.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Running mean over **all** observed losses (not just the window).
    pub fn mean(&self) -> f32 {
        if self.observed == 0 {
            0.0
        } else {
            (self.sum / self.observed as f64) as f32
        }
    }

    /// Most recently recorded loss.
    pub fn last(&self) -> Option<f32> {
        if self.recent.is_empty() {
            return None;
        }
        let idx = if self.recent.len() < self.capacity {
            self.recent.len() - 1
        } else {
            (self.head + self.capacity - 1) % self.capacity
        };
        Some(self.recent[idx])
    }

    /// The retained window, oldest → newest.
    pub fn recent(&self) -> Vec<f32> {
        if self.recent.len() < self.capacity {
            return self.recent.clone();
        }
        (0..self.capacity).map(|k| self.recent[(self.head + k) % self.capacity]).collect()
    }
}

impl Default for LossRing {
    fn default() -> LossRing {
        LossRing::new(LossRing::DEFAULT_CAPACITY)
    }
}

/// Which engine computes Q-values and training updates — the seam that
/// decouples deep-RL tuning from per-backend compiled artifacts.
pub enum QBackend {
    /// Pure-Rust MLP engine (default): dimension-generic, no manifest.
    Native(NativeQNet),
    /// AOT-compiled PJRT artifacts (the original path).
    Aot(AotQNet),
}

/// The coordinator-facing Q-network: a thin dispatcher over [`QBackend`].
pub struct QNet {
    engine: QBackend,
}

impl QNet {
    /// Native engine with the standard architecture, sized for a
    /// backend's `(state_dim, num_actions)` — no artifacts involved.
    pub fn native(state_dim: usize, num_actions: usize, rng: &mut Rng) -> QNet {
        let net = NativeQNet::with_default_shape(state_dim, num_actions, rng);
        QNet { engine: QBackend::Native(net) }
    }

    /// Wrap a loaded AOT engine.
    pub fn from_aot(net: AotQNet) -> QNet {
        QNet { engine: QBackend::Aot(net) }
    }

    pub fn engine(&self) -> &QBackend {
        &self.engine
    }

    pub fn engine_name(&self) -> &'static str {
        match &self.engine {
            QBackend::Native(_) => "native",
            QBackend::Aot(_) => "aot",
        }
    }

    pub fn state_dim(&self) -> usize {
        match &self.engine {
            QBackend::Native(n) => n.state_dim(),
            QBackend::Aot(a) => a.state_dim,
        }
    }

    pub fn num_actions(&self) -> usize {
        match &self.engine {
            QBackend::Native(n) => n.num_actions(),
            QBackend::Aot(a) => a.num_actions,
        }
    }

    pub fn replay_batch(&self) -> usize {
        match &self.engine {
            QBackend::Native(n) => n.replay_batch,
            QBackend::Aot(a) => a.replay_batch,
        }
    }

    pub fn params(&self) -> &QParams {
        match &self.engine {
            QBackend::Native(n) => &n.params,
            QBackend::Aot(a) => &a.params,
        }
    }

    pub fn opt(&self) -> &AdamState {
        match &self.engine {
            QBackend::Native(n) => &n.opt,
            QBackend::Aot(a) => &a.opt,
        }
    }

    /// Replace parameters and optimizer state together (hub pull).
    /// Both engines validate shapes themselves (same contract).
    pub fn set_state(&mut self, params: QParams, opt: AdamState) -> Result<()> {
        match &mut self.engine {
            QBackend::Native(n) => n.set_state(params, opt),
            QBackend::Aot(a) => a.set_state(params, opt),
        }
    }

    /// Q(s, ·) for one state.
    pub fn q_values(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        match &mut self.engine {
            QBackend::Native(n) => n.q_values(state),
            QBackend::Aot(a) => a.q_values(state),
        }
    }

    /// Q(s, ·) for a `[batch, state_dim]` flat matrix, returned as a
    /// `[batch, num_actions]` flat matrix. The native engine answers
    /// with one blocked batched forward; the AOT engine loops its
    /// fused single-state artifact (the batch layout is compiled in).
    /// Row `r` is bit-identical to `q_values(&states[r * dim..])` on
    /// both engines.
    pub fn q_values_batch(&mut self, states: &[f32], batch: usize) -> Result<Vec<f32>> {
        match &mut self.engine {
            QBackend::Native(n) => n.q_values_batch(states, batch),
            QBackend::Aot(a) => {
                let dim = a.state_dim;
                anyhow::ensure!(
                    batch > 0 && states.len() == batch * dim,
                    "batch states size {} != {batch} x {dim}",
                    states.len()
                );
                let mut out = Vec::with_capacity(batch * a.num_actions);
                for r in 0..batch {
                    out.extend(a.q_values(&states[r * dim..(r + 1) * dim])?);
                }
                Ok(out)
            }
        }
    }

    /// One Q-learning update. Returns the outcome plus, for the native
    /// engine, the raw gradients that were applied (the gradient-merge
    /// push payload; `None` from the fused AOT artifact).
    pub fn train(
        &mut self,
        batch: &TrainBatch,
        lr: f32,
        gamma: f32,
    ) -> Result<(TrainOutcome, Option<QParams>)> {
        match &mut self.engine {
            QBackend::Native(n) => {
                let (outcome, grads) = n.train_step(batch, lr, gamma)?;
                Ok((outcome, Some(grads)))
            }
            QBackend::Aot(a) => {
                // The fused q_train artifact returns only the batch
                // loss: no per-sample TD errors and no raw gradients
                // without a second device round-trip.
                let loss = a.train_step(batch, lr, gamma)?;
                Ok((TrainOutcome { loss, td_errors: None }, None))
            }
        }
    }

    /// Apply externally computed gradients exactly as [`QNet::train`]
    /// would apply its own (native engine only — the fused trainer's
    /// completion path). `train(batch, …)` and
    /// "compute grads elsewhere → `apply_train`" leave bit-identical
    /// engine state; see [`NativeQNet::apply_train`].
    pub fn apply_train(&mut self, grads: &QParams, loss: f32, lr: f32) -> Result<()> {
        match &mut self.engine {
            QBackend::Native(n) => n.apply_train(grads, loss, lr),
            QBackend::Aot(_) => anyhow::bail!(
                "externally computed gradients can only be applied to the native engine; \
                 the fused AOT artifact computes and applies its own"
            ),
        }
    }

    /// Fixed-Q-targets ablation step (AOT engine only).
    pub fn train_with_target(&mut self, batch: &TrainBatch, lr: f32, gamma: f32) -> Result<f32> {
        match &mut self.engine {
            QBackend::Aot(a) => a.train_step_with_target(batch, lr, gamma),
            QBackend::Native(_) => anyhow::bail!(
                "the fixed-Q-targets ablation runs on the AOT engine (--agent dqn-target); \
                 the native engine implements the paper-faithful no-target update only"
            ),
        }
    }

    /// Is the fixed-Q-targets artifact available?
    pub fn has_target_network(&self) -> bool {
        match &self.engine {
            QBackend::Aot(a) => a.has_target_network(),
            QBackend::Native(_) => false,
        }
    }

    /// Copy the online network into the frozen target (AOT ablation).
    pub fn sync_target(&mut self) {
        if let QBackend::Aot(a) = &mut self.engine {
            a.sync_target();
        }
    }

    /// Bounded training-loss diagnostics.
    pub fn losses(&self) -> &LossRing {
        match &self.engine {
            QBackend::Native(n) => &n.losses,
            QBackend::Aot(a) => &a.loss_history,
        }
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn train_batch_validation() {
        let b = TrainBatch {
            states: vec![0.0; 4],
            actions_onehot: vec![0.0; 6],
            rewards: vec![0.0; 2],
            next_states: vec![0.0; 4],
            done: vec![0.0; 2],
        };
        assert!(b.validate(2, 2, 3).is_ok());
        assert!(b.validate(2, 3, 3).is_err());
    }

    #[test]
    fn loss_ring_is_bounded_with_exact_running_stats() {
        let mut ring = LossRing::new(4);
        assert!(ring.is_empty());
        assert_eq!(ring.last(), None);
        for i in 1..=10 {
            ring.push(i as f32);
        }
        // Lifetime stats cover all ten observations...
        assert_eq!(ring.len(), 10);
        assert_eq!(ring.mean(), 5.5);
        assert_eq!(ring.last(), Some(10.0));
        // ...while memory holds only the newest four, in order.
        assert_eq!(ring.retained(), 4);
        assert_eq!(ring.recent(), vec![7.0, 8.0, 9.0, 10.0]);
    }

    #[test]
    fn loss_ring_below_capacity_keeps_everything() {
        let mut ring = LossRing::new(8);
        ring.push(2.0);
        ring.push(4.0);
        assert_eq!(ring.recent(), vec![2.0, 4.0]);
        assert_eq!(ring.last(), Some(4.0));
        assert_eq!(ring.mean(), 3.0);
        assert_eq!(ring.retained(), 2);
    }

    #[test]
    fn native_qnet_dispatches_through_the_seam() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mut q = QNet::native(6, 4, &mut rng);
        assert_eq!(q.engine_name(), "native");
        assert_eq!((q.state_dim(), q.num_actions()), (6, 4));
        assert!(!q.has_target_network());
        let qs = q.q_values(&[0.1; 6]).unwrap();
        assert_eq!(qs.len(), 4);
        // The ablation entry point is AOT-only and says so.
        let batch = TrainBatch {
            states: vec![0.0; 6],
            actions_onehot: vec![1.0, 0.0, 0.0, 0.0],
            rewards: vec![0.0],
            next_states: vec![0.0; 6],
            done: vec![1.0],
        };
        assert!(q.train_with_target(&batch, 1e-3, 0.9).is_err());
        let (outcome, grads) = q.train(&batch, 1e-3, 0.9).unwrap();
        assert!(outcome.td_errors.is_some(), "native engine reports per-sample TDs");
        assert!(grads.is_some(), "native engine exposes raw gradients");
        assert_eq!(q.losses().len(), 1);
    }

    #[test]
    fn q_values_batch_rows_match_single_calls() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let mut q = QNet::native(3, 5, &mut rng);
        let batch = 4;
        let states: Vec<f32> = (0..batch * 3).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let flat = q.q_values_batch(&states, batch).unwrap();
        assert_eq!(flat.len(), batch * 5);
        for r in 0..batch {
            let single = q.q_values(&states[r * 3..(r + 1) * 3]).unwrap();
            let row: Vec<u32> = flat[r * 5..(r + 1) * 5].iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = single.iter().map(|x| x.to_bits()).collect();
            assert_eq!(row, want, "row {r}");
        }
        assert!(q.q_values_batch(&states, batch + 1).is_err());
    }
}
