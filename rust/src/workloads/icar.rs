//! ICAR — the Intermediate Complexity Atmospheric Research model (§6.1).
//!
//! The CAF version (Gutmann/Rouson) domain-decomposes the atmosphere and
//! per timestep: advects/microphysics over local columns, then exchanges
//! halo columns with neighbours using coarray *puts* (the paper notes
//! ICAR "attempts to overlap computation with communication by using
//! coarray puts instead of gets"), then synchronizes. Every few steps it
//! broadcasts forcing data and reduces diagnostics (the IO part).
//!
//! Skeleton properties that drive the paper's observed landscape:
//!
//! * 1-D decomposition in x ⇒ halo size is *independent of image count*
//!   while compute shrinks — strong scaling makes communication relatively
//!   more expensive at 512 images than 256 (paper: 13% → 25% win).
//! * halo messages (~240 KiB with the default problem) sit *above* the
//!   default 128 KiB eager threshold ⇒ rendezvous handshakes with
//!   compute-busy targets; raising the threshold ×10 (the paper's human
//!   tuning) or enabling ASYNC_PROGRESS (AITuning's find) both fix it.
//! * terrain-induced load imbalance staggers images, putting pressure on
//!   poll/yield behaviour at the per-step sync (§6.2's
//!   POLLS_BEFORE_YIELD effect, growing with image count).

use super::spec::Workload;
use crate::coarray::CafProgram;
use crate::util::rng::Rng;

/// ICAR communication skeleton (strong-scaling test case).
#[derive(Debug, Clone)]
pub struct Icar {
    /// Global columns in x (decomposed dimension).
    pub nx: usize,
    /// Columns in y (undecomposed).
    pub ny: usize,
    /// Vertical levels.
    pub nz: usize,
    /// Prognostic variables exchanged in halos.
    pub nvars: usize,
    /// Timesteps simulated.
    pub steps: usize,
    /// Compute time per grid cell per step, µs.
    pub cell_us: f64,
    /// Static per-image load imbalance (fraction, terrain-driven).
    pub imbalance: f64,
    /// Halo-exchange rounds per step (u/v, thermodynamics, moisture).
    pub halo_rounds: usize,
    /// Broadcast forcing + reduce diagnostics every `io_every` steps.
    pub io_every: usize,
}

impl Default for Icar {
    fn default() -> Icar {
        Icar {
            nx: 8192,
            ny: 256,
            nz: 24,
            nvars: 12,
            steps: 20,
            cell_us: 0.010,
            imbalance: 0.08,
            halo_rounds: 3,
            io_every: 10,
        }
    }
}

impl Icar {
    /// Bytes of one halo message (2-wide halo of `nvars` f32 fields
    /// across the full y–z face) — independent of image count.
    pub fn halo_bytes(&self) -> u64 {
        (2 * self.ny * self.nz * self.nvars * 4) as u64
    }

    /// Per-image compute per step at `images`, µs (before imbalance).
    pub fn compute_us(&self, images: usize) -> f64 {
        let cells = (self.nx / images).max(1) * self.ny * self.nz;
        cells as f64 * self.cell_us
    }
}

impl Workload for Icar {
    fn name(&self) -> &'static str {
        "icar"
    }

    fn build(&self, images: usize, rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2, "ICAR needs at least 2 images");
        let halo = self.halo_bytes();
        // Static terrain factor per image (mountainous columns cost more).
        let factors: Vec<f64> = (0..images)
            .map(|_| 1.0 + self.imbalance * rng.f64())
            .collect();
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                let west = if img == 1 { images } else { img - 1 };
                let east = if img == images { 1 } else { img + 1 };
                let compute = self.compute_us(images) * factors[img - 1];
                let round_halo = halo / self.halo_rounds as u64;
                for step in 0..self.steps {
                    // ICAR overlaps communication with computation by
                    // issuing halo *puts* first, then computing the
                    // interior while boundaries fly (§6.2). Each field
                    // group (dynamics, thermo, moisture) is exchanged
                    // and synchronized separately. Without async
                    // progress the rendezvous handshake stalls until
                    // the target reaches its sync, exposing the
                    // transfer; eager or async-progress configurations
                    // genuinely overlap it.
                    for _ in 0..self.halo_rounds {
                        p.put(west, round_halo);
                        p.put(east, round_halo);
                        p.compute(compute / self.halo_rounds as f64);
                        p.sync_all();
                    }
                    if step % self.io_every == self.io_every - 1 {
                        p.co_broadcast(32 * 1024); // forcing data
                        p.co_sum(256); // domain diagnostics
                    }
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn halo_is_above_default_eager_threshold() {
        let icar = Icar::default();
        let halo = icar.halo_bytes();
        let per_round = halo / icar.halo_rounds as u64;
        assert!(per_round > 131_072, "round halo {per_round} must exceed default eager max");
        assert!(per_round < 1_310_720, "round halo {per_round} must fall below 10x eager max");
        assert_eq!(halo, 589_824);
    }

    #[test]
    fn strong_scaling_compute_shrinks() {
        let icar = Icar::default();
        assert!(icar.compute_us(512) < icar.compute_us(256));
        assert_eq!(icar.halo_bytes(), icar.halo_bytes()); // halo constant
    }

    #[test]
    fn skeleton_runs_in_simulator() {
        let icar = Icar { steps: 3, ..Icar::default() };
        let mut rng = Rng::new(1);
        let progs = icar.build(8, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 8);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        // 8 images × 3 steps × 3 rounds × 2 neighbours = 144 puts
        assert_eq!(stats.eager_msgs + stats.rendezvous_msgs, 144);
        assert!(stats.rendezvous_msgs > 0, "default config should use rendezvous");
        assert!(stats.total_time_us > 0.0);
    }

    #[test]
    fn imbalance_spreads_compute() {
        let icar = Icar::default();
        let mut rng = Rng::new(2);
        let progs = icar.build(16, &mut rng);
        let first_compute = |p: &CafProgram| -> f64 {
            p.ops
                .iter()
                .find_map(|op| match op {
                    crate::coarray::CafOp::Compute { us } => Some(*us),
                    _ => None,
                })
                .unwrap()
        };
        let times: Vec<f64> = progs.iter().map(first_compute).collect();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        assert!(max > min, "terrain imbalance must differentiate images");
        assert!(max / min < 1.2);
    }
}
