//! Lattice-Boltzmann flow solver (CAF port, cf. Rosales XSW'13) — one of
//! the paper's training codes.
//!
//! Pattern: 1-D slab decomposition; per step a collide (compute) phase
//! then streaming of distribution functions to the two slab neighbours
//! (medium puts), with a density/momentum reduction every few steps.
//! Very regular and balanced; mostly eager-size messages — a contrast to
//! ICAR that teaches the agent protocol thresholds don't always bind.

use super::spec::Workload;
use crate::coarray::CafProgram;
use crate::util::rng::Rng;

/// LBM communication skeleton (D2Q9-style slabs).
#[derive(Debug, Clone)]
pub struct LatticeBoltzmann {
    /// Lattice sites per side (square lattice).
    pub n: usize,
    /// Timesteps.
    pub steps: usize,
    /// Compute per site per step, µs.
    pub site_us: f64,
    /// Distributions streamed across a slab boundary (of 9, 3 cross).
    pub cross_dists: usize,
    /// Reduce macroscopic quantities every `reduce_every` steps.
    pub reduce_every: usize,
}

impl Default for LatticeBoltzmann {
    fn default() -> LatticeBoltzmann {
        LatticeBoltzmann { n: 2048, steps: 40, site_us: 0.003, cross_dists: 3, reduce_every: 5 }
    }
}

impl LatticeBoltzmann {
    fn boundary_bytes(&self) -> u64 {
        (self.n * self.cross_dists * 8) as u64
    }

    fn compute_us(&self, images: usize) -> f64 {
        (self.n * self.n) as f64 / images as f64 * self.site_us
    }
}

impl Workload for LatticeBoltzmann {
    fn name(&self) -> &'static str {
        "lattice_boltzmann"
    }

    fn build(&self, images: usize, _rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2);
        let boundary = self.boundary_bytes();
        let compute = self.compute_us(images);
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                let up = if img == 1 { images } else { img - 1 };
                let down = if img == images { 1 } else { img + 1 };
                for step in 0..self.steps {
                    p.compute(compute); // collide
                    p.put(up, boundary); // stream up
                    p.put(down, boundary); // stream down
                    p.sync_all();
                    if step % self.reduce_every == self.reduce_every - 1 {
                        p.co_sum(24); // rho, ux, uy
                    }
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn boundary_is_eager_sized_by_default() {
        let lbm = LatticeBoltzmann::default();
        assert!(lbm.boundary_bytes() <= 131_072, "{}", lbm.boundary_bytes());
    }

    #[test]
    fn runs_and_reduces() {
        let lbm = LatticeBoltzmann { steps: 5, ..LatticeBoltzmann::default() };
        let mut rng = Rng::new(4);
        let progs = lbm.build(8, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::edison(), CvarSet::vanilla(), 8);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        assert_eq!(stats.collectives, 1); // steps=5, reduce_every=5
        assert_eq!(stats.eager_msgs, 8 * 5 * 2);
    }
}
