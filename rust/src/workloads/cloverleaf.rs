//! CloverLeaf — 2-D structured compressible hydrodynamics (PGAS/CAF
//! version, Mallinson et al. PGAS'14). One of the paper's four training
//! codes.
//!
//! Pattern: 2-D domain decomposition, per-step 4-neighbour halo exchange
//! with *pairwise* synchronization (`sync images`), plus a global `dt`
//! reduction every step. Well load-balanced; medium halos (tens of KiB)
//! that straddle eager/rendezvous as image counts change.

use super::spec::Workload;
use crate::coarray::CafProgram;
use crate::util::rng::Rng;

/// CloverLeaf communication skeleton.
#[derive(Debug, Clone)]
pub struct CloverLeaf {
    /// Global cells per side (square grid).
    pub n: usize,
    /// Hydro timesteps.
    pub steps: usize,
    /// Compute per cell per step, µs.
    pub cell_us: f64,
    /// Fields exchanged per halo round.
    pub nfields: usize,
}

impl Default for CloverLeaf {
    fn default() -> CloverLeaf {
        CloverLeaf { n: 4096, steps: 25, cell_us: 0.004, nfields: 4 }
    }
}

/// Near-square process grid (px × py = images, px ≤ py).
pub fn process_grid(images: usize) -> (usize, usize) {
    let mut px = (images as f64).sqrt() as usize;
    while px > 1 && images % px != 0 {
        px -= 1;
    }
    (px.max(1), images / px.max(1))
}

impl CloverLeaf {
    fn halo_bytes(&self, images: usize) -> u64 {
        let (px, py) = process_grid(images);
        let tile = self.n / px.max(py).max(1);
        (tile.max(16) * self.nfields * 8) as u64
    }

    fn compute_us(&self, images: usize) -> f64 {
        (self.n * self.n) as f64 / images as f64 * self.cell_us
    }
}

impl Workload for CloverLeaf {
    fn name(&self) -> &'static str {
        "cloverleaf"
    }

    fn min_images(&self) -> usize {
        4
    }

    fn build(&self, images: usize, _rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 4, "CloverLeaf needs a 2-D grid (≥4 images)");
        let (px, py) = process_grid(images);
        let halo = self.halo_bytes(images);
        let compute = self.compute_us(images);
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                let r = img - 1;
                let (x, y) = (r % px, r / px);
                // 4-neighbour torus
                let west = (y * px + (x + px - 1) % px) + 1;
                let east = (y * px + (x + 1) % px) + 1;
                let north = (((y + py - 1) % py) * px + x) + 1;
                let south = (((y + 1) % py) * px + x) + 1;
                let neighbors: Vec<usize> =
                    [west, east, north, south].into_iter().filter(|&n| n != img).collect();
                for _ in 0..self.steps {
                    p.compute(compute);
                    for &n in &neighbors {
                        p.put(n, halo);
                    }
                    for &n in &neighbors {
                        p.sync_images(n);
                    }
                    p.co_sum(8); // dt reduction
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn grid_factorization() {
        assert_eq!(process_grid(16), (4, 4));
        assert_eq!(process_grid(8), (2, 4));
        assert_eq!(process_grid(7), (1, 7));
    }

    #[test]
    fn runs_without_deadlock() {
        let clover = CloverLeaf { steps: 2, ..CloverLeaf::default() };
        let mut rng = Rng::new(3);
        let progs = clover.build(16, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 16);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        assert_eq!(stats.collectives, 2); // one dt reduction per step
        assert!(stats.total_time_us > 0.0);
    }

    #[test]
    fn halos_shrink_with_scale() {
        let clover = CloverLeaf::default();
        assert!(clover.halo_bytes(256) <= clover.halo_bytes(64));
    }
}
