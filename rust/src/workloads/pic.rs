//! Skeleton Particle-in-Cell (Decyk, Comp. Phys. Comm. 1995) — one of
//! the paper's training codes.
//!
//! Pattern: 1-D field decomposition; per step a push/deposit compute
//! phase whose cost follows the (moving, unbalanced) particle
//! population, then particle migration to the two neighbours as
//! *variable-size* puts, then a guard-cell field exchange of small puts
//! and a pairwise sync. The strong imbalance plus many smaller messages
//! builds unexpected-queue pressure — the landscape region where eager
//! thresholds, piggybacking and poll/yield interact.

use super::spec::Workload;
use crate::coarray::CafProgram;
use crate::util::rng::Rng;

/// Skeleton PIC communication skeleton.
#[derive(Debug, Clone)]
pub struct SkeletonPic {
    /// Particles per image (average).
    pub particles_per_image: usize,
    /// Timesteps.
    pub steps: usize,
    /// Compute per particle per step, µs.
    pub particle_us: f64,
    /// Fraction of particles migrating per step (average).
    pub migration_rate: f64,
    /// Bytes per particle (position, velocity, charge).
    pub particle_bytes: u64,
    /// Guard-cell field exchange size.
    pub guard_bytes: u64,
    /// Per-image population imbalance amplitude (fraction).
    pub imbalance: f64,
}

impl Default for SkeletonPic {
    fn default() -> SkeletonPic {
        SkeletonPic {
            particles_per_image: 200_000,
            steps: 30,
            particle_us: 0.002,
            migration_rate: 0.01,
            particle_bytes: 48,
            guard_bytes: 4096,
            imbalance: 0.5,
        }
    }
}

impl Workload for SkeletonPic {
    fn name(&self) -> &'static str {
        "skeleton_pic"
    }

    fn build(&self, images: usize, rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2);
        // Static density profile: a beam bunched in the middle images.
        let pops: Vec<f64> = (0..images)
            .map(|i| {
                let x = (i as f64 + 0.5) / images as f64;
                let beam = 1.0 + self.imbalance * (-(x - 0.5) * (x - 0.5) * 24.0).exp();
                beam * (1.0 + 0.1 * rng.f64())
            })
            .collect();
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                let up = if img == 1 { images } else { img - 1 };
                let down = if img == images { 1 } else { img + 1 };
                let pop = self.particles_per_image as f64 * pops[img - 1];
                let compute = pop * self.particle_us;
                let migrants =
                    ((pop * self.migration_rate / 2.0) as u64).max(1) * self.particle_bytes;
                for _ in 0..self.steps {
                    p.compute(compute); // push + deposit
                    p.put(up, migrants);
                    p.put(down, migrants);
                    p.put(up, self.guard_bytes); // guard cells
                    p.put(down, self.guard_bytes);
                    p.sync_images(up);
                    p.sync_images(down);
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn beam_profile_is_unbalanced() {
        let pic = SkeletonPic::default();
        let mut rng = Rng::new(5);
        let progs = pic.build(16, &mut rng);
        let compute = |p: &CafProgram| match p.ops[0] {
            crate::coarray::CafOp::Compute { us } => us,
            _ => panic!(),
        };
        let mid = compute(&progs[7]);
        let edge = compute(&progs[0]);
        assert!(mid > edge * 1.2, "beam centre must be heavier: {mid} vs {edge}");
    }

    #[test]
    fn runs_with_umq_pressure() {
        let pic = SkeletonPic { steps: 4, ..SkeletonPic::default() };
        let mut rng = Rng::new(6);
        let progs = pic.build(8, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 8);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        // Unbalanced senders -> some eager arrivals find targets busy.
        assert!(stats.umq_summary().max >= 1.0);
        assert!(stats.events_processed > 0);
    }
}
