//! The workload abstraction: anything that can emit a CAF team program.

use crate::coarray::CafProgram;
use crate::util::rng::Rng;

/// A parallel application skeleton.
pub trait Workload {
    /// Human-readable name (used in logs/reports).
    fn name(&self) -> &'static str;

    /// Build the per-image programs for a team of `images`.
    ///
    /// `rng` drives static load-imbalance assignment (NOT run-to-run
    /// noise — that is the simulator's job), so a given seed yields a
    /// reproducible problem instance.
    fn build(&self, images: usize, rng: &mut Rng) -> Vec<CafProgram>;

    /// Smallest team size this workload supports.
    fn min_images(&self) -> usize {
        2
    }
}

/// Enumeration of the built-in workloads (CLI/bench selection).
///
/// `Ord` follows declaration order and is load-bearing for the
/// deterministic iteration of workload-stratified replay buffers
/// ([`crate::coordinator::StratifiedRing`]); [`WorkloadKind::ordinal`]
/// is the matching dense index into [`WorkloadKind::ALL`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WorkloadKind {
    Icar,
    CloverLeaf,
    LatticeBoltzmann,
    SkeletonPic,
    PrkStencil,
    PrkTranspose,
    PrkP2p,
    PrkCollectives,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 8] = [
        WorkloadKind::Icar,
        WorkloadKind::CloverLeaf,
        WorkloadKind::LatticeBoltzmann,
        WorkloadKind::SkeletonPic,
        WorkloadKind::PrkStencil,
        WorkloadKind::PrkTranspose,
        WorkloadKind::PrkP2p,
        WorkloadKind::PrkCollectives,
    ];

    /// The paper's four *training* codes (ICAR is held out for
    /// evaluation, §6).
    pub const TRAINING: [WorkloadKind; 4] = [
        WorkloadKind::CloverLeaf,
        WorkloadKind::LatticeBoltzmann,
        WorkloadKind::SkeletonPic,
        WorkloadKind::PrkTranspose,
    ];

    /// Number of built-in workloads (`ALL.len()` as a usable const).
    pub const COUNT: usize = WorkloadKind::ALL.len();

    /// Dense index of this kind in [`WorkloadKind::ALL`] — the slot key
    /// for per-workload occupancy arrays and replay digests.
    pub fn ordinal(self) -> usize {
        match self {
            WorkloadKind::Icar => 0,
            WorkloadKind::CloverLeaf => 1,
            WorkloadKind::LatticeBoltzmann => 2,
            WorkloadKind::SkeletonPic => 3,
            WorkloadKind::PrkStencil => 4,
            WorkloadKind::PrkTranspose => 5,
            WorkloadKind::PrkP2p => 6,
            WorkloadKind::PrkCollectives => 7,
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s.to_ascii_lowercase().as_str() {
            "icar" => Some(WorkloadKind::Icar),
            "cloverleaf" | "clover" => Some(WorkloadKind::CloverLeaf),
            "lbm" | "lattice_boltzmann" | "lattice-boltzmann" => {
                Some(WorkloadKind::LatticeBoltzmann)
            }
            "pic" | "skeleton_pic" => Some(WorkloadKind::SkeletonPic),
            "prk_stencil" | "stencil" => Some(WorkloadKind::PrkStencil),
            "prk_transpose" | "transpose" => Some(WorkloadKind::PrkTranspose),
            "prk_p2p" | "p2p" => Some(WorkloadKind::PrkP2p),
            "prk_collectives" | "collectives" => Some(WorkloadKind::PrkCollectives),
            _ => None,
        }
    }

    pub fn instantiate(&self) -> Box<dyn Workload> {
        match self {
            WorkloadKind::Icar => Box::new(super::Icar::default()),
            WorkloadKind::CloverLeaf => Box::new(super::CloverLeaf::default()),
            WorkloadKind::LatticeBoltzmann => Box::new(super::LatticeBoltzmann::default()),
            WorkloadKind::SkeletonPic => Box::new(super::SkeletonPic::default()),
            WorkloadKind::PrkStencil => Box::new(super::prk::Stencil::default()),
            WorkloadKind::PrkTranspose => Box::new(super::prk::Transpose::default()),
            WorkloadKind::PrkP2p => Box::new(super::prk::SynchP2p::default()),
            WorkloadKind::PrkCollectives => Box::new(super::prk::Collectives::default()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Icar => "icar",
            WorkloadKind::CloverLeaf => "cloverleaf",
            WorkloadKind::LatticeBoltzmann => "lattice_boltzmann",
            WorkloadKind::SkeletonPic => "skeleton_pic",
            WorkloadKind::PrkStencil => "prk_stencil",
            WorkloadKind::PrkTranspose => "prk_transpose",
            WorkloadKind::PrkP2p => "prk_p2p",
            WorkloadKind::PrkCollectives => "prk_collectives",
        }
    }
}

/// Convenience bundle: a workload with fixed team size, ready to build.
pub struct WorkloadSpec {
    pub kind: WorkloadKind,
    pub images: usize,
}

impl WorkloadSpec {
    pub fn build(&self, seed: u64) -> Vec<CafProgram> {
        let mut rng = Rng::new(seed);
        self.kind.instantiate().build(self.images, &mut rng)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for kind in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::parse("nope"), None);
    }

    #[test]
    fn ordinal_indexes_all_and_ord_matches_declaration() {
        assert_eq!(WorkloadKind::COUNT, WorkloadKind::ALL.len());
        for (i, kind) in WorkloadKind::ALL.iter().enumerate() {
            assert_eq!(kind.ordinal(), i);
        }
        // Ord (used by stratified replay's BTreeMap walk) agrees with
        // the ordinal ordering.
        assert!(WorkloadKind::ALL.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn training_set_excludes_icar() {
        assert!(!WorkloadKind::TRAINING.contains(&WorkloadKind::Icar));
        assert_eq!(WorkloadKind::TRAINING.len(), 4);
    }

    #[test]
    fn every_workload_builds_a_full_team() {
        for kind in WorkloadKind::ALL {
            let spec = WorkloadSpec { kind, images: 8 };
            let progs = spec.build(42);
            assert_eq!(progs.len(), 8, "{}", kind.name());
            assert!(progs.iter().all(|p| !p.ops.is_empty()), "{}", kind.name());
        }
    }

    #[test]
    fn builds_are_deterministic_per_seed() {
        let spec = WorkloadSpec { kind: WorkloadKind::Icar, images: 8 };
        let a = spec.build(7);
        let b = spec.build(7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ops, y.ops);
        }
    }
}
