//! Parallel Research Kernels (Van der Wijngaart & Mattson, HPEC'14) —
//! the paper's fourth training code family. Three kernels with sharply
//! different communication characters:
//!
//! * [`Stencil`] — 2-D star stencil: small 4-neighbour halos, balanced;
//! * [`Transpose`] — staged all-to-all of tiles: message-count stress,
//!   where piggybacking and eager thresholds dominate;
//! * [`SynchP2p`] — pipelined wavefront: pure latency/progress stress,
//!   the kernel most sensitive to poll/yield and async progress;
//! * [`Collectives`] — broadcast/reduction-dominated bulk-synchronous
//!   iteration: the workload that exercises collective-algorithm
//!   selection (the `collectives` tunable backend).

mod collectives;
mod p2p;
mod stencil;
mod transpose;

pub use collectives::Collectives;
pub use p2p::SynchP2p;
pub use stencil::Stencil;
pub use transpose::Transpose;
