//! PRK Transpose: distributed matrix transpose — staged all-to-all.
//!
//! Every image owns a block of columns and must scatter tiles to every
//! other image. We model PRK's staged/colwise variant with a bounded
//! partner set per iteration (`max_partners`), which keeps simulated
//! event counts tractable at 2048 images while preserving the
//! message-count-dominated character.

use crate::coarray::CafProgram;
use crate::util::rng::Rng;
use crate::workloads::spec::Workload;

/// PRK transpose kernel skeleton.
#[derive(Debug, Clone)]
pub struct Transpose {
    /// Matrix order (N×N doubles).
    pub n: usize,
    /// Iterations.
    pub steps: usize,
    /// Compute per local element per iteration, µs.
    pub elem_us: f64,
    /// Partner cap per iteration (staged all-to-all; PRK iterates
    /// phases round-robin).
    pub max_partners: usize,
}

impl Default for Transpose {
    fn default() -> Transpose {
        Transpose { n: 4096, steps: 8, elem_us: 0.0004, max_partners: 64 }
    }
}

impl Workload for Transpose {
    fn name(&self) -> &'static str {
        "prk_transpose"
    }

    fn build(&self, images: usize, _rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2);
        let partners = self.max_partners.min(images - 1);
        // Tile: my columns × partner's rows × 8 bytes.
        let tile_bytes = (((self.n / images).max(1) * (self.n / images).max(1)) * 8).max(64) as u64;
        let compute = (self.n as f64 * self.n as f64 / images as f64) * self.elem_us;
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                for step in 0..self.steps {
                    p.compute(compute);
                    // Phase-shifted partner schedule avoids hot spots
                    // (classic staged all-to-all).
                    for k in 1..=partners {
                        let partner = ((img - 1) + k * (step + 1)) % images + 1;
                        if partner != img {
                            p.put(partner, tile_bytes);
                        }
                    }
                    p.sync_all();
                }
                p.co_sum(8); // transpose checksum
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn partner_cap_bounds_messages() {
        let t = Transpose { steps: 1, max_partners: 4, ..Transpose::default() };
        let mut rng = Rng::new(9);
        let progs = t.build(16, &mut rng);
        for p in &progs {
            let puts =
                p.ops.iter().filter(|op| matches!(op, crate::coarray::CafOp::Put { .. })).count();
            assert!(puts <= 4);
        }
    }

    #[test]
    fn small_tiles_are_eager() {
        let t = Transpose::default();
        let tile = (((t.n / 256).max(1) * (t.n / 256).max(1)) * 8).max(64) as i64;
        assert!(tile <= 131_072, "transpose tiles should be eager-sized: {tile}");
    }

    #[test]
    fn runs_clean() {
        let t = Transpose { steps: 2, max_partners: 8, ..Transpose::default() };
        let mut rng = Rng::new(10);
        let progs = t.build(8, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 8);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        // At 8 images the 4096² matrix gives 2 MiB tiles: all rendezvous.
        assert!(stats.rendezvous_msgs > 0);
        assert_eq!(stats.collectives, 1);
    }
}
