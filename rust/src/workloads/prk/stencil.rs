//! PRK Stencil: 2-D star-shaped stencil with 4-neighbour halo exchange.

use crate::coarray::CafProgram;
use crate::util::rng::Rng;
use crate::workloads::cloverleaf::process_grid;
use crate::workloads::spec::Workload;

/// PRK stencil kernel skeleton.
#[derive(Debug, Clone)]
pub struct Stencil {
    /// Grid points per side.
    pub n: usize,
    /// Iterations.
    pub steps: usize,
    /// Compute per point per iteration, µs.
    pub point_us: f64,
    /// Stencil radius (halo width).
    pub radius: usize,
}

impl Default for Stencil {
    fn default() -> Stencil {
        Stencil { n: 8192, steps: 30, point_us: 0.0012, radius: 2 }
    }
}

impl Workload for Stencil {
    fn name(&self) -> &'static str {
        "prk_stencil"
    }

    fn min_images(&self) -> usize {
        4
    }

    fn build(&self, images: usize, _rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 4);
        let (px, py) = process_grid(images);
        let tile = self.n / px.max(py).max(1);
        let halo = (tile.max(16) * self.radius * 8) as u64;
        let compute = (self.n * self.n) as f64 / images as f64 * self.point_us;
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                let r = img - 1;
                let (x, y) = (r % px, r / px);
                let mut neighbors = Vec::new();
                if x > 0 {
                    neighbors.push(y * px + x - 1 + 1);
                }
                if x + 1 < px {
                    neighbors.push(y * px + x + 1 + 1);
                }
                if y > 0 {
                    neighbors.push((y - 1) * px + x + 1);
                }
                if y + 1 < py {
                    neighbors.push((y + 1) * px + x + 1);
                }
                for _ in 0..self.steps {
                    p.compute(compute);
                    for &n in &neighbors {
                        p.put(n, halo);
                    }
                    p.sync_all();
                }
                p.co_sum(8); // final norm check
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    #[test]
    fn interior_images_have_four_neighbors() {
        let st = Stencil { steps: 1, ..Stencil::default() };
        let mut rng = Rng::new(7);
        let progs = st.build(16, &mut rng); // 4x4 grid
        // Image at grid (1,1) = rank 5 = image 6: interior.
        let puts = progs[5]
            .ops
            .iter()
            .filter(|op| matches!(op, crate::coarray::CafOp::Put { .. }))
            .count();
        assert_eq!(puts, 4);
        // Corner image 1: two neighbours.
        let corner_puts = progs[0]
            .ops
            .iter()
            .filter(|op| matches!(op, crate::coarray::CafOp::Put { .. }))
            .count();
        assert_eq!(corner_puts, 2);
    }

    #[test]
    fn runs_clean() {
        let st = Stencil { steps: 2, ..Stencil::default() };
        let mut rng = Rng::new(8);
        let progs = st.build(16, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::edison(), CvarSet::vanilla(), 16);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        assert!(stats.total_time_us > 0.0);
        assert_eq!(stats.collectives, 1);
    }
}
