//! PRK Synch_p2p: pipelined 2-D wavefront ("hyperplane") sweep.
//!
//! Image `i` waits for a boundary value from image `i-1`, computes its
//! chunk of the current row-block, and signals image `i+1`. Tiny
//! messages, long dependency chains: runtime is dominated by per-hop
//! latency and *progress responsiveness* — the workload that punishes
//! bad `POLLS_BEFORE_YIELD` settings and rewards async progress hardest.

use crate::coarray::CafProgram;
use crate::util::rng::Rng;
use crate::workloads::spec::Workload;

/// PRK synch_p2p kernel skeleton.
#[derive(Debug, Clone)]
pub struct SynchP2p {
    /// Grid width per image (columns each image owns).
    pub width: usize,
    /// Row blocks per sweep (pipeline depth).
    pub row_blocks: usize,
    /// Full sweeps.
    pub sweeps: usize,
    /// Compute per point, µs.
    pub point_us: f64,
    /// Boundary payload per hop (one row-block edge).
    pub edge_bytes: u64,
}

impl Default for SynchP2p {
    fn default() -> SynchP2p {
        SynchP2p { width: 2048, row_blocks: 8, sweeps: 4, point_us: 0.0008, edge_bytes: 512 }
    }
}

impl Workload for SynchP2p {
    fn name(&self) -> &'static str {
        "prk_p2p"
    }

    fn build(&self, images: usize, _rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2);
        let block_compute = (self.width * self.row_blocks) as f64 * self.point_us;
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                for _ in 0..self.sweeps {
                    for _ in 0..self.row_blocks {
                        if img > 1 {
                            p.event_wait(1); // upstream boundary ready
                        }
                        p.compute(block_compute / self.row_blocks as f64);
                        if img < images {
                            p.put(img + 1, self.edge_bytes);
                            p.event_post(img + 1);
                        }
                    }
                }
                // Corner value feeds back to image 1 to seed the next
                // sweep in the real kernel; final sync keeps teams tidy.
                p.sync_all();
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coarray::{lower_all, RuntimeOptions};
    use crate::mpi_t::{CvarId, CvarSet};
    use crate::simmpi::{Engine, Machine, SimConfig};

    fn run(images: usize, async_progress: bool) -> f64 {
        let k = SynchP2p { sweeps: 2, ..SynchP2p::default() };
        let mut rng = Rng::new(11);
        let progs = k.build(images, &mut rng);
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cv = CvarSet::vanilla();
        cv.set(CvarId(0), i64::from(async_progress));
        let mut cfg = SimConfig::new(Machine::cheyenne(), cv, images);
        cfg.noise = 0.0;
        Engine::new(cfg, lowered).run().total_time_us
    }

    #[test]
    fn pipeline_completes() {
        assert!(run(8, false) > 0.0);
    }

    #[test]
    fn async_progress_speeds_up_the_pipeline() {
        let without = run(16, false);
        let with = run(16, true);
        assert!(
            with < without,
            "async progress should cut pipeline stalls: {with} vs {without}"
        );
    }
}
