//! PRK-style collective-heavy kernel: a bulk-synchronous iteration
//! dominated by large broadcasts and reductions.
//!
//! Shape of many spectral/ensemble codes (and of the MPI
//! collective-benchmark suites in Hunold & Carpen-Amarie's
//! performance-guidelines work): each timestep the root fans a large
//! parameter block out to every rank (`co_broadcast`), ranks compute,
//! then global sums reduce the step's observables (`co_sum`) before a
//! barrier closes the step. Point-to-point traffic is negligible by
//! construction — this is the workload that exercises
//! collective-algorithm selection, the second tunable backend's knob
//! space. The skeleton builds real CAF programs, so the coarrays
//! backend can also run it through the discrete-event engine.

use crate::coarray::CafProgram;
use crate::util::rng::Rng;
use crate::workloads::spec::Workload;

/// Collective-heavy kernel skeleton.
#[derive(Debug, Clone)]
pub struct Collectives {
    /// Timesteps.
    pub steps: usize,
    /// Broadcast payload per step (bytes).
    pub bcast_bytes: u64,
    /// Reduction payload per step (bytes).
    pub allreduce_bytes: u64,
    /// Reductions per step.
    pub allreduces_per_step: usize,
    /// Compute per rank per step, µs.
    pub compute_us: f64,
}

impl Default for Collectives {
    fn default() -> Collectives {
        Collectives {
            steps: 10,
            bcast_bytes: 1 << 20,
            allreduce_bytes: 256 * 1024,
            allreduces_per_step: 2,
            compute_us: 150.0,
        }
    }
}

impl Workload for Collectives {
    fn name(&self) -> &'static str {
        "prk_collectives"
    }

    fn build(&self, images: usize, rng: &mut Rng) -> Vec<CafProgram> {
        assert!(images >= 2);
        // Static per-rank compute imbalance: the problem instance, not
        // run-to-run noise (that's the simulator's job).
        let imbalance: Vec<f64> =
            (0..images).map(|_| 1.0 + 0.1 * (rng.f64() - 0.5)).collect();
        (1..=images)
            .map(|img| {
                let mut p = CafProgram::new(img, images);
                for _ in 0..self.steps {
                    p.co_broadcast(self.bcast_bytes);
                    p.compute(self.compute_us * imbalance[img - 1]);
                    for _ in 0..self.allreduces_per_step {
                        p.co_sum(self.allreduce_bytes);
                    }
                    p.sync_all();
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn builds_collective_dominated_programs() {
        let mut rng = Rng::new(3);
        let progs = Collectives::default().build(8, &mut rng);
        assert_eq!(progs.len(), 8);
        for p in &progs {
            let collectives = p
                .ops
                .iter()
                .filter(|op| {
                    matches!(
                        op,
                        crate::coarray::CafOp::CoSum { .. }
                            | crate::coarray::CafOp::CoBroadcast { .. }
                    )
                })
                .count();
            assert_eq!(collectives, 10 * 3, "bcast + 2 co_sum per step");
        }
    }
}
