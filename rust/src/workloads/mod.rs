//! Communication skeletons of the paper's CAF codes (§6).
//!
//! The paper trains AITuning on four coarray-Fortran codes — CloverLeaf,
//! a Lattice-Boltzmann solver, UCLA's Skeleton PIC, and the Parallel
//! Research Kernels — and evaluates on ICAR, NCAR's intermediate-
//! complexity atmospheric model. We model each as its *communication
//! skeleton*: the per-timestep pattern of puts/gets/syncs/collectives
//! with realistic message sizes, synchronization structure, compute/
//! communication ratio, and load imbalance, authored against the CAF
//! surface in [`crate::coarray`].
//!
//! Each skeleton's knob sensitivities (which cvars matter) emerge from
//! its pattern, not from hard-coding — e.g. ICAR's medium-size halo puts
//! land just above the default eager threshold, so raising
//! `CH3_EAGER_MAX_MSG_SIZE` or enabling `ASYNC_PROGRESS` both help, as
//! the paper found (§6.2).

mod cloverleaf;
mod icar;
mod lattice_boltzmann;
mod pic;
pub mod prk;
mod spec;

pub use cloverleaf::CloverLeaf;
pub use icar::Icar;
pub use lattice_boltzmann::LatticeBoltzmann;
pub use pic::SkeletonPic;
pub use spec::{Workload, WorkloadKind, WorkloadSpec};
