//! # AITuning — deep-RL tuning of run-time communication libraries
//!
//! Reproduction of *AITuning: Machine Learning-based Tuning Tool for
//! Run-Time Communication Libraries* (Fanfarillo & Del Vento, NCAR, 2019)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the AITuning coordinator (controller, episode
//!   loop, replay buffer, ensemble inference), plus every substrate the
//!   paper depends on, built from scratch: a discrete-event MPI-3
//!   simulator ([`simmpi`]), an OpenCoarrays-like coarray runtime
//!   ([`coarray`]), the MPI Tool Information Interface ([`mpi_t`]), the
//!   paper's CAF workloads ([`workloads`]), tuning baselines
//!   ([`baselines`]), and a multi-threaded campaign engine ([`campaign`])
//!   that fans tuning sessions across cores with deterministic,
//!   thread-count-invariant results — either as independent learners or
//!   coupled through the [`coordinator::LearnerHub`] parameter server
//!   (shared weights + pooled replay, merged in job order).
//! * **L2/L1 (python/, build-time only)** — the deep Q-network (JAX) and
//!   its fused-dense Pallas kernel, AOT-lowered to HLO text under
//!   `artifacts/` and executed from [`runtime`] via the PJRT C API.
//!
//! Python never runs on the tuning path: after `make artifacts`, the
//! `aituning` binary is self-contained.

pub mod backend;
pub mod baselines;
pub mod campaign;
pub mod coarray;
pub mod convergence;
pub mod coordinator;
pub mod metrics;
pub mod mpi_t;
pub mod runtime;
pub mod simmpi;
pub mod util;
pub mod workloads;
