//! # AITuning — deep-RL tuning of run-time communication libraries
//!
//! Reproduction of *AITuning: Machine Learning-based Tuning Tool for
//! Run-Time Communication Libraries* (Fanfarillo & Del Vento, NCAR, 2019)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the AITuning coordinator (controller, episode
//!   loop, replay buffer, ensemble inference), plus every substrate the
//!   paper depends on, built from scratch: a discrete-event MPI-3
//!   simulator ([`simmpi`]), an OpenCoarrays-like coarray runtime
//!   ([`coarray`]), the MPI Tool Information Interface ([`mpi_t`]), the
//!   paper's CAF workloads ([`workloads`]), tuning baselines
//!   ([`baselines`]), and a multi-threaded campaign engine ([`campaign`])
//!   that fans tuning sessions across cores with deterministic,
//!   thread-count-invariant results — either as independent learners or
//!   coupled through the [`coordinator::LearnerHub`] parameter server
//!   (shared weights + pooled replay, merged in job order).
//! * **L2/L1** — the deep Q-network. By default it runs on the **native
//!   engine** ([`runtime::native`]): a pure-Rust MLP (backprop, Huber
//!   loss, Adam) sized from any backend's state/action layout, so the
//!   `aituning` binary is self-contained on a bare checkout. The
//!   original path survives behind [`runtime::QBackend::Aot`]: the JAX
//!   Q-network and its fused-dense Pallas kernel (python/, build-time
//!   only), AOT-lowered to HLO text under `artifacts/` and executed via
//!   the PJRT C API.
//!
//! Python never runs on the tuning path — and with the native engine it
//! never runs at all.

pub mod backend;
pub mod baselines;
pub mod campaign;
pub mod coarray;
pub mod convergence;
pub mod coordinator;
pub mod metrics;
pub mod mpi_t;
pub mod runtime;
pub mod simmpi;
pub mod util;
pub mod workloads;
