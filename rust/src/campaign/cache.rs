//! Episode-result cache: skip re-simulating configurations already
//! measured under identical conditions.
//!
//! Ensemble scoring, baseline searches and sweeps repeatedly evaluate
//! the *same* `(workload, images, CvarSet, seeds)` tuple — e.g. the
//! vanilla reference is re-scored by every baseline, and evolutionary
//! search re-visits configurations. Since the simulator is a pure
//! function of that tuple, those episodes can be answered from a map
//! instead of re-run. Keys include every input that affects the
//! simulated total time, so a hit is exact by construction.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::mpi_t::CvarSet;
use crate::simmpi::Machine;
use crate::util::json::{num, obj, s, Json};
use crate::workloads::WorkloadKind;

use super::store::format::{self, FrameReader};

/// Everything that determines one simulated episode's total time.
/// Ordered (derive order = field order) so the persisted cache file is
/// written in one canonical key order regardless of insertion order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EpisodeKey {
    pub workload: WorkloadKind,
    pub images: usize,
    pub cvars: CvarSet,
    /// Machine model identity (presets are fully determined by name).
    pub machine: &'static str,
    /// Simulator noise level, bit-exact.
    pub noise_bits: u64,
    /// Fixes the problem instance (§: same application across runs).
    pub workload_seed: u64,
    /// Fixes the run-to-run noise draw.
    pub run_seed: u64,
}

impl EpisodeKey {
    pub fn new(
        workload: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        machine: &Machine,
        noise: f64,
        workload_seed: u64,
        run_seed: u64,
    ) -> EpisodeKey {
        EpisodeKey {
            workload,
            images,
            cvars: cvars.clone(),
            machine: machine.name,
            noise_bits: noise.to_bits(),
            workload_seed,
            run_seed,
        }
    }
}

/// Thread-safe memo table of episode total times, with hit/miss
/// counters for reporting.
///
/// The lock is *not* held while an episode simulates, so two workers
/// racing on the same cold key may both run it; they compute the same
/// value (the simulator is deterministic in the key), so results stay
/// bit-identical regardless of interleaving.
#[derive(Debug, Default)]
pub struct EpisodeCache {
    /// `BTreeMap`, not a hash map: [`EpisodeCache::save_to`] iterates
    /// the entries into a persisted file, and key order is the only
    /// iteration order that makes two caches with the same entries
    /// serialize to the same bytes regardless of insertion history.
    map: Mutex<BTreeMap<EpisodeKey, f64>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

/// Recover the guarded map even if another worker panicked mid-insert:
/// entries are idempotent (pure function of the key), so a poisoned
/// lock holds valid data and propagating the poison would only turn
/// one worker's panic into a campaign-wide abort.
fn lock_map(
    map: &Mutex<BTreeMap<EpisodeKey, f64>>,
) -> std::sync::MutexGuard<'_, BTreeMap<EpisodeKey, f64>> {
    map.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn encode_key(k: &EpisodeKey) -> Json {
    obj(vec![
        ("workload", s(k.workload.name())),
        ("images", num(k.images as f64)),
        ("cvars", format::encode_cvars(&k.cvars)),
        ("machine", s(k.machine)),
        ("noise_bits", format::hex_u64(k.noise_bits)),
        ("workload_seed", format::hex_u64(k.workload_seed)),
        ("run_seed", format::hex_u64(k.run_seed)),
    ])
}

fn decode_key(j: &Json) -> Result<EpisodeKey> {
    let workload_name =
        j.at(&["workload"])?.as_str().context("episode key workload must be a string")?;
    let machine_name =
        j.at(&["machine"])?.as_str().context("episode key machine must be a string")?;
    Ok(EpisodeKey {
        workload: WorkloadKind::parse(workload_name)
            .with_context(|| format!("unknown workload {workload_name:?} in episode cache"))?,
        images: format::usize_of(j.at(&["images"])?)?,
        cvars: format::decode_cvars(j.at(&["cvars"])?)?,
        machine: Machine::by_name(machine_name)
            .with_context(|| format!("unknown machine {machine_name:?} in episode cache"))?
            .name,
        noise_bits: format::u64_of(j.at(&["noise_bits"])?)?,
        workload_seed: format::u64_of(j.at(&["workload_seed"])?)?,
        run_seed: format::u64_of(j.at(&["run_seed"])?)?,
    })
}

impl EpisodeCache {
    pub fn new() -> EpisodeCache {
        EpisodeCache::default()
    }

    /// Look up `key`, or compute it with `run` and remember the result.
    pub fn get_or_run(
        &self,
        key: EpisodeKey,
        run: impl FnOnce() -> Result<f64>,
    ) -> Result<f64> {
        if let Some(&t) = lock_map(&self.map).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(t);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t = run()?;
        lock_map(&self.map).insert(key, t);
        Ok(t)
    }

    /// Number of distinct episodes stored.
    pub fn len(&self) -> usize {
        lock_map(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        lock_map(&self.map).is_empty()
    }

    /// Lookups answered from the map.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to simulate.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Persist every entry to `path` in the campaign store's frame
    /// format ([`format::write_frame`]), key-ascending, f64 values as
    /// exact bit patterns. Byte-stable: two caches holding the same
    /// entries write identical files regardless of insertion order.
    pub fn save_to(&self, path: &Path) -> Result<()> {
        let mut out = BufWriter::new(
            File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        for (key, &us) in lock_map(&self.map).iter() {
            let record = obj(vec![("key", encode_key(key)), ("us", format::hex_f64(us))]);
            format::write_frame(&mut out, &record)?;
        }
        out.flush().with_context(|| format!("flushing {}", path.display()))?;
        Ok(())
    }

    /// Merge entries from `path` into the cache (a missing file is an
    /// empty cache — the first run of a fresh store). A torn trailing
    /// frame (crash mid-save) drops only that frame. Returns the
    /// number of entries loaded.
    pub fn load_from(&self, path: &Path) -> Result<usize> {
        if !path.exists() {
            return Ok(0);
        }
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut reader = FrameReader::new(BufReader::new(file));
        let mut entries = Vec::new();
        while let Some(record) = reader.next_frame()? {
            let key = decode_key(record.at(&["key"])?)?;
            let us = format::f64_of(record.at(&["us"])?)?;
            entries.push((key, us));
        }
        let loaded = entries.len();
        let mut map = lock_map(&self.map);
        for (key, us) in entries {
            map.insert(key, us);
        }
        Ok(loaded)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn key(run_seed: u64) -> EpisodeKey {
        EpisodeKey::new(
            WorkloadKind::Icar,
            32,
            &CvarSet::vanilla(),
            &Machine::cheyenne(),
            0.02,
            7,
            run_seed,
        )
    }

    #[test]
    fn second_lookup_is_a_hit_and_skips_the_closure() {
        let cache = EpisodeCache::new();
        let mut calls = 0;
        for _ in 0..3 {
            let t = cache
                .get_or_run(key(1), || {
                    calls += 1;
                    Ok(42.0)
                })
                .unwrap();
            assert_eq!(t, 42.0);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_seeds_are_distinct_entries() {
        let cache = EpisodeCache::new();
        cache.get_or_run(key(1), || Ok(1.0)).unwrap();
        cache.get_or_run(key(2), || Ok(2.0)).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get_or_run(key(2), || Ok(99.0)).unwrap(), 2.0);
    }

    #[test]
    fn failed_runs_are_not_cached() {
        let cache = EpisodeCache::new();
        assert!(cache.get_or_run(key(1), || anyhow::bail!("boom")).is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.get_or_run(key(1), || Ok(5.0)).unwrap(), 5.0);
    }

    fn temp_file(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("aituning-cache-{tag}-{}.jsonl", std::process::id()))
    }

    #[test]
    fn save_load_round_trips_and_is_insertion_order_independent() {
        let path = temp_file("roundtrip");
        let a = EpisodeCache::new();
        a.get_or_run(key(1), || Ok(1.5)).unwrap();
        a.get_or_run(key(2), || Ok(f64::from_bits(0x7ff8_0000_0000_0001))).unwrap();
        a.save_to(&path).unwrap();
        let bytes_a = std::fs::read(&path).unwrap();

        // Same entries inserted in the opposite order → same bytes.
        let b = EpisodeCache::new();
        b.get_or_run(key(2), || Ok(f64::from_bits(0x7ff8_0000_0000_0001))).unwrap();
        b.get_or_run(key(1), || Ok(1.5)).unwrap();
        b.save_to(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), bytes_a);

        let c = EpisodeCache::new();
        assert_eq!(c.load_from(&path).unwrap(), 2);
        assert_eq!(c.len(), 2);
        // Loaded values answer lookups bit-exactly (NaN payload included).
        let mut ran = false;
        let t = c
            .get_or_run(key(2), || {
                ran = true;
                Ok(0.0)
            })
            .unwrap();
        assert!(!ran, "loaded entry must be a cache hit");
        assert_eq!(t.to_bits(), 0x7ff8_0000_0000_0001);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn loading_a_missing_file_is_empty_not_an_error() {
        let cache = EpisodeCache::new();
        assert_eq!(cache.load_from(&temp_file("missing-never-created")).unwrap(), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn torn_trailing_frame_drops_only_that_entry() {
        let path = temp_file("torn");
        let a = EpisodeCache::new();
        a.get_or_run(key(1), || Ok(1.0)).unwrap();
        a.get_or_run(key(2), || Ok(2.0)).unwrap();
        a.save_to(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        let b = EpisodeCache::new();
        assert_eq!(b.load_from(&path).unwrap(), 1);
        std::fs::remove_file(&path).unwrap();
    }
}
