//! Episode-result cache: skip re-simulating configurations already
//! measured under identical conditions.
//!
//! Ensemble scoring, baseline searches and sweeps repeatedly evaluate
//! the *same* `(workload, images, CvarSet, seeds)` tuple — e.g. the
//! vanilla reference is re-scored by every baseline, and evolutionary
//! search re-visits configurations. Since the simulator is a pure
//! function of that tuple, those episodes can be answered from a map
//! instead of re-run. Keys include every input that affects the
//! simulated total time, so a hit is exact by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::mpi_t::CvarSet;
use crate::simmpi::Machine;
use crate::workloads::WorkloadKind;

/// Everything that determines one simulated episode's total time.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct EpisodeKey {
    pub workload: WorkloadKind,
    pub images: usize,
    pub cvars: CvarSet,
    /// Machine model identity (presets are fully determined by name).
    pub machine: &'static str,
    /// Simulator noise level, bit-exact.
    pub noise_bits: u64,
    /// Fixes the problem instance (§: same application across runs).
    pub workload_seed: u64,
    /// Fixes the run-to-run noise draw.
    pub run_seed: u64,
}

impl EpisodeKey {
    pub fn new(
        workload: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        machine: &Machine,
        noise: f64,
        workload_seed: u64,
        run_seed: u64,
    ) -> EpisodeKey {
        EpisodeKey {
            workload,
            images,
            cvars: cvars.clone(),
            machine: machine.name,
            noise_bits: noise.to_bits(),
            workload_seed,
            run_seed,
        }
    }
}

/// Thread-safe memo table of episode total times, with hit/miss
/// counters for reporting.
///
/// The lock is *not* held while an episode simulates, so two workers
/// racing on the same cold key may both run it; they compute the same
/// value (the simulator is deterministic in the key), so results stay
/// bit-identical regardless of interleaving.
#[derive(Debug, Default)]
pub struct EpisodeCache {
    /// Audited lookup-only (detlint R1): this map is only ever probed
    /// by key (`get`/`insert`/`len`/`is_empty`) — nothing iterates it,
    /// so its hash order can never reach a report or fingerprint. If a
    /// future change needs to enumerate entries, switch to `BTreeMap`.
    map: Mutex<HashMap<EpisodeKey, f64>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

/// Recover the guarded map even if another worker panicked mid-insert:
/// entries are idempotent (pure function of the key), so a poisoned
/// lock holds valid data and propagating the poison would only turn
/// one worker's panic into a campaign-wide abort.
fn lock_map(
    map: &Mutex<HashMap<EpisodeKey, f64>>,
) -> std::sync::MutexGuard<'_, HashMap<EpisodeKey, f64>> {
    map.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl EpisodeCache {
    pub fn new() -> EpisodeCache {
        EpisodeCache::default()
    }

    /// Look up `key`, or compute it with `run` and remember the result.
    pub fn get_or_run(
        &self,
        key: EpisodeKey,
        run: impl FnOnce() -> Result<f64>,
    ) -> Result<f64> {
        if let Some(&t) = lock_map(&self.map).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(t);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t = run()?;
        lock_map(&self.map).insert(key, t);
        Ok(t)
    }

    /// Number of distinct episodes stored.
    pub fn len(&self) -> usize {
        lock_map(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        lock_map(&self.map).is_empty()
    }

    /// Lookups answered from the map.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to simulate.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn key(run_seed: u64) -> EpisodeKey {
        EpisodeKey::new(
            WorkloadKind::Icar,
            32,
            &CvarSet::vanilla(),
            &Machine::cheyenne(),
            0.02,
            7,
            run_seed,
        )
    }

    #[test]
    fn second_lookup_is_a_hit_and_skips_the_closure() {
        let cache = EpisodeCache::new();
        let mut calls = 0;
        for _ in 0..3 {
            let t = cache
                .get_or_run(key(1), || {
                    calls += 1;
                    Ok(42.0)
                })
                .unwrap();
            assert_eq!(t, 42.0);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_seeds_are_distinct_entries() {
        let cache = EpisodeCache::new();
        cache.get_or_run(key(1), || Ok(1.0)).unwrap();
        cache.get_or_run(key(2), || Ok(2.0)).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get_or_run(key(2), || Ok(99.0)).unwrap(), 2.0);
    }

    #[test]
    fn failed_runs_are_not_cached() {
        let cache = EpisodeCache::new();
        assert!(cache.get_or_run(key(1), || anyhow::bail!("boom")).is_err());
        assert!(cache.is_empty());
        assert_eq!(cache.get_or_run(key(1), || Ok(5.0)).unwrap(), 5.0);
    }
}
