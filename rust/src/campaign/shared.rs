//! Shared-learning campaigns: one distributed learner instead of N
//! isolated sessions.
//!
//! [`CampaignEngine::run_shared`] drives the same job list as
//! [`CampaignEngine::run`], but the sessions learn *together* through a
//! [`LearnerHub`]. In the default [`crate::coordinator::SyncMode::Sync`]
//! (and the degenerate `Async { staleness: 0 }`, which is the same
//! schedule by definition) execution is round-synchronous:
//!
//! ```text
//! round r:   pull ──► step sync_every runs ──► push     (all jobs, in
//!            parallel across the worker pool)
//! barrier:   hub.merge(contributions in job-index order)
//! ```
//!
//! Within a round every job's segment is a pure function of (its own
//! state at round start, the hub snapshot at round start) — workers
//! share nothing else — and the merge consumes contributions in job
//! order regardless of which thread finished first. By induction the
//! entire campaign, hub state included, is bit-identical at any worker
//! count; parallelism changes wall-clock only. This is the engine
//! contract PR 1 pinned for independent jobs, extended to a coupled
//! learner: the barrier is what buys determinism that asynchronous
//! A3C-style gradient pushes cannot give.
//!
//! The merge cadence comes from the base config's
//! [`SharedLearning::sync_every`] (runs per segment). Smaller cadence =
//! tighter coupling and more merges; `sync_every >= runs` degenerates
//! to a single end-of-session merge.
//!
//! The hub's global buffer runs the base config's
//! [`crate::coordinator::ReplayPolicyKind`]; workers pull its frozen
//! snapshot behind an `Arc` (O(1) per pull) and the determinism
//! argument above is policy-independent, so the 1-vs-N fingerprint
//! checks hold for uniform, stratified and prioritized replay alike.
//!
//! With `--sync-mode async --staleness N` (N ≥ 1) the round barrier is
//! gone: [`CampaignEngine::run_shared`] dispatches to the
//! bounded-staleness driver in [`super::async_shared`], which pushes
//! each segment's contribution the moment it finishes and enforces the
//! staleness window at segment *start* instead of a per-round barrier.
//! See `docs/shared_learning.md` for the trade (wall-clock vs
//! schedule-determinism).

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
// detlint: allow(R3) -- wall-clock is reporting-only (CampaignReport.wall_clock); it never feeds fingerprint()
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{
    AgentKind, AgentState, Controller, HubContribution, HubView, LearnerHub, MergeMode,
    SharedLearning, TuningConfig,
};
use crate::runtime::{
    argmax, q_values_batch_of, DenseKernel, FusedGrads, FusedTrainer, TrainBatch,
};

use super::collector::ShardedCollector;
use super::engine::{finalize_report, CampaignEngine, SpillOptions, SpillRun, StraggleSpec};
use super::job::CampaignJob;
use super::report::{CampaignReport, JobOutcome};
use super::store::{campaign_digest, CampaignStore, Manifest, OutcomeSink, StoreMode};

/// The in-flight state of one shared-learning campaign: hub, slots and
/// the round parameters. [`CampaignEngine::run_shared`] drives it start
/// to finish; the spilled/resumable path drives the *same* rounds with
/// digest checkpoints between them, so the two can never diverge in
/// behavior — they are one loop body.
pub(super) struct SharedCampaign<'a> {
    pub(super) base: &'a TuningConfig,
    pub(super) shared: SharedLearning,
    pub(super) jobs: &'a [CampaignJob],
    pub(super) sync_every: usize,
    pub(super) rounds: usize,
    pub(super) workers: usize,
    pub(super) hub: LearnerHub,
    /// One persistent controller per job; workers move them in and
    /// out of the slots between rounds (dynamic claiming is safe —
    /// within a round, segments touch disjoint slots).
    pub(super) slots: Vec<Mutex<Option<Controller>>>,
    /// Injected per-segment delays (benchmarks only); pure sleeps, so
    /// fingerprints are unaffected in either mode.
    pub(super) straggle: Option<StraggleSpec>,
    /// The fused cross-job trainer (native-DQN campaigns with fusion
    /// enabled). `Some` means rounds with a dense master stack every
    /// job's first minibatch through one packed GEMM per layer; `None`
    /// (tabular/AOT jobs, `--no-fuse-training`, the async driver) keeps
    /// the per-job sequential path. Either way the numbers are
    /// bit-identical — this is a throughput knob, never a semantics
    /// knob — which is exactly what lets the toggle exist untracked by
    /// any fingerprint.
    pub(super) fused: Option<FusedTrainer>,
}

impl SharedCampaign<'_> {
    /// One pull/train/push round: batched greedy hints, the segment
    /// pool (fused across jobs when a dense master exists, per-job
    /// sequential otherwise), then the job-index-order hub merge.
    fn round(&mut self) -> Result<()> {
        let view = self.hub.view();
        // Batched best_action: every live job's first greedy
        // selection of this round shares one blocked GEMM over the
        // master parameters (computed once, on this thread — the
        // result is worker-count invariant by construction). Routed
        // through the fused trainer when one exists, so its packed
        // panels are warm before the training pass over the same
        // master.
        let hints = round_hints(&view, self.jobs, &self.slots, self.fused.as_mut())?;
        // Every job is on the same segment index in sync mode: the
        // number of merges the hub has already consumed.
        let segment = self.hub.merges();
        // Fusion needs every job's first minibatch to be a pure
        // function of one shared dense master — true from the first
        // merge onward in both modes (weights: the merge *is* the
        // master every worker pulls; grads: workers pull the hub's
        // post-Adam master). Round 0 has no master, so it runs the
        // sequential pool.
        let fuse = self.fused.is_some()
            && matches!(view.master.as_deref(), Some(AgentState::Dense { .. }));
        let contributions = if fuse {
            self.fused_round(&view, &hints, segment)?
        } else {
            self.sequential_round(&view, &hints, segment)?
        };
        self.hub.merge(&contributions)
    }

    /// The pre-fusion round body: every job's full segment runs
    /// independently on the pool (also the fallback whenever fusion
    /// cannot apply).
    fn sequential_round(
        &self,
        view: &HubView,
        hints: &[Option<usize>],
        segment: usize,
    ) -> Result<Vec<HubContribution>> {
        let collector = ShardedCollector::new(self.jobs.len(), self.workers);
        let cursor = AtomicUsize::new(0);
        let jobs = self.jobs;
        let base = self.base;
        let shared = self.shared;
        let sync_every = self.sync_every;
        let slots = &self.slots;
        let straggle = self.straggle;
        std::thread::scope(|scope| {
            for w in 0..self.workers {
                let collector = &collector;
                let cursor = &cursor;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = run_segment(
                        base,
                        shared,
                        &jobs[i],
                        i,
                        sync_every,
                        view,
                        &slots[i],
                        hints[i],
                        straggle.as_ref(),
                        segment,
                    );
                    collector.push(w, i, r);
                });
            }
        });
        collector.into_merged()?.into_iter().collect()
    }

    /// The fused round body, two phases around one cross-job training
    /// pass:
    ///
    /// 1. **Presample** (parallel): each job pulls the master, runs its
    ///    segment's first tuning run through the transition push, and
    ///    draws its training minibatch at the exact RNG position the
    ///    sequential path would ([`Controller::step_run_presampled`]).
    /// 2. One [`FusedTrainer::train_grads`] over the stacked batches on
    ///    this thread — every job's forward/`dx` GEMMs share the packed
    ///    master panels.
    /// 3. **Complete** (parallel): each job applies its own gradients
    ///    ([`Controller::complete_fused`]) and runs the rest of its
    ///    segment, which trains sequentially on the worker's local
    ///    post-update parameters exactly as before.
    ///
    /// Per job this is bit-identical to [`run_segment`] — same draws,
    /// same updates, same contribution — so fingerprints cannot see
    /// which body ran; only the wall clock can.
    fn fused_round(
        &mut self,
        view: &HubView,
        hints: &[Option<usize>],
        segment: usize,
    ) -> Result<Vec<HubContribution>> {
        let Some(AgentState::Dense { params, .. }) = view.master.as_deref() else {
            anyhow::bail!("fused round scheduled without a dense master");
        };
        let trainer = self.fused.as_mut().context("fused round without a trainer")?;
        let jobs = self.jobs;
        let base = self.base;
        let shared = self.shared;
        let slots = &self.slots;
        let workers = self.workers;

        let collector = ShardedCollector::new(jobs.len(), workers);
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = presample_segment(base, shared, &jobs[i], view, &slots[i], hints[i]);
                    collector.push(w, i, r);
                });
            }
        });
        let batches =
            collector.into_merged()?.into_iter().collect::<Result<Vec<TrainBatch>>>()?;

        let refs: Vec<&TrainBatch> = batches.iter().collect();
        let fused = trainer.train_grads(params, &refs, base.gamma)?;
        // Job-indexed cells the completion pool drains — each slot is
        // taken exactly once, by whichever worker claims that job.
        let cells: Vec<Mutex<Option<FusedGrads>>> =
            fused.into_iter().map(|g| Mutex::new(Some(g))).collect();

        let collector = ShardedCollector::new(jobs.len(), workers);
        let cursor = AtomicUsize::new(0);
        let sync_every = self.sync_every;
        let straggle = self.straggle;
        let cells = &cells;
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = complete_segment(
                        i,
                        sync_every,
                        &slots[i],
                        &cells[i],
                        straggle.as_ref(),
                        segment,
                    );
                    collector.push(w, i, r);
                });
            }
        });
        collector.into_merged()?.into_iter().collect()
    }

    /// Finish every session in job order and return the outcomes plus
    /// the final hub.
    fn finish(self) -> Result<(Vec<JobOutcome>, LearnerHub)> {
        let SharedCampaign { jobs, slots, hub, .. } = self;
        let mut results = Vec::with_capacity(jobs.len());
        for (job, slot) in jobs.iter().zip(&slots) {
            // A poisoned slot means a worker panicked mid-segment; the
            // panic has already surfaced through the scoped join, so
            // recover the guard rather than double-reporting here.
            let mut ctl = slot
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .take()
                .context("shared campaign lost a controller")?;
            let outcome = ctl.finish_session()?;
            results.push(JobOutcome { job: *job, outcome });
        }
        Ok((results, hub))
    }
}

impl CampaignEngine {
    /// Validate a shared job list and set up its campaign state.
    pub(super) fn shared_campaign<'a>(&'a self, jobs: &'a [CampaignJob]) -> Result<SharedCampaign<'a>> {
        anyhow::ensure!(!jobs.is_empty(), "shared campaign needs at least one job");
        let base = &self.config().base;
        anyhow::ensure!(
            jobs.iter().all(|j| j.agent == jobs[0].agent),
            "shared campaign jobs must share one agent kind"
        );
        anyhow::ensure!(
            jobs.iter().all(|j| j.backend == jobs[0].backend),
            "shared campaign jobs must share one backend (the hub merges one \
             state family and one replay dimensionality)"
        );
        let shared = base.shared.unwrap_or_default();
        anyhow::ensure!(
            shared.merge != MergeMode::Grads || jobs[0].agent == AgentKind::Dqn,
            "gradient-level merging (--merge grads) requires the native DQN agent \
             (--agent dqn) on every job; got {:?}",
            jobs[0].agent
        );
        let sync_every = shared.sync_every.max(1);
        let rounds = base.runs.div_ceil(sync_every).max(1);
        let hub = LearnerHub::new(base.replay_capacity, base.replay_policy, jobs[0].backend)
            .with_merge(shared.merge, base.lr)
            .with_hub_optimizer(shared.hub_lr_schedule, shared.hub_steps)
            .with_staleness(shared.mode.staleness());
        // Fused cross-job training applies only to the native DQN
        // agent (the trainer computes native-kernel gradients); the
        // `fuse_training` knob exists so the fuse-on/off fingerprint
        // identity is testable and the sequential body stays reachable.
        let fused = (self.config().fuse_training && jobs[0].agent == AgentKind::Dqn)
            .then(|| FusedTrainer::new(DenseKernel::default()));
        Ok(SharedCampaign {
            base,
            shared,
            jobs,
            sync_every,
            rounds,
            workers: self.workers_for(jobs.len()),
            hub,
            slots: jobs.iter().map(|_| Mutex::new(None)).collect(),
            straggle: self.config().straggle,
            fused,
        })
    }

    /// Run a shared-learning campaign over `jobs`.
    ///
    /// All jobs must use the same agent kind (the hub merges one state
    /// family). The report carries the final [`crate::coordinator::HubSummary`];
    /// [`CampaignReport::fingerprint`] covers it, so the 1-vs-N-worker
    /// identity check extends to the hub.
    pub fn run_shared(&self, jobs: &[CampaignJob]) -> Result<CampaignReport> {
        // detlint: allow(R3) -- reporting-only: elapsed time is displayed, never fingerprinted
        let started = Instant::now();
        let shared = self.config().base.shared.unwrap_or_default();
        if shared.mode.runs_async() {
            // Async { staleness: 0 } deliberately does NOT take this
            // branch: a zero window forbids any overlap, which is the
            // synchronous schedule by definition — so it runs the sync
            // loop below and is bitwise identical to `--sync-mode sync`.
            return self.run_shared_async(jobs);
        }
        let mut campaign = self.shared_campaign(jobs)?;
        for _round in 0..campaign.rounds {
            campaign.round()?;
        }
        let workers = campaign.workers;
        let (results, hub) = campaign.finish()?;
        Ok(CampaignReport {
            results,
            wall_clock: started.elapsed(),
            workers,
            hub: Some(hub.summary()),
        })
    }

    /// [`CampaignEngine::run_shared`] against a campaign store, with
    /// crash resume.
    ///
    /// A shared campaign cannot *skip* finished jobs the way the
    /// independent path does — every session contributes to every
    /// merge round, so the learning trajectory is sequential in
    /// rounds. Resume therefore means **replay with validation**: the
    /// rounds re-run from scratch, and after each merge the hub digest
    /// must equal the digest the manifest recorded for that round
    /// before the crash (self-consistency in the Hunold &
    /// Carpen-Amarie sense — a measurement that cannot be reproduced
    /// bit-identically is reported as divergence, not silently
    /// accepted). What resume *saves* is the simulator work memoized
    /// in the persisted episode cache, and — for a store that already
    /// completed — everything: a complete store short-circuits to a
    /// pure segment replay with no simulation at all.
    ///
    /// `opts.crash_after` counts merge **rounds** here, not jobs.
    pub fn run_shared_spilled(
        &self,
        jobs: &[CampaignJob],
        dir: &Path,
        opts: &SpillOptions,
    ) -> Result<SpillRun> {
        // detlint: allow(R3) -- reporting-only wall clock, never fingerprinted
        let started = Instant::now();
        anyhow::ensure!(!jobs.is_empty(), "shared campaign needs at least one job");
        let base = &self.config().base;
        let shared_cfg = base.shared.unwrap_or_default();
        anyhow::ensure!(
            !shared_cfg.mode.runs_async(),
            "--sync-mode async does not support the campaign store: resume is a \
             round-by-round digest-validated replay, and the async schedule has no \
             rounds to replay; drop --spill-dir/--resume or use --sync-mode sync"
        );
        let digest = campaign_digest(base, jobs, Some(shared_cfg));
        let mut store = if opts.resume {
            let store = CampaignStore::open(dir)?;
            store.validate(StoreMode::Shared, digest, jobs.len())?;
            store
        } else {
            CampaignStore::create(dir, Manifest::new(StoreMode::Shared, digest, jobs.len()))?
        };
        self.cache().load_from(&store.episodes_path())?;

        if store.manifest().complete {
            // Finished store: rebuild the report purely from segments.
            let hub = store
                .manifest()
                .hub
                .context("complete shared store lacks a hub summary")?;
            let workers = self.workers_for(jobs.len());
            let mut report =
                finalize_report(&store, jobs, started.elapsed(), workers, Some(hub))?;
            report.jobs_loaded = jobs.len();
            return Ok(SpillRun::Complete(report));
        }

        let recorded = store.manifest().round_digests.clone();
        let mut campaign = self.shared_campaign(jobs)?;
        let budget = opts.crash_after.unwrap_or(campaign.rounds).min(campaign.rounds);
        for round in 0..budget {
            campaign.round()?;
            let hub_digest = campaign.hub.digest();
            match recorded.get(round) {
                Some(&expected) => anyhow::ensure!(
                    hub_digest == expected,
                    "resumed shared campaign diverged at round {round}: hub digest \
                     {hub_digest:016x}, store recorded {expected:016x} — the replayed \
                     merge sequence no longer matches the original run"
                ),
                None => {
                    store.manifest_mut().round_digests.push(hub_digest);
                    store.save_manifest()?;
                }
            }
        }
        self.cache().save_to(&store.episodes_path())?;
        if budget < campaign.rounds {
            return Ok(SpillRun::Interrupted { completed: budget, total: campaign.rounds });
        }

        let workers = campaign.workers;
        let (results, hub) = campaign.finish()?;
        // Segments of an incomplete shared store are artifacts of a
        // finalize that crashed mid-write; the replay just regenerated
        // every outcome bit-identically, so clear and rewrite.
        store.clear_segments()?;
        let sink = OutcomeSink::create(store.dir(), store.next_generation()?, 1)?;
        for (i, result) in results.iter().enumerate() {
            sink.append(0, i, result)?;
        }
        let summary = hub.summary();
        store.manifest_mut().hub = Some(summary);
        store.manifest_mut().complete = true;
        store.save_manifest()?;
        // Round-trip through the store so the fingerprint we report is
        // the one any later rebuild will reproduce.
        let mut report = finalize_report(&store, jobs, started.elapsed(), workers, Some(summary))?;
        report.jobs_executed = jobs.len();
        Ok(SpillRun::Complete(report))
    }
}

/// Batched greedy selection for one campaign round: one GEMM instead
/// of one forward per live job.
///
/// After a round's merge, every native-DQN worker adopts the *same*
/// dense master state at its next segment start ([`Controller::sync_from_hub`]),
/// so the first greedy selection of each job's segment is the argmax
/// of one shared network at that job's pending session state. This
/// evaluates all of those states as a single `[live_jobs, state_dim]`
/// batch over the master parameters and stages each argmax as a
/// [`Controller::stage_greedy_hint`].
///
/// Determinism: hints are computed before workers spawn, from state
/// that does not depend on worker count; `q_values_batch_of` rows are
/// bit-identical to the per-job single-state forwards they replace
/// (the kernel contract), and a hint replaces only the Q-value
/// computation — never an RNG draw — so trajectories and fingerprints
/// are unchanged. Debug builds re-verify every consumed hint against
/// the live agent. Jobs without a master yet (round 0; the grads-mode
/// bootstrap round) or on a non-native agent get no hint: the AOT
/// engine's forward is not bitwise-comparable to the native kernels,
/// and tabular state is not a dense network.
fn round_hints(
    view: &HubView,
    jobs: &[CampaignJob],
    slots: &[Mutex<Option<Controller>>],
    trainer: Option<&mut FusedTrainer>,
) -> Result<Vec<Option<usize>>> {
    let mut hints: Vec<Option<usize>> = vec![None; jobs.len()];
    if jobs[0].agent != AgentKind::Dqn {
        return Ok(hints);
    }
    let Some(AgentState::Dense { params, .. }) = view.master.as_deref() else {
        return Ok(hints);
    };
    let mut rows: Vec<usize> = Vec::new();
    let mut states: Vec<f32> = Vec::new();
    for (i, slot) in slots.iter().enumerate() {
        let guard = slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if let Some(state) = guard.as_ref().and_then(Controller::session_state) {
            rows.push(i);
            states.extend_from_slice(state);
        }
    }
    if rows.is_empty() {
        return Ok(hints);
    }
    // The packed no-store forward and the plain evaluator are bitwise
    // interchangeable; going through the trainer warms its panel cache
    // for this round's fused training pass over the same master.
    let q = match trainer {
        Some(t) => t.forward(params, &states, rows.len())?,
        None => q_values_batch_of(params, &states, rows.len(), DenseKernel::default())?,
    };
    let num_actions = q.len() / rows.len();
    for (k, &i) in rows.iter().enumerate() {
        hints[i] = Some(argmax(&q[k * num_actions..(k + 1) * num_actions]));
    }
    Ok(hints)
}

/// One job's segment: create-and-begin on first touch, pull the hub
/// view, stage the greedy hint, run `sync_every` tuning runs, package
/// the push. Shared verbatim by the sync round loop and the async
/// driver — the modes differ only in *when* segments run and merge,
/// never in what a segment computes.
#[allow(clippy::too_many_arguments)]
pub(super) fn run_segment(
    base: &TuningConfig,
    shared: SharedLearning,
    job: &CampaignJob,
    job_index: usize,
    sync_every: usize,
    view: &HubView,
    slot: &Mutex<Option<Controller>>,
    hint: Option<usize>,
    straggle: Option<&StraggleSpec>,
    segment: usize,
) -> Result<HubContribution> {
    let mut guard = slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    // Take the controller out of the slot (creating it on first touch),
    // run the segment, and put it back — the take/put-back shape avoids
    // ever holding an `Option` that later code must re-prove is `Some`.
    let mut ctl = take_or_create(&mut guard, base, shared, job)?;
    ctl.sync_from_hub(view)?;
    // Staged *after* the pull so the hint's provenance (the master
    // parameters the batch was evaluated over) is exactly the agent
    // state making the next selection.
    ctl.stage_greedy_hint(hint);
    ctl.step_session(sync_every)?;
    if let Some(spec) = straggle {
        // Benchmark-only heterogeneity: a pure sleep *after* the
        // segment's compute, so it stretches wall clock (what the
        // sync-vs-async ablation measures) without touching any number
        // that feeds a fingerprint.
        let delay = spec.delay(job_index, segment);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
    }
    let contribution = ctl.hub_contribution(job_index);
    *guard = Some(ctl);
    contribution
}

/// Take a job's controller out of its slot, constructing and beginning
/// it on the first touch of the campaign. Shared by the sequential
/// segment body and the fused round's presample phase, so "which round
/// body ran" can never change how a controller is born.
fn take_or_create(
    guard: &mut Option<Controller>,
    base: &TuningConfig,
    shared: SharedLearning,
    job: &CampaignJob,
) -> Result<Controller> {
    match guard.take() {
        Some(ctl) => Ok(ctl),
        None => {
            let cfg = TuningConfig {
                agent: job.agent,
                seed: job.seed,
                machine: job.resolve_machine()?,
                backend: job.backend,
                shared: Some(shared),
                ..base.clone()
            };
            let mut ctl = Controller::new(cfg)?;
            ctl.begin_session(job.workload, job.images)?;
            Ok(ctl)
        }
    }
}

/// Phase 1 of a fused round for one job: pull, stage the hint, run the
/// segment's first tuning run and hand back its presampled minibatch.
/// The prefix (lock, take-or-create, [`Controller::sync_from_hub`],
/// [`Controller::stage_greedy_hint`]) is [`run_segment`]'s own prefix,
/// and the run + sample are the sequential first iteration's draws in
/// the sequential order ([`Controller::step_run_presampled`]).
fn presample_segment(
    base: &TuningConfig,
    shared: SharedLearning,
    job: &CampaignJob,
    view: &HubView,
    slot: &Mutex<Option<Controller>>,
    hint: Option<usize>,
) -> Result<TrainBatch> {
    let mut guard = slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let mut ctl = take_or_create(&mut guard, base, shared, job)?;
    ctl.sync_from_hub(view)?;
    ctl.stage_greedy_hint(hint);
    let batch = ctl.step_run_presampled();
    *guard = Some(ctl);
    batch
}

/// Phase 2 of a fused round for one job: apply the fused gradients
/// ([`Controller::complete_fused`]), run the remaining `sync_every − 1`
/// runs of the segment sequentially, then package the push — from here
/// on, byte for byte what [`run_segment`] does after its first run.
fn complete_segment(
    job_index: usize,
    sync_every: usize,
    slot: &Mutex<Option<Controller>>,
    cell: &Mutex<Option<FusedGrads>>,
    straggle: Option<&StraggleSpec>,
    segment: usize,
) -> Result<HubContribution> {
    let mut ctl = slot
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .take()
        .context("fused round lost a controller between phases")?;
    let grads = cell
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .take()
        .context("fused gradients for this job were already consumed")?;
    ctl.complete_fused(grads)?;
    ctl.step_session(sync_every - 1)?;
    if let Some(spec) = straggle {
        // Same benchmark-only sleep as the sequential body, at the same
        // point: after the segment's compute, before the push.
        let delay = spec.delay(job_index, segment);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
    }
    let contribution = ctl.hub_contribution(job_index);
    let mut guard = slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    *guard = Some(ctl);
    contribution
}
