//! Bounded-staleness asynchronous shared learning: the round barrier
//! replaced by a staleness window.
//!
//! The synchronous driver ([`super::shared`]) pays one barrier per
//! round: every round costs the *maximum* segment time over all jobs,
//! so one straggler stretches every round. This driver removes the
//! barrier. A worker pulls whatever master is current, runs its job's
//! next segment, and the hub merges the contribution the moment the
//! segment ends ([`LearnerHub::merge_one`]) — generation-stamped, with
//! staleness-weighted averaging (weights mode) or a direct scheduled
//! Adam step (grads mode).
//!
//! ## The staleness window
//!
//! Let `G` be the hub generation (total merges) and `g_j` the
//! generation worker `j` pulled at. The merged staleness of a
//! contribution is `G_at_merge - g_pull`, and the hub *errors* on any
//! merge beyond the window `S` ([`LearnerHub::merge_one`] names the
//! offending job and generations). The driver therefore has to make a
//! too-stale merge impossible, and it does so by gating segment
//! *starts*, never merges — merges always proceed immediately, which
//! is what makes the schedule deadlock-free:
//!
//! ```text
//! start allowed  ⇔  in_flight ≤ S  ∧  (G − g_min) + in_flight ≤ S
//! ```
//!
//! where `g_min` is the oldest in-flight pull. Invariant: for every
//! in-flight contribution `j`, `(G − g_j) + (in_flight − 1) ≤ S`.
//! Starts preserve it (that is exactly the gate: the new pull has
//! staleness 0, and the oldest pull is the binding case); a merge
//! bumps `G` by one and shrinks `in_flight` by one, so the sum is
//! unchanged for everyone still in flight. At `j`'s own merge,
//! `in_flight ≥ 1` gives `G − g_j ≤ S` — the hub check can never fire
//! under this driver; it is a second, independent enforcement of the
//! same contract. `S = 0` admits no overlap at all, i.e. the
//! synchronous schedule — which is why
//! [`crate::coordinator::SyncMode::runs_async`] routes
//! `Async { staleness: 0 }` to the sync loop, bitwise.
//!
//! Liveness: a blocked start holds nothing; every in-flight segment
//! terminates and merges unconditionally; once `in_flight` drains to
//! zero the gate is trivially open (`0 ≤ S`). So the campaign always
//! completes, for any `S ≥ 1` and any segment-time skew.
//!
//! ## What determinism survives
//!
//! Per-job trajectories are still driven by per-job forked RNG streams
//! and segments still run [`super::shared::run_segment`] verbatim; the
//! *merge interleaving* is now scheduling-dependent, so the report
//! fingerprint is recorded, not pinned across worker counts (see
//! `docs/shared_learning.md`). The staleness histogram in
//! [`crate::coordinator::HubSummary`] records the schedule the run
//! actually took.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
// detlint: allow(R3) -- wall-clock is reporting-only (CampaignReport.wall_clock); it never feeds fingerprint()
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{AgentKind, AgentState, Controller, HubView, LearnerHub};
use crate::runtime::{argmax, q_values_batch_of, DenseKernel};

use super::engine::CampaignEngine;
use super::job::CampaignJob;
use super::report::{CampaignReport, JobOutcome};
use super::shared::{run_segment, SharedCampaign};

/// Everything the workers share, behind one mutex: the hub plus the
/// scheduling state the staleness gate is computed from. One lock is
/// deliberate — the gate reads `(G, g_min, in_flight)` and a merge
/// writes all three, so finer locking would just reinvent this lock's
/// critical sections with more ways to get them wrong.
struct AsyncState {
    hub: LearnerHub,
    /// Jobs ready to start their next segment (a job re-queues only
    /// after its previous segment merges, so at most one worker ever
    /// touches a job's controller slot at a time).
    queue: VecDeque<usize>,
    /// Segments pulled but not yet merged.
    in_flight: usize,
    /// Multiset of in-flight pull generations; first key = `g_min`.
    pulls: BTreeMap<usize, usize>,
    /// Per-job completed-segment count (also the segment index the
    /// straggle spec keys on).
    segments_done: Vec<usize>,
    /// Total segments not yet merged, across all jobs.
    remaining: usize,
    /// First error wins; everyone drains once it is set.
    error: Option<anyhow::Error>,
}

impl AsyncState {
    /// The start gate described in the module docs.
    fn can_start(&self, window: usize) -> bool {
        if self.in_flight > window {
            return false;
        }
        match self.pulls.keys().next() {
            None => true,
            Some(&g_min) => {
                let generation = self.hub.generations();
                debug_assert!(generation >= g_min);
                (generation - g_min) + self.in_flight <= window
            }
        }
    }

    fn record_pull(&mut self, generation: usize) {
        self.in_flight += 1;
        *self.pulls.entry(generation).or_insert(0) += 1;
    }

    fn clear_pull(&mut self, generation: usize) {
        self.in_flight -= 1;
        if let Some(n) = self.pulls.get_mut(&generation) {
            *n -= 1;
            if *n == 0 {
                self.pulls.remove(&generation);
            }
        }
    }
}

/// The per-pull greedy hint: the async analogue of the sync loop's
/// batched [`super::shared`] round hints. There is no round to batch
/// over — each pull serves one job — so this evaluates a single-row
/// `q_values_batch_of` over the pulled master at the job's pending
/// session state. Same bitwise-kernel contract as the sync path, same
/// "hint replaces a Q evaluation, never an RNG draw" argument, so it
/// cannot perturb the trajectory.
fn pull_hint(
    view: &HubView,
    agent: AgentKind,
    slot: &Mutex<Option<Controller>>,
) -> Result<Option<usize>> {
    if agent != AgentKind::Dqn {
        return Ok(None);
    }
    let Some(AgentState::Dense { params, .. }) = view.master.as_deref() else {
        return Ok(None);
    };
    let guard = slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let Some(state) = guard.as_ref().and_then(Controller::session_state) else {
        return Ok(None);
    };
    let q = q_values_batch_of(params, state, 1, DenseKernel::default())?;
    Ok(Some(argmax(&q)))
}

impl CampaignEngine {
    /// Run a shared campaign on the bounded-staleness asynchronous
    /// schedule. Called by [`CampaignEngine::run_shared`] when the
    /// configured [`crate::coordinator::SyncMode`] has a non-zero
    /// window; not meaningful to call directly with a sync config
    /// (a zero window would serialize every segment through the gate).
    pub(super) fn run_shared_async(&self, jobs: &[CampaignJob]) -> Result<CampaignReport> {
        // detlint: allow(R3) -- reporting-only: elapsed time is displayed, never fingerprinted
        let started = Instant::now();
        let SharedCampaign {
            base,
            shared,
            jobs,
            sync_every,
            rounds,
            workers,
            hub,
            slots,
            straggle,
            // The fused trainer never runs here: workers pull per-merge
            // masters at their own pace, so no two jobs' minibatches
            // are functions of one shared parameter set. Segments stay
            // sequential (and bit-identical to what fusion would have
            // produced anyway).
            fused: _,
        } = self.shared_campaign(jobs)?;
        let window = shared.mode.staleness();
        debug_assert!(window > 0, "run_shared_async dispatched with a zero window");
        let agent = jobs[0].agent;

        let state = Mutex::new(AsyncState {
            hub,
            queue: (0..jobs.len()).collect(),
            in_flight: 0,
            pulls: BTreeMap::new(),
            segments_done: vec![0; jobs.len()],
            remaining: jobs.len() * rounds,
            error: None,
        });
        let ready = Condvar::new();

        std::thread::scope(|scope| {
            for _w in 0..workers {
                let state = &state;
                let ready = &ready;
                let slots = &slots;
                let straggle = straggle.as_ref();
                scope.spawn(move || {
                    let mut guard = state.lock().unwrap_or_else(|p| p.into_inner());
                    loop {
                        if guard.error.is_some() || guard.remaining == 0 {
                            break;
                        }
                        let job = if guard.can_start(window) { guard.queue.pop_front() } else { None };
                        let Some(i) = job else {
                            // Either the window is closed or no job is
                            // ready; both change only at a merge, which
                            // notifies.
                            guard = ready.wait(guard).unwrap_or_else(|p| p.into_inner());
                            continue;
                        };
                        let view = guard.hub.view();
                        let pulled = view.generation;
                        let segment = guard.segments_done[i];
                        guard.record_pull(pulled);
                        drop(guard);

                        let result = pull_hint(&view, agent, &slots[i]).and_then(|hint| {
                            run_segment(
                                base,
                                shared,
                                &jobs[i],
                                i,
                                sync_every,
                                &view,
                                &slots[i],
                                hint,
                                straggle,
                                segment,
                            )
                        });

                        guard = state.lock().unwrap_or_else(|p| p.into_inner());
                        guard.clear_pull(pulled);
                        let merged = result.and_then(|contribution| {
                            guard.hub.merge_one(&contribution, pulled)
                        });
                        match merged {
                            Ok(()) => {
                                guard.segments_done[i] += 1;
                                guard.remaining -= 1;
                                if guard.segments_done[i] < rounds {
                                    guard.queue.push_back(i);
                                }
                            }
                            Err(e) => {
                                if guard.error.is_none() {
                                    guard.error = Some(e);
                                }
                            }
                        }
                        // A merge can open the gate, ready a job, or
                        // finish the campaign — wake everyone to
                        // re-check.
                        ready.notify_all();
                    }
                    drop(guard);
                    ready.notify_all();
                });
            }
        });

        let state = state.into_inner().unwrap_or_else(|p| p.into_inner());
        if let Some(e) = state.error {
            return Err(e);
        }
        anyhow::ensure!(
            state.remaining == 0,
            "async shared campaign stalled with {} segments unmerged (driver bug: \
             the start gate must always reopen once in-flight work drains)",
            state.remaining
        );
        let hub = state.hub;

        // Finish every session in job order — identical to the sync
        // driver's finish, so reports from the two modes differ only
        // where the schedules genuinely diverged.
        let mut results = Vec::with_capacity(jobs.len());
        for (job, slot) in jobs.iter().zip(&slots) {
            let mut ctl = slot
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .take()
                .context("async shared campaign lost a controller")?;
            let outcome = ctl.finish_session()?;
            results.push(JobOutcome { job: *job, outcome });
        }
        Ok(CampaignReport {
            results,
            wall_clock: started.elapsed(),
            workers,
            hub: Some(hub.summary()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendId;
    use crate::coordinator::ReplayPolicyKind;

    fn state_for(window: usize, generations: usize) -> AsyncState {
        let mut hub = LearnerHub::new(64, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_staleness(window);
        // Advance the generation counter without real contributions:
        // the gate only reads `generations()`.
        for _ in 0..generations {
            hub.bump_generation_for_test();
        }
        AsyncState {
            hub,
            queue: VecDeque::new(),
            in_flight: 0,
            pulls: BTreeMap::new(),
            segments_done: Vec::new(),
            remaining: 0,
            error: None,
        }
    }

    #[test]
    fn gate_bounds_concurrency_by_the_window() {
        let mut s = state_for(2, 0);
        // Window S=2 admits at most S+1 = 3 concurrent pulls at the
        // same generation.
        assert!(s.can_start(2));
        s.record_pull(0);
        assert!(s.can_start(2));
        s.record_pull(0);
        assert!(s.can_start(2));
        s.record_pull(0);
        assert!(!s.can_start(2));
        s.clear_pull(0);
        assert!(s.can_start(2));
    }

    #[test]
    fn gate_accounts_for_generation_lag_of_the_oldest_pull() {
        // One old pull at generation 0 while the hub is at 3: with
        // S=4, (G - g_min) + in_flight = 3 + 1 = 4 <= 4 allows one
        // more start; after it, 3 + 2 = 5 > 4 closes the gate even
        // though the raw concurrency (2) is far below S+1.
        let mut s = state_for(4, 3);
        s.record_pull(0);
        assert!(s.can_start(4));
        s.record_pull(3);
        assert!(!s.can_start(4));
        // The old pull merging reopens it.
        s.clear_pull(0);
        s.hub.bump_generation_for_test();
        assert!(s.can_start(4));
    }

    #[test]
    fn gate_invariant_implies_merge_staleness_within_window() {
        // Exhaustively walk small schedules: any interleaving of
        // starts (gate permitting) and merges keeps every merge's
        // staleness within the window. Driven by the in-repo Rng so
        // the walk is seeded, not flaky.
        use crate::util::rng::Rng;
        for window in 1..4usize {
            let mut rng = Rng::with_stream(0x5eed_0123, window as u64);
            for _trial in 0..200 {
                let mut s = state_for(window, 0);
                let mut in_flight: Vec<usize> = Vec::new(); // pull generations
                for _step in 0..40 {
                    let start = rng.chance(0.5);
                    if start && s.can_start(window) {
                        let g = s.hub.generations();
                        s.record_pull(g);
                        in_flight.push(g);
                    } else if !in_flight.is_empty() {
                        // Merge a uniformly random in-flight segment —
                        // adversarial completion order.
                        let k = rng.below(in_flight.len() as u64) as usize;
                        let g = in_flight.swap_remove(k);
                        let staleness = s.hub.generations() - g;
                        assert!(
                            staleness <= window,
                            "merge staleness {staleness} escaped window {window}"
                        );
                        s.clear_pull(g);
                        s.hub.bump_generation_for_test();
                    }
                }
            }
        }
    }
}
