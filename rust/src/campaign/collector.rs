//! Sharded result collection for the worker pool.
//!
//! Each worker pushes finished items into its *own* shard, so the only
//! lock ever contended is uncontended in steady state; the merge step
//! then reassembles the items in job-index order, making the collected
//! output independent of thread scheduling.
//!
//! With a [`SpillSink`] attached, a pushed item that the sink persists
//! is dropped from memory immediately — the shard keeps only the
//! `(index, spilled)` marker — so the collector's residency is bounded
//! by the handful of in-flight items rather than by campaign size.
//! Items the sink *declines* (e.g. failed jobs, which have no durable
//! representation) stay buffered exactly as in the in-memory path.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Where a collector spills completed items.
///
/// Determinism: `spill` observes one `(shard, index, item)` at a time
/// and must not reorder or transform records — the store it writes is
/// merged back in index order, so whatever it persists must decode to
/// exactly the item it was handed. Returns `Ok(Some(bytes))` when the
/// item was durably persisted (the collector may drop it),
/// `Ok(None)` to decline (the collector keeps it in memory), `Err` to
/// abort the campaign (the first error is surfaced after the pool
/// joins; subsequent items are kept, not spilled).
pub trait SpillSink<T>: Send + Sync {
    fn spill(&self, shard: usize, index: usize, item: &T) -> anyhow::Result<Option<usize>>;
}

/// Typed merge failure: exactly which indices a crashed or buggy pool
/// failed to deliver (and which arrived twice). `--resume` reporting
/// depends on the indices, not just the counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectorError {
    pub expected: usize,
    pub collected: usize,
    pub missing: Vec<usize>,
    pub duplicates: Vec<usize>,
}

/// How many offending indices an error message lists before eliding.
const LISTED_INDICES: usize = 16;

fn list_indices(ixs: &[usize]) -> String {
    let mut out = String::new();
    for (n, i) in ixs.iter().take(LISTED_INDICES).enumerate() {
        if n > 0 {
            out.push_str(", ");
        }
        out.push_str(&i.to_string());
    }
    if ixs.len() > LISTED_INDICES {
        out.push_str(&format!(", … ({} total)", ixs.len()));
    }
    out
}

impl std::fmt::Display for CollectorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "collector holds {} of {} items", self.collected, self.expected)?;
        if !self.missing.is_empty() {
            write!(f, "; missing indices [{}]", list_indices(&self.missing))?;
        }
        if !self.duplicates.is_empty() {
            write!(f, "; duplicated indices [{}]", list_indices(&self.duplicates))?;
        }
        Ok(())
    }
}

impl std::error::Error for CollectorError {}

/// Per-worker sharded `(index, item)` store with an order-restoring
/// merge and an optional bounded-memory spill path.
pub struct ShardedCollector<T> {
    /// `None` marks an item the sink persisted (index accounted for,
    /// payload on disk).
    shards: Vec<Mutex<Vec<(usize, Option<T>)>>>,
    expected: usize,
    sink: Option<Arc<dyn SpillSink<T>>>,
    /// First sink failure; later pushes fall back to buffering.
    sink_error: Mutex<Option<anyhow::Error>>,
    buffered: AtomicUsize,
    peak_buffered: AtomicUsize,
    spilled: AtomicUsize,
    spilled_bytes: AtomicUsize,
}

impl<T> ShardedCollector<T> {
    /// In-memory collector for `expected` items over `shards` workers.
    pub fn new(expected: usize, shards: usize) -> ShardedCollector<T> {
        ShardedCollector {
            shards: (0..shards.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
            expected,
            sink: None,
            sink_error: Mutex::new(None),
            buffered: AtomicUsize::new(0),
            peak_buffered: AtomicUsize::new(0),
            spilled: AtomicUsize::new(0),
            spilled_bytes: AtomicUsize::new(0),
        }
    }

    /// Spilling collector: pushed items are offered to `sink` first and
    /// only buffered if the sink declines (or has already failed).
    pub fn with_spill(
        expected: usize,
        shards: usize,
        sink: Arc<dyn SpillSink<T>>,
    ) -> ShardedCollector<T> {
        let mut c = ShardedCollector::new(expected, shards);
        c.sink = Some(sink);
        c
    }

    /// Record the result for global index `index` from worker `shard`.
    ///
    /// A poisoned shard lock is recovered, not propagated: the vector
    /// behind it is append-only, so a panicking sibling can never leave
    /// it in a torn state, and the merge still catches any item it
    /// failed to deliver.
    pub fn push(&self, shard: usize, index: usize, item: T) {
        let entry = match &self.sink {
            Some(sink) if self.sink_error.lock().unwrap_or_else(|p| p.into_inner()).is_none() => {
                match sink.spill(shard, index, &item) {
                    Ok(Some(bytes)) => {
                        self.spilled.fetch_add(1, Ordering::Relaxed);
                        self.spilled_bytes.fetch_add(bytes, Ordering::Relaxed);
                        (index, None)
                    }
                    Ok(None) => (index, Some(item)),
                    Err(e) => {
                        let mut slot =
                            self.sink_error.lock().unwrap_or_else(|p| p.into_inner());
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        (index, Some(item))
                    }
                }
            }
            _ => (index, Some(item)),
        };
        if entry.1.is_some() {
            let now = self.buffered.fetch_add(1, Ordering::Relaxed) + 1;
            self.peak_buffered.fetch_max(now, Ordering::Relaxed);
        }
        self.shards[shard % self.shards.len()]
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .push(entry);
    }

    /// Most items held in memory at once (spill mode: the declined /
    /// not-yet-spilled residency, the number the scaling bench pins).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered.load(Ordering::Relaxed)
    }

    /// Items the sink persisted.
    pub fn spilled(&self) -> usize {
        self.spilled.load(Ordering::Relaxed)
    }

    /// Total bytes the sink reported writing.
    pub fn spilled_bytes(&self) -> usize {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    fn drain(self) -> (Vec<(usize, Option<T>)>, Option<anyhow::Error>) {
        let mut all: Vec<(usize, Option<T>)> = Vec::with_capacity(self.expected.min(1 << 20));
        for shard in self.shards {
            all.extend(shard.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner()));
        }
        all.sort_by_key(|(i, _)| *i);
        let err = self.sink_error.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner());
        (all, err)
    }

    fn index_error(expected_ixs: &BTreeSet<usize>, got: &[usize]) -> CollectorError {
        let got_set: BTreeSet<usize> = got.iter().copied().collect();
        let mut duplicates: Vec<usize> = Vec::new();
        for w in got.windows(2) {
            if w[0] == w[1] && duplicates.last() != Some(&w[0]) {
                duplicates.push(w[0]);
            }
        }
        CollectorError {
            expected: expected_ixs.len(),
            collected: got.len(),
            missing: expected_ixs.difference(&got_set).copied().collect(),
            duplicates,
        }
    }

    /// Merge all shards back into index order. Errors (instead of
    /// panicking) when the delivered index set is not exactly
    /// `0..expected`, naming the missing/duplicated indices — in spill
    /// mode that is a recoverable state (`--resume` re-runs them).
    /// Only valid without a sink: a spilled item has no in-memory
    /// payload to merge (use [`ShardedCollector::into_spill_residue`]).
    pub fn into_merged(self) -> Result<Vec<T>, CollectorError> {
        let expected_ixs: BTreeSet<usize> = (0..self.expected).collect();
        let (all, _) = self.drain();
        let got: Vec<usize> = all.iter().map(|(i, _)| *i).collect();
        let ok = got.len() == expected_ixs.len() && got.iter().enumerate().all(|(p, i)| p == *i);
        if !ok {
            return Err(Self::index_error(&expected_ixs, &got));
        }
        let mut out = Vec::with_capacity(all.len());
        for (i, item) in all {
            match item {
                Some(item) => out.push(item),
                // A spilled marker in a merge-from-memory call: the
                // payload is on disk, not here.
                None => {
                    return Err(CollectorError {
                        expected: self.expected,
                        collected: i,
                        missing: vec![i],
                        duplicates: Vec::new(),
                    });
                }
            }
        }
        Ok(out)
    }

    /// Finish a spill-mode pool: surface the first sink error, check
    /// that exactly the `attempted` indices were delivered, and return
    /// the items the sink declined (index-ascending). The engine
    /// inspects these — for campaign outcomes they are the failed jobs.
    pub fn into_spill_residue(
        self,
        attempted: &BTreeSet<usize>,
    ) -> anyhow::Result<Vec<(usize, T)>> {
        let (all, sink_error) = self.drain();
        if let Some(e) = sink_error {
            return Err(e.context("campaign spill sink failed"));
        }
        let got: Vec<usize> = all.iter().map(|(i, _)| *i).collect();
        let delivered: BTreeSet<usize> = got.iter().copied().collect();
        if delivered != *attempted || got.len() != attempted.len() {
            return Err(Self::index_error(attempted, &got).into());
        }
        Ok(all.into_iter().filter_map(|(i, item)| item.map(|t| (i, t))).collect())
    }
}

impl<T> std::fmt::Debug for ShardedCollector<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedCollector")
            .field("shards", &self.shards.len())
            .field("expected", &self.expected)
            .field("spilling", &self.sink.is_some())
            .field("spilled", &self.spilled())
            .field("peak_buffered", &self.peak_buffered())
            .finish()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn merge_restores_index_order_across_shards() {
        let c = ShardedCollector::new(5, 2);
        c.push(1, 3, "d");
        c.push(0, 0, "a");
        c.push(1, 1, "b");
        c.push(0, 4, "e");
        c.push(0, 2, "c");
        assert_eq!(c.into_merged().unwrap(), vec!["a", "b", "c", "d", "e"]);
    }

    #[test]
    fn shard_ids_wrap() {
        let c = ShardedCollector::new(2, 1);
        c.push(7, 1, 10);
        c.push(3, 0, 20);
        assert_eq!(c.into_merged().unwrap(), vec![20, 10]);
    }

    #[test]
    fn missing_items_error_names_the_indices() {
        let c: ShardedCollector<u32> = ShardedCollector::new(4, 2);
        c.push(0, 0, 1);
        c.push(1, 2, 3);
        let err = c.into_merged().unwrap_err();
        assert_eq!(err.expected, 4);
        assert_eq!(err.collected, 2);
        assert_eq!(err.missing, vec![1, 3]);
        assert!(err.duplicates.is_empty());
        let msg = err.to_string();
        assert!(msg.contains("missing indices [1, 3]"), "{msg}");
    }

    #[test]
    fn duplicate_items_error_names_the_indices() {
        let c: ShardedCollector<u32> = ShardedCollector::new(2, 2);
        c.push(0, 0, 1);
        c.push(0, 1, 2);
        c.push(1, 1, 3);
        let err = c.into_merged().unwrap_err();
        assert_eq!(err.duplicates, vec![1]);
        assert!(err.to_string().contains("duplicated indices [1]"), "{}", err);
    }

    #[test]
    fn long_index_lists_are_elided() {
        let c: ShardedCollector<u32> = ShardedCollector::new(40, 1);
        let err = c.into_merged().unwrap_err();
        assert_eq!(err.missing.len(), 40);
        assert!(err.to_string().contains("… (40 total)"), "{}", err);
    }

    #[test]
    fn works_from_multiple_threads() {
        let c = ShardedCollector::new(64, 4);
        std::thread::scope(|s| {
            for w in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for i in (w..64).step_by(4) {
                        c.push(w, i, i * 10);
                    }
                });
            }
        });
        let merged = c.into_merged().unwrap();
        assert_eq!(merged.len(), 64);
        assert!(merged.iter().enumerate().all(|(i, &v)| v == i * 10));
    }

    /// Sink that persists even items (into a shared Vec) and declines
    /// odd ones.
    struct EvenSink(Mutex<Vec<(usize, i32)>>);
    impl SpillSink<i32> for EvenSink {
        fn spill(&self, _shard: usize, index: usize, item: &i32) -> anyhow::Result<Option<usize>> {
            if index % 2 == 0 {
                self.0.lock().unwrap().push((index, *item));
                Ok(Some(8))
            } else {
                Ok(None)
            }
        }
    }

    #[test]
    fn spill_mode_bounds_residency_and_keeps_declined_items() {
        let sink = Arc::new(EvenSink(Mutex::new(Vec::new())));
        let c = ShardedCollector::with_spill(6, 2, sink.clone() as Arc<dyn SpillSink<i32>>);
        for i in 0..6 {
            c.push(i % 2, i, i as i32 * 100);
        }
        assert_eq!(c.spilled(), 3);
        assert_eq!(c.spilled_bytes(), 24);
        assert_eq!(c.peak_buffered(), 3); // only the declined odd items
        let attempted: BTreeSet<usize> = (0..6).collect();
        let residue = c.into_spill_residue(&attempted).unwrap();
        assert_eq!(residue, vec![(1, 100), (3, 300), (5, 500)]);
        assert_eq!(sink.0.lock().unwrap().as_slice(), &[(0, 0), (2, 200), (4, 400)]);
    }

    #[test]
    fn spill_residue_validates_the_attempted_set() {
        let sink = Arc::new(EvenSink(Mutex::new(Vec::new())));
        let c = ShardedCollector::with_spill(4, 1, sink as Arc<dyn SpillSink<i32>>);
        c.push(0, 0, 1);
        c.push(0, 3, 2);
        let attempted: BTreeSet<usize> = (0..4).collect();
        let err = c.into_spill_residue(&attempted).unwrap_err();
        let collector_err = err.downcast_ref::<CollectorError>().unwrap();
        assert_eq!(collector_err.missing, vec![1, 2]);
    }

    struct FailingSink;
    impl SpillSink<i32> for FailingSink {
        fn spill(&self, _s: usize, _i: usize, _t: &i32) -> anyhow::Result<Option<usize>> {
            anyhow::bail!("disk full")
        }
    }

    #[test]
    fn first_sink_error_is_surfaced_and_items_fall_back_to_memory() {
        let c = ShardedCollector::with_spill(2, 1, Arc::new(FailingSink) as Arc<dyn SpillSink<i32>>);
        c.push(0, 0, 1);
        c.push(0, 1, 2);
        assert_eq!(c.spilled(), 0);
        assert_eq!(c.peak_buffered(), 2); // both kept despite the sink
        let attempted: BTreeSet<usize> = (0..2).collect();
        let err = c.into_spill_residue(&attempted).unwrap_err();
        assert!(format!("{err:#}").contains("disk full"), "{err:#}");
    }
}
