//! Sharded result collection for the worker pool.
//!
//! Each worker pushes finished items into its *own* shard, so the only
//! lock ever contended is uncontended in steady state; the merge step
//! then reassembles the items in job-index order, making the collected
//! output independent of thread scheduling.

use std::sync::Mutex;

/// Per-worker sharded `(index, item)` store with an order-restoring
/// merge.
#[derive(Debug)]
pub struct ShardedCollector<T> {
    shards: Vec<Mutex<Vec<(usize, T)>>>,
    expected: usize,
}

impl<T> ShardedCollector<T> {
    /// Collector for `expected` items spread over `shards` workers.
    pub fn new(expected: usize, shards: usize) -> ShardedCollector<T> {
        ShardedCollector {
            shards: (0..shards.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
            expected,
        }
    }

    /// Record the result for global index `index` from worker `shard`.
    ///
    /// A poisoned shard lock is recovered, not propagated: the vector
    /// behind it is append-only, so a panicking sibling can never leave
    /// it in a torn state, and `into_merged` still catches any item it
    /// failed to deliver.
    pub fn push(&self, shard: usize, index: usize, item: T) {
        self.shards[shard % self.shards.len()]
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .push((index, item));
    }

    /// Merge all shards back into index order.
    ///
    /// Panics if the number of collected items differs from `expected`
    /// or any index is duplicated/missing — either would mean a worker
    /// died without reporting, which must not be silent.
    pub fn into_merged(self) -> Vec<T> {
        let mut all: Vec<(usize, T)> = Vec::with_capacity(self.expected);
        for shard in self.shards {
            all.extend(shard.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner()));
        }
        all.sort_by_key(|(i, _)| *i);
        assert_eq!(all.len(), self.expected, "collector item count mismatch");
        for (pos, (i, _)) in all.iter().enumerate() {
            assert_eq!(*i, pos, "collector indices must be exactly 0..expected");
        }
        all.into_iter().map(|(_, item)| item).collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn merge_restores_index_order_across_shards() {
        let c = ShardedCollector::new(5, 2);
        c.push(1, 3, "d");
        c.push(0, 0, "a");
        c.push(1, 1, "b");
        c.push(0, 4, "e");
        c.push(0, 2, "c");
        assert_eq!(c.into_merged(), vec!["a", "b", "c", "d", "e"]);
    }

    #[test]
    fn shard_ids_wrap() {
        let c = ShardedCollector::new(2, 1);
        c.push(7, 1, 10);
        c.push(3, 0, 20);
        assert_eq!(c.into_merged(), vec![20, 10]);
    }

    #[test]
    #[should_panic(expected = "count mismatch")]
    fn missing_items_panic() {
        let c: ShardedCollector<u32> = ShardedCollector::new(3, 2);
        c.push(0, 0, 1);
        c.into_merged();
    }

    #[test]
    fn works_from_multiple_threads() {
        let c = ShardedCollector::new(64, 4);
        std::thread::scope(|s| {
            for w in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for i in (w..64).step_by(4) {
                        c.push(w, i, i * 10);
                    }
                });
            }
        });
        let merged = c.into_merged();
        assert_eq!(merged.len(), 64);
        assert!(merged.iter().enumerate().all(|(i, &v)| v == i * 10));
    }
}
