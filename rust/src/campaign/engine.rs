//! The multi-threaded campaign engine: fan independent tuning jobs and
//! fixed-config evaluations across a `std::thread` worker pool.
//!
//! Work distribution is a shared atomic cursor over the job list; each
//! worker claims the next index, runs the job to completion with its
//! own [`Controller`] seeded from the job spec, and deposits the result
//! in its [`ShardedCollector`] shard. Because every job owns its full
//! RNG stream (see [`crate::campaign::job_grid`]) and results are
//! merged back in job-index order, the campaign report is bit-identical
//! at 1 worker and at N workers — parallelism changes wall-clock only.

use std::collections::BTreeSet;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::controller::seed_mix;
use crate::coordinator::{Controller, HubSummary, TuningConfig};
use crate::mpi_t::CvarSet;
use crate::simmpi::Machine;
use crate::workloads::WorkloadKind;

use super::cache::{EpisodeCache, EpisodeKey};
use super::collector::{ShardedCollector, SpillSink};
use super::job::CampaignJob;
use super::report::{CampaignReport, JobOutcome, ReportAccumulator, SpilledReport};
use super::store::{campaign_digest, format, CampaignStore, Manifest, OutcomeSink, StoreMode};

/// Engine settings: the shared tuning template plus the pool size.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Template for every job's controller; each job overrides `agent`
    /// and `seed` from its own spec.
    pub base: TuningConfig,
    /// Worker threads; `0` means one per available hardware thread.
    pub workers: usize,
    /// Deterministic per-segment delay injection for shared campaigns
    /// (`None` = no delays). The sync-vs-async ablation uses this to
    /// model heterogeneous segment times — a fixed straggler job plus
    /// hash-derived jitter — without touching any simulated result:
    /// delays are pure `thread::sleep`s, so fingerprints are unaffected
    /// and sync mode stays bit-identical with a spec installed.
    pub straggle: Option<StraggleSpec>,
    /// Fuse the round's per-job training minibatches into one packed
    /// cross-job GEMM pass when a shared campaign's round has a dense
    /// master (native DQN; sync schedule). On by default; a pure
    /// throughput knob — the fused and sequential bodies are
    /// bit-identical per job, so this is deliberately **not** part of
    /// any campaign digest or fingerprint, and
    /// `--no-fuse-training` exists to prove it.
    pub fuse_training: bool,
}

impl CampaignConfig {
    pub fn new(base: TuningConfig) -> CampaignConfig {
        CampaignConfig { base, workers: 0, straggle: None, fuse_training: true }
    }
}

/// Deterministic straggler/jitter injection: how long a worker sleeps
/// before finishing `(job_index, segment)`. The delay is a pure
/// function of the spec and those two indices (FNV-mixed, never a
/// clock or thread id), so a delayed campaign is exactly as replayable
/// as an undelayed one — wall-clock changes, results do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StraggleSpec {
    /// Job index that always sleeps `straggler_ms` extra per segment —
    /// the injected straggler the async ablation routes around.
    pub straggler_job: usize,
    /// Constant extra delay of the straggler job per segment (ms).
    pub straggler_ms: u64,
    /// Upper bound of the uniform per-`(job, segment)` jitter every job
    /// draws (ms); 0 disables jitter. Wide jitter across all jobs is
    /// what makes the per-round barrier expensive: each sync round
    /// waits for that round's unluckiest draw.
    pub jitter_ms: u64,
    /// Seed of the jitter hash (vary to resample the delay pattern).
    pub seed: u64,
}

impl StraggleSpec {
    /// The injected delay for one job segment.
    pub fn delay(&self, job_index: usize, segment: usize) -> Duration {
        let mut ms = if job_index == self.straggler_job { self.straggler_ms } else { 0 };
        if self.jitter_ms > 0 {
            let mut h = crate::util::fnv::Fnv64::new();
            h.mix(self.seed);
            h.mix(job_index as u64);
            h.mix(segment as u64);
            ms += h.finish() % (self.jitter_ms + 1);
        }
        Duration::from_millis(ms)
    }
}

/// The campaign engine: a reusable worker-pool front end over
/// [`Controller::tune`] and cached fixed-config evaluation.
#[derive(Debug)]
pub struct CampaignEngine {
    cfg: CampaignConfig,
    cache: EpisodeCache,
}

impl CampaignEngine {
    pub fn new(cfg: CampaignConfig) -> CampaignEngine {
        CampaignEngine { cfg, cache: EpisodeCache::new() }
    }

    /// The shared episode cache (hit/miss stats for reports).
    pub fn cache(&self) -> &EpisodeCache {
        &self.cache
    }

    /// The engine's configuration.
    pub fn config(&self) -> &CampaignConfig {
        &self.cfg
    }

    /// Worker threads the engine will actually use for `n` work items.
    pub fn workers_for(&self, n: usize) -> usize {
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let requested = if self.cfg.workers == 0 { hw } else { self.cfg.workers };
        requested.clamp(1, n.max(1))
    }

    /// Run a full tuning campaign: every job is an independent seeded
    /// tuning session; results come back in job order regardless of
    /// scheduling. Fails with the first (by job index) job error.
    ///
    /// Unlike [`CampaignEngine::run_shared`], this path has no batched
    /// greedy selection: independent jobs hold *distinct* weights from
    /// the first training step on, so there is no shared parameter set
    /// to evaluate all pending states against in one pass — batching
    /// across jobs here would change which network answers each row.
    pub fn run(&self, jobs: &[CampaignJob]) -> Result<CampaignReport> {
        let workers = self.workers_for(jobs.len());
        let started = Instant::now();
        let collector = ShardedCollector::new(jobs.len(), workers);
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                let base = &self.cfg.base;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    collector.push(w, i, run_job(base, &jobs[i]));
                });
            }
        });
        let results = collector.into_merged()?.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(CampaignReport { results, wall_clock: started.elapsed(), workers, hub: None })
    }

    /// [`CampaignEngine::run`] with bounded memory and crash resume:
    /// workers spill each completed job to a per-shard segment in
    /// `dir`, aggregation streams the store back in job-index order,
    /// and the returned report's fingerprint is bitwise identical to
    /// the in-memory path's. With `opts.resume`, jobs the store
    /// already holds are skipped — the resumed campaign's fingerprint
    /// equals an uninterrupted run's because both paths aggregate the
    /// same bit-exact records in the same order.
    pub fn run_spilled(
        &self,
        jobs: &[CampaignJob],
        dir: &Path,
        opts: &SpillOptions,
    ) -> Result<SpillRun> {
        anyhow::ensure!(!jobs.is_empty(), "campaign needs at least one job");
        let digest = campaign_digest(&self.cfg.base, jobs, None);
        let started = Instant::now();
        let mut store = if opts.resume {
            let store = CampaignStore::open(dir)?;
            store.validate(StoreMode::Independent, digest, jobs.len())?;
            store
        } else {
            CampaignStore::create(dir, Manifest::new(StoreMode::Independent, digest, jobs.len()))?
        };
        self.cache.load_from(&store.episodes_path())?;
        let completed = if opts.resume { store.scan_completed()? } else { BTreeSet::new() };
        if let Some(&stray) = completed.range(jobs.len()..).next() {
            anyhow::bail!(
                "store {} holds job index {stray}, past this {}-job campaign",
                dir.display(),
                jobs.len()
            );
        }
        let loaded = completed.len();
        let mut pending: Vec<usize> = (0..jobs.len()).filter(|i| !completed.contains(i)).collect();
        let budget = opts.crash_after.unwrap_or(pending.len()).min(pending.len());
        let interrupted = budget < pending.len();
        pending.truncate(budget);

        if !pending.is_empty() {
            let workers = self.workers_for(pending.len());
            let sink = Arc::new(OutcomeSink::create(store.dir(), store.next_generation()?, workers)?);
            let collector = ShardedCollector::with_spill(
                pending.len(),
                workers,
                sink as Arc<dyn SpillSink<Result<JobOutcome>>>,
            );
            let cursor = AtomicUsize::new(0);
            let pending = &pending;
            std::thread::scope(|scope| {
                for w in 0..workers {
                    let collector = &collector;
                    let cursor = &cursor;
                    let base = &self.cfg.base;
                    scope.spawn(move || loop {
                        let k = cursor.fetch_add(1, Ordering::Relaxed);
                        if k >= pending.len() {
                            break;
                        }
                        // Pushed under the *global* job index: segment
                        // records must merge into 0..jobs.len() across
                        // resume attempts.
                        let i = pending[k];
                        collector.push(w, i, run_job(base, &jobs[i]));
                    });
                }
            });
            let attempted: BTreeSet<usize> = pending.iter().copied().collect();
            // The sink persists every successful outcome, so the
            // residue is the error channel: surface the first (by job
            // index) failure, like the in-memory path does.
            for (i, r) in collector.into_spill_residue(&attempted)? {
                match r {
                    Err(e) => {
                        return Err(e.context(format!(
                            "campaign job {i} ({}) failed",
                            jobs[i].label()
                        )))
                    }
                    Ok(_) => anyhow::bail!(
                        "internal: job {i} succeeded but its outcome was not spilled"
                    ),
                }
            }
            self.cache.save_to(&store.episodes_path())?;
        }

        if interrupted {
            return Ok(SpillRun::Interrupted { completed: loaded + pending.len(), total: jobs.len() });
        }
        let workers = self.workers_for(jobs.len());
        let mut report = finalize_report(&store, jobs, started.elapsed(), workers, None)?;
        report.jobs_loaded = loaded;
        report.jobs_executed = jobs.len() - loaded;
        store.manifest_mut().complete = true;
        store.save_manifest()?;
        Ok(SpillRun::Complete(report))
    }

    /// Score one fixed configuration (mean total time over `repeats`
    /// episodes) through the episode cache, with deterministic
    /// per-repeat seeds — repeated scoring of the same configuration is
    /// answered from the cache.
    pub fn evaluate(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
    ) -> Result<f64> {
        evaluate_config(&self.cfg.base, kind, images, cvars, repeats, Some(&self.cache))
    }

    /// One noise-free probe episode of `cvars` on `(kind, images)`,
    /// using the same derived workload seed as [`evaluate_config`], so
    /// protocol counters and message statistics describe exactly the
    /// problem instance the timed evaluations measured.
    pub fn probe_episode(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
    ) -> Result<crate::coordinator::EpisodeResult> {
        let base = &self.cfg.base;
        let workload_seed = base.seed ^ seed_mix(kind, images);
        cvars.backend().runtime().run_episode(
            kind, images, &base.machine, cvars, 0.0, workload_seed, 1,
        )
    }

    /// Score many fixed configurations in parallel (the batched path
    /// baselines and sweeps fan out through). Results are ordered like
    /// `configs` and identical to calling [`CampaignEngine::evaluate`]
    /// per config serially.
    ///
    /// Work items are individual *episodes* — `(config, repeat)` pairs
    /// — not whole configs, so even one expensive config with many
    /// repeats fans across the full pool (no second pool is spawned;
    /// the granularity change reuses the same cursor + collector).
    pub fn evaluate_batch(
        &self,
        kind: WorkloadKind,
        images: usize,
        configs: &[CvarSet],
        repeats: usize,
    ) -> Result<Vec<f64>> {
        let machine = self.cfg.base.machine.clone();
        let specs: Vec<EvalSpec> = configs
            .iter()
            .map(|cvars| EvalSpec {
                machine: machine.clone(),
                workload: kind,
                images,
                cvars: cvars.clone(),
            })
            .collect();
        self.evaluate_specs(&specs, repeats)
    }

    /// Score heterogeneous fixed-config evaluations — each spec names
    /// its own machine/workload/scale — on one worker pool, at
    /// per-episode granularity. The means come back in spec order and
    /// each equals the serial [`CampaignEngine::evaluate`] result for
    /// that spec's cell bit-for-bit (same per-repeat seeds, same
    /// in-order summation).
    pub fn evaluate_specs(&self, specs: &[EvalSpec], repeats: usize) -> Result<Vec<f64>> {
        if specs.is_empty() {
            return Ok(Vec::new());
        }
        let repeats = repeats.max(1);
        let items = specs.len() * repeats;
        let workers = self.workers_for(items);
        let collector = ShardedCollector::new(items, workers);
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                let base = &self.cfg.base;
                let cache = &self.cache;
                scope.spawn(move || loop {
                    let j = cursor.fetch_add(1, Ordering::Relaxed);
                    if j >= items {
                        break;
                    }
                    let spec = &specs[j / repeats];
                    let run_seed = (j % repeats) as u64 + 1;
                    let workload_seed = base.seed ^ seed_mix(spec.workload, spec.images);
                    let r = cached_episode_time(
                        &spec.machine,
                        spec.workload,
                        spec.images,
                        &spec.cvars,
                        base.noise,
                        workload_seed,
                        run_seed,
                        Some(cache),
                    );
                    collector.push(w, j, r);
                });
            }
        });
        let times = collector.into_merged()?.into_iter().collect::<Result<Vec<f64>>>()?;
        // Per-spec mean, summing repeats in seed order — the same
        // accumulation the serial path performs.
        Ok(times
            .chunks(repeats)
            .map(|chunk| {
                let mut total = 0.0;
                for &t in chunk {
                    total += t;
                }
                total / repeats as f64
            })
            .collect())
    }
}

/// Options for the spillable/resumable campaign paths.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpillOptions {
    /// Open an existing store and skip (independent) or replay-validate
    /// (shared) the work it already holds.
    pub resume: bool,
    /// Deterministic crash hook for tests and the CI resume smoke:
    /// stop after this many newly-executed jobs (independent) or merge
    /// rounds (shared) and return [`SpillRun::Interrupted`].
    pub crash_after: Option<usize>,
}

/// Result of a spilled campaign attempt.
#[derive(Debug)]
pub enum SpillRun {
    Complete(SpilledReport),
    /// The crash budget ran out first; everything finished so far is
    /// durable in the store and `--resume` picks up from here.
    Interrupted { completed: usize, total: usize },
}

impl SpillRun {
    /// Unwrap a completed run (test/CLI convenience).
    pub fn into_complete(self) -> Result<SpilledReport> {
        match self {
            SpillRun::Complete(report) => Ok(report),
            SpillRun::Interrupted { completed, total } => anyhow::bail!(
                "campaign interrupted after {completed}/{total} units; resume it first"
            ),
        }
    }
}

/// Stream every segment of `store` through a [`ReportAccumulator`] in
/// global job-index order, cross-checking each record against the live
/// job list. This is the only way reports are built from a store —
/// completion, resume and rebuild all converge here, so they cannot
/// disagree with each other (or with the in-memory fingerprint, which
/// shares the accumulator's mix sequence).
pub(super) fn finalize_report(
    store: &CampaignStore,
    jobs: &[CampaignJob],
    wall_clock: Duration,
    workers: usize,
    hub: Option<HubSummary>,
) -> Result<SpilledReport> {
    let mut acc = ReportAccumulator::new();
    let mut merge = store.merge()?;
    let mut pos = 0usize;
    while let Some((i, record)) = merge.next_record()? {
        anyhow::ensure!(
            pos < jobs.len() && i == pos,
            "campaign store {} does not hold exactly jobs 0..{} (next stored index: {i}, expected {pos})",
            store.dir().display(),
            jobs.len()
        );
        let (_, outcome) = format::decode_record(&record)
            .with_context(|| format!("decoding stored job {i}"))?;
        anyhow::ensure!(
            outcome.job == jobs[i],
            "stored job {i} ({}) does not match this campaign's job list ({})",
            outcome.job.label(),
            jobs[i].label()
        );
        acc.push(&outcome);
        pos += 1;
    }
    anyhow::ensure!(
        pos == jobs.len(),
        "campaign store {} holds {pos} of {} jobs (crash-interrupted? resume it)",
        store.dir().display(),
        jobs.len()
    );
    Ok(acc.finish(wall_clock, workers, hub))
}

/// One fixed-configuration evaluation cell: a configuration scored on a
/// specific machine, workload and scale. The unit [`CampaignEngine::evaluate_specs`]
/// fans out, letting a single pool span both testbeds (and arbitrary
/// workload mixes) in one call.
#[derive(Debug, Clone)]
pub struct EvalSpec {
    pub machine: Machine,
    pub workload: WorkloadKind,
    pub images: usize,
    pub cvars: CvarSet,
}

/// Run one campaign job: an independent controller seeded from the job.
/// The job's machine and backend override the base config's (the job,
/// not the engine, names the testbed and the tunable runtime), and
/// `shared` is stripped — `run` is the independent path, so its
/// controllers must not track hub-push shards even when the caller's
/// base config also drives `run_shared`.
fn run_job(base: &TuningConfig, job: &CampaignJob) -> Result<JobOutcome> {
    let cfg = TuningConfig {
        agent: job.agent,
        seed: job.seed,
        machine: job.resolve_machine()?,
        backend: job.backend,
        shared: None,
        ..base.clone()
    };
    let mut ctl = Controller::new(cfg)?;
    let outcome = ctl.tune(job.workload, job.images)?;
    Ok(JobOutcome { job: *job, outcome })
}

/// Mean total time of `cvars` on `(kind, images)` over `repeats`
/// episodes, with deterministic per-repeat run seeds (`1..=repeats`).
///
/// The deterministic seeds are what make the cache effective: scoring
/// the same configuration under the same base config always simulates
/// the same episodes, so the second scorer gets pure cache hits. Pass
/// `None` to force re-simulation.
pub fn evaluate_config(
    base: &TuningConfig,
    kind: WorkloadKind,
    images: usize,
    cvars: &CvarSet,
    repeats: usize,
    cache: Option<&EpisodeCache>,
) -> Result<f64> {
    let workload_seed = base.seed ^ seed_mix(kind, images);
    let repeats = repeats.max(1);
    let mut total = 0.0;
    for r in 0..repeats {
        let run_seed = r as u64 + 1;
        total += cached_episode_time(
            &base.machine,
            kind,
            images,
            cvars,
            base.noise,
            workload_seed,
            run_seed,
            cache,
        )?;
    }
    Ok(total / repeats as f64)
}

/// One (possibly cached) episode total time — the shared leaf of the
/// serial and per-episode-parallel evaluation paths.
#[allow(clippy::too_many_arguments)]
fn cached_episode_time(
    machine: &Machine,
    kind: WorkloadKind,
    images: usize,
    cvars: &CvarSet,
    noise: f64,
    workload_seed: u64,
    run_seed: u64,
    cache: Option<&EpisodeCache>,
) -> Result<f64> {
    // The configuration names its backend; the episode key includes
    // the full CvarSet (backend tag and all), so the two runtimes can
    // never collide in the cache.
    let simulate = || {
        Ok(cvars
            .backend()
            .runtime()
            .run_episode(kind, images, machine, cvars, noise, workload_seed, run_seed)?
            .total_time_us)
    };
    match cache {
        Some(c) => {
            let key = EpisodeKey::new(kind, images, cvars, machine, noise, workload_seed, run_seed);
            c.get_or_run(key, simulate)
        }
        None => simulate(),
    }
}
