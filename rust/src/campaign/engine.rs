//! The multi-threaded campaign engine: fan independent tuning jobs and
//! fixed-config evaluations across a `std::thread` worker pool.
//!
//! Work distribution is a shared atomic cursor over the job list; each
//! worker claims the next index, runs the job to completion with its
//! own [`Controller`] seeded from the job spec, and deposits the result
//! in its [`ShardedCollector`] shard. Because every job owns its full
//! RNG stream (see [`crate::campaign::job_grid`]) and results are
//! merged back in job-index order, the campaign report is bit-identical
//! at 1 worker and at N workers — parallelism changes wall-clock only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::controller::seed_mix;
use crate::coordinator::{Controller, TuningConfig};
use crate::mpi_t::CvarSet;
use crate::simmpi::Machine;
use crate::workloads::WorkloadKind;

use super::cache::{EpisodeCache, EpisodeKey};
use super::collector::ShardedCollector;
use super::job::CampaignJob;
use super::report::{CampaignReport, JobOutcome};

/// Engine settings: the shared tuning template plus the pool size.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Template for every job's controller; each job overrides `agent`
    /// and `seed` from its own spec.
    pub base: TuningConfig,
    /// Worker threads; `0` means one per available hardware thread.
    pub workers: usize,
}

impl CampaignConfig {
    pub fn new(base: TuningConfig) -> CampaignConfig {
        CampaignConfig { base, workers: 0 }
    }
}

/// The campaign engine: a reusable worker-pool front end over
/// [`Controller::tune`] and cached fixed-config evaluation.
#[derive(Debug)]
pub struct CampaignEngine {
    cfg: CampaignConfig,
    cache: EpisodeCache,
}

impl CampaignEngine {
    pub fn new(cfg: CampaignConfig) -> CampaignEngine {
        CampaignEngine { cfg, cache: EpisodeCache::new() }
    }

    /// The shared episode cache (hit/miss stats for reports).
    pub fn cache(&self) -> &EpisodeCache {
        &self.cache
    }

    /// The engine's configuration.
    pub fn config(&self) -> &CampaignConfig {
        &self.cfg
    }

    /// Worker threads the engine will actually use for `n` work items.
    pub fn workers_for(&self, n: usize) -> usize {
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let requested = if self.cfg.workers == 0 { hw } else { self.cfg.workers };
        requested.clamp(1, n.max(1))
    }

    /// Run a full tuning campaign: every job is an independent seeded
    /// tuning session; results come back in job order regardless of
    /// scheduling. Fails with the first (by job index) job error.
    ///
    /// Unlike [`CampaignEngine::run_shared`], this path has no batched
    /// greedy selection: independent jobs hold *distinct* weights from
    /// the first training step on, so there is no shared parameter set
    /// to evaluate all pending states against in one pass — batching
    /// across jobs here would change which network answers each row.
    pub fn run(&self, jobs: &[CampaignJob]) -> Result<CampaignReport> {
        let workers = self.workers_for(jobs.len());
        let started = Instant::now();
        let collector = ShardedCollector::new(jobs.len(), workers);
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                let base = &self.cfg.base;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    collector.push(w, i, run_job(base, &jobs[i]));
                });
            }
        });
        let results = collector.into_merged().into_iter().collect::<Result<Vec<_>>>()?;
        Ok(CampaignReport { results, wall_clock: started.elapsed(), workers, hub: None })
    }

    /// Score one fixed configuration (mean total time over `repeats`
    /// episodes) through the episode cache, with deterministic
    /// per-repeat seeds — repeated scoring of the same configuration is
    /// answered from the cache.
    pub fn evaluate(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
    ) -> Result<f64> {
        evaluate_config(&self.cfg.base, kind, images, cvars, repeats, Some(&self.cache))
    }

    /// One noise-free probe episode of `cvars` on `(kind, images)`,
    /// using the same derived workload seed as [`evaluate_config`], so
    /// protocol counters and message statistics describe exactly the
    /// problem instance the timed evaluations measured.
    pub fn probe_episode(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
    ) -> Result<crate::coordinator::EpisodeResult> {
        let base = &self.cfg.base;
        let workload_seed = base.seed ^ seed_mix(kind, images);
        cvars.backend().runtime().run_episode(
            kind, images, &base.machine, cvars, 0.0, workload_seed, 1,
        )
    }

    /// Score many fixed configurations in parallel (the batched path
    /// baselines and sweeps fan out through). Results are ordered like
    /// `configs` and identical to calling [`CampaignEngine::evaluate`]
    /// per config serially.
    ///
    /// Work items are individual *episodes* — `(config, repeat)` pairs
    /// — not whole configs, so even one expensive config with many
    /// repeats fans across the full pool (no second pool is spawned;
    /// the granularity change reuses the same cursor + collector).
    pub fn evaluate_batch(
        &self,
        kind: WorkloadKind,
        images: usize,
        configs: &[CvarSet],
        repeats: usize,
    ) -> Result<Vec<f64>> {
        let machine = self.cfg.base.machine.clone();
        let specs: Vec<EvalSpec> = configs
            .iter()
            .map(|cvars| EvalSpec {
                machine: machine.clone(),
                workload: kind,
                images,
                cvars: cvars.clone(),
            })
            .collect();
        self.evaluate_specs(&specs, repeats)
    }

    /// Score heterogeneous fixed-config evaluations — each spec names
    /// its own machine/workload/scale — on one worker pool, at
    /// per-episode granularity. The means come back in spec order and
    /// each equals the serial [`CampaignEngine::evaluate`] result for
    /// that spec's cell bit-for-bit (same per-repeat seeds, same
    /// in-order summation).
    pub fn evaluate_specs(&self, specs: &[EvalSpec], repeats: usize) -> Result<Vec<f64>> {
        if specs.is_empty() {
            return Ok(Vec::new());
        }
        let repeats = repeats.max(1);
        let items = specs.len() * repeats;
        let workers = self.workers_for(items);
        let collector = ShardedCollector::new(items, workers);
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for w in 0..workers {
                let collector = &collector;
                let cursor = &cursor;
                let base = &self.cfg.base;
                let cache = &self.cache;
                scope.spawn(move || loop {
                    let j = cursor.fetch_add(1, Ordering::Relaxed);
                    if j >= items {
                        break;
                    }
                    let spec = &specs[j / repeats];
                    let run_seed = (j % repeats) as u64 + 1;
                    let workload_seed = base.seed ^ seed_mix(spec.workload, spec.images);
                    let r = cached_episode_time(
                        &spec.machine,
                        spec.workload,
                        spec.images,
                        &spec.cvars,
                        base.noise,
                        workload_seed,
                        run_seed,
                        Some(cache),
                    );
                    collector.push(w, j, r);
                });
            }
        });
        let times = collector.into_merged().into_iter().collect::<Result<Vec<f64>>>()?;
        // Per-spec mean, summing repeats in seed order — the same
        // accumulation the serial path performs.
        Ok(times
            .chunks(repeats)
            .map(|chunk| {
                let mut total = 0.0;
                for &t in chunk {
                    total += t;
                }
                total / repeats as f64
            })
            .collect())
    }
}

/// One fixed-configuration evaluation cell: a configuration scored on a
/// specific machine, workload and scale. The unit [`CampaignEngine::evaluate_specs`]
/// fans out, letting a single pool span both testbeds (and arbitrary
/// workload mixes) in one call.
#[derive(Debug, Clone)]
pub struct EvalSpec {
    pub machine: Machine,
    pub workload: WorkloadKind,
    pub images: usize,
    pub cvars: CvarSet,
}

/// Run one campaign job: an independent controller seeded from the job.
/// The job's machine and backend override the base config's (the job,
/// not the engine, names the testbed and the tunable runtime), and
/// `shared` is stripped — `run` is the independent path, so its
/// controllers must not track hub-push shards even when the caller's
/// base config also drives `run_shared`.
fn run_job(base: &TuningConfig, job: &CampaignJob) -> Result<JobOutcome> {
    let cfg = TuningConfig {
        agent: job.agent,
        seed: job.seed,
        machine: job.resolve_machine()?,
        backend: job.backend,
        shared: None,
        ..base.clone()
    };
    let mut ctl = Controller::new(cfg)?;
    let outcome = ctl.tune(job.workload, job.images)?;
    Ok(JobOutcome { job: *job, outcome })
}

/// Mean total time of `cvars` on `(kind, images)` over `repeats`
/// episodes, with deterministic per-repeat run seeds (`1..=repeats`).
///
/// The deterministic seeds are what make the cache effective: scoring
/// the same configuration under the same base config always simulates
/// the same episodes, so the second scorer gets pure cache hits. Pass
/// `None` to force re-simulation.
pub fn evaluate_config(
    base: &TuningConfig,
    kind: WorkloadKind,
    images: usize,
    cvars: &CvarSet,
    repeats: usize,
    cache: Option<&EpisodeCache>,
) -> Result<f64> {
    let workload_seed = base.seed ^ seed_mix(kind, images);
    let repeats = repeats.max(1);
    let mut total = 0.0;
    for r in 0..repeats {
        let run_seed = r as u64 + 1;
        total += cached_episode_time(
            &base.machine,
            kind,
            images,
            cvars,
            base.noise,
            workload_seed,
            run_seed,
            cache,
        )?;
    }
    Ok(total / repeats as f64)
}

/// One (possibly cached) episode total time — the shared leaf of the
/// serial and per-episode-parallel evaluation paths.
#[allow(clippy::too_many_arguments)]
fn cached_episode_time(
    machine: &Machine,
    kind: WorkloadKind,
    images: usize,
    cvars: &CvarSet,
    noise: f64,
    workload_seed: u64,
    run_seed: u64,
    cache: Option<&EpisodeCache>,
) -> Result<f64> {
    // The configuration names its backend; the episode key includes
    // the full CvarSet (backend tag and all), so the two runtimes can
    // never collide in the cache.
    let simulate = || {
        Ok(cvars
            .backend()
            .runtime()
            .run_episode(kind, images, machine, cvars, noise, workload_seed, run_seed)?
            .total_time_us)
    };
    match cache {
        Some(c) => {
            let key = EpisodeKey::new(kind, images, cvars, machine, noise, workload_seed, run_seed);
            c.get_or_run(key, simulate)
        }
        None => simulate(),
    }
}
