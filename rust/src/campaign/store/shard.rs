//! Per-shard segment files and the k-way job-order merge over them.
//!
//! Each worker owns one segment per campaign attempt ("generation"),
//! named `seg-{generation:04}-{shard:03}.jsonl`, and appends completed
//! jobs as frames ([`super::format`]). Because workers claim jobs from
//! a monotone atomic cursor, indices within one segment are strictly
//! increasing — which is exactly the invariant a k-way min-head merge
//! needs to stream every record back in global job-index order without
//! buffering more than one head record per segment. The merge enforces
//! that invariant (and rejects duplicate indices across segments), so
//! a corrupted or hand-edited store fails loudly instead of producing
//! a silently different fingerprint.

use std::collections::BTreeSet;
use std::fs::{File, OpenOptions};
use std::io::{BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::format::{self, FrameReader};

/// Canonical segment file name for `(generation, shard)`.
pub fn segment_file_name(generation: u32, shard: usize) -> String {
    format!("seg-{generation:04}-{shard:03}.jsonl")
}

/// One segment file on disk.
#[derive(Debug, Clone)]
pub struct Segment {
    pub path: PathBuf,
    pub generation: u32,
    pub shard: usize,
}

fn parse_segment_name(name: &str) -> Option<(u32, usize)> {
    let rest = name.strip_prefix("seg-")?.strip_suffix(".jsonl")?;
    let (g, sh) = rest.split_once('-')?;
    Some((g.parse().ok()?, sh.parse().ok()?))
}

/// Every segment in `dir`, sorted by `(generation, shard)` — the
/// directory-listing order the OS returns is never observable.
pub fn list_segments(dir: &Path) -> Result<Vec<Segment>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing campaign store {}", dir.display()))?;
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        if let Some((generation, shard)) = parse_segment_name(&name.to_string_lossy()) {
            out.push(Segment { path: entry.path(), generation, shard });
        }
    }
    out.sort_by_key(|sg| (sg.generation, sg.shard));
    Ok(out)
}

/// The next unused generation number in `dir` (0 for a fresh store).
/// Each resume attempt writes a fresh generation so it can never
/// append into — or clash with — a prior attempt's segments.
pub fn next_generation(dir: &Path) -> Result<u32> {
    Ok(list_segments(dir)?.iter().map(|sg| sg.generation + 1).max().unwrap_or(0))
}

/// Append-only writer for one shard's segment. Every append is flushed
/// through to the OS before it returns, so a completed job's frame
/// survives any later crash of this process.
#[derive(Debug)]
pub struct ShardWriter {
    out: BufWriter<File>,
    path: PathBuf,
}

impl ShardWriter {
    pub fn create(dir: &Path, generation: u32, shard: usize) -> Result<ShardWriter> {
        let path = dir.join(segment_file_name(generation, shard));
        let file = OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        Ok(ShardWriter { out: BufWriter::new(file), path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record frame; returns the bytes written.
    pub fn append(&mut self, record: &Json) -> Result<usize> {
        let n = format::write_frame(&mut self.out, record)?;
        self.out
            .flush()
            .with_context(|| format!("flushing segment {}", self.path.display()))?;
        Ok(n)
    }
}

struct Cursor {
    segment: Segment,
    reader: FrameReader<BufReader<File>>,
    head: Option<(usize, Json)>,
    last: Option<usize>,
}

impl Cursor {
    fn advance(&mut self) -> Result<()> {
        self.head = match self
            .reader
            .next_frame()
            .with_context(|| format!("reading segment {}", self.segment.path.display()))?
        {
            Some(json) => {
                let i = format::record_index(&json)?;
                if let Some(prev) = self.last {
                    anyhow::ensure!(
                        i > prev,
                        "segment {}: record index {i} after {prev} — segments must be \
                         strictly index-ascending",
                        self.segment.path.display()
                    );
                }
                self.last = Some(i);
                Some((i, json))
            }
            None => None,
        };
        Ok(())
    }
}

/// Streaming k-way merge over every segment in a store directory,
/// yielding records in ascending global job-index order while holding
/// only one head record per segment in memory.
pub struct SegmentMerge {
    cursors: Vec<Cursor>,
}

impl SegmentMerge {
    pub fn open(dir: &Path) -> Result<SegmentMerge> {
        let mut cursors = Vec::new();
        for segment in list_segments(dir)? {
            let file = File::open(&segment.path)
                .with_context(|| format!("opening segment {}", segment.path.display()))?;
            let mut cursor = Cursor {
                reader: FrameReader::new(BufReader::new(file)),
                segment,
                head: None,
                last: None,
            };
            cursor.advance()?;
            cursors.push(cursor);
        }
        Ok(SegmentMerge { cursors })
    }

    /// The next record in ascending job-index order, or `None` when
    /// every segment is exhausted. Duplicate indices across segments
    /// are an error (a store can hold each job at most once).
    pub fn next_record(&mut self) -> Result<Option<(usize, Json)>> {
        let mut best: Option<(usize, usize)> = None; // (cursor, index)
        for (k, cursor) in self.cursors.iter().enumerate() {
            let Some((i, _)) = cursor.head else { continue };
            match best {
                None => best = Some((k, i)),
                Some((bk, bi)) => {
                    anyhow::ensure!(
                        i != bi,
                        "job index {i} appears in both {} and {}",
                        self.cursors[bk].segment.path.display(),
                        self.cursors[k].segment.path.display()
                    );
                    if i < bi {
                        best = Some((k, i));
                    }
                }
            }
        }
        let Some((k, _)) = best else { return Ok(None) };
        let head = self.cursors[k].head.take();
        self.cursors[k].advance()?;
        Ok(head)
    }
}

/// The set of job indices a store already holds a completed record
/// for — the manifest of finished work `--resume` skips. Derived by
/// scanning the segments themselves (the frames are the durable truth;
/// a counter file could lie after a crash).
pub fn scan_completed(dir: &Path) -> Result<BTreeSet<usize>> {
    let mut merge = SegmentMerge::open(dir)?;
    let mut done = BTreeSet::new();
    while let Some((i, _)) = merge.next_record()? {
        done.insert(i);
    }
    Ok(done)
}
