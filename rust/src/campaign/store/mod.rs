//! The spillable, crash-resumable campaign store.
//!
//! A store is a directory: a [`Manifest`] (`manifest.json`), one
//! segment file per `(generation, shard)` holding completed-job frames
//! ([`shard`]), and an optional persisted episode cache
//! (`episodes.jsonl`, written by [`super::cache::EpisodeCache`]).
//! Workers spill each finished [`JobOutcome`] to their shard as a
//! bit-exact frame ([`format`]); aggregation streams every segment
//! back in global job-index order, so the report fingerprint of a
//! spilled campaign is bitwise identical to the in-memory path — and a
//! resumed campaign to an uninterrupted one. `docs/campaign_store.md`
//! has the full layout and the determinism argument.

pub mod format;
pub mod manifest;
pub mod shard;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::{SharedLearning, TuningConfig};
use crate::util::fnv::Fnv64;

use super::collector::SpillSink;
use super::job::CampaignJob;
use super::report::JobOutcome;

pub use manifest::{Manifest, StoreMode};
pub use shard::{SegmentMerge, ShardWriter};

/// An open campaign store directory plus its manifest.
#[derive(Debug)]
pub struct CampaignStore {
    dir: PathBuf,
    manifest: Manifest,
}

impl CampaignStore {
    /// Create a fresh store. Refuses a directory that already holds a
    /// manifest — continuing an existing store is `--resume`'s job, and
    /// silently appending to one here could mix two campaigns.
    pub fn create(dir: &Path, manifest: Manifest) -> Result<CampaignStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating campaign store {}", dir.display()))?;
        anyhow::ensure!(
            !Manifest::path(dir).exists(),
            "{} already holds a campaign store; pass it via --resume to continue it",
            dir.display()
        );
        manifest.save(dir)?;
        Ok(CampaignStore { dir: dir.to_path_buf(), manifest })
    }

    /// Open an existing store.
    pub fn open(dir: &Path) -> Result<CampaignStore> {
        let manifest = Manifest::load(dir)?;
        Ok(CampaignStore { dir: dir.to_path_buf(), manifest })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn manifest_mut(&mut self) -> &mut Manifest {
        &mut self.manifest
    }

    pub fn save_manifest(&self) -> Result<()> {
        self.manifest.save(&self.dir)
    }

    /// Check that this store belongs to the campaign the caller is
    /// about to run; the error names which flag family diverged.
    pub fn validate(&self, mode: StoreMode, config_digest: u64, total_jobs: usize) -> Result<()> {
        anyhow::ensure!(
            self.manifest.mode == mode,
            "{} is a {} campaign store, this invocation is {}",
            self.dir.display(),
            self.manifest.mode.name(),
            mode.name()
        );
        anyhow::ensure!(
            self.manifest.total_jobs == total_jobs,
            "{} was written for {} jobs, this invocation builds {} — \
             the grid flags (backend/machine/images/seed) differ",
            self.dir.display(),
            self.manifest.total_jobs,
            total_jobs
        );
        anyhow::ensure!(
            self.manifest.config_digest == config_digest,
            "{} was written by a different campaign configuration \
             (digest {:016x}, this invocation {:016x}); rerun with the original flags",
            self.dir.display(),
            self.manifest.config_digest,
            config_digest
        );
        Ok(())
    }

    /// Job indices with a durable completed record (segment scan — the
    /// frames themselves are the source of truth, not a counter).
    pub fn scan_completed(&self) -> Result<BTreeSet<usize>> {
        shard::scan_completed(&self.dir)
    }

    /// Streaming job-index-order merge over every segment.
    pub fn merge(&self) -> Result<SegmentMerge> {
        SegmentMerge::open(&self.dir)
    }

    /// The generation number the next attempt should write under.
    pub fn next_generation(&self) -> Result<u32> {
        shard::next_generation(&self.dir)
    }

    /// Delete every segment file. Only the shared-resume finalizer
    /// calls this: an incomplete shared store's segments are artifacts
    /// of a crashed final write (the replay regenerates them
    /// bit-identically); independent stores never clear — their
    /// segments *are* the completed work.
    pub fn clear_segments(&self) -> Result<usize> {
        let segments = shard::list_segments(&self.dir)?;
        let n = segments.len();
        for seg in segments {
            std::fs::remove_file(&seg.path)
                .with_context(|| format!("removing stale segment {}", seg.path.display()))?;
        }
        Ok(n)
    }

    /// Where this store persists the episode cache.
    pub fn episodes_path(&self) -> PathBuf {
        self.dir.join("episodes.jsonl")
    }
}

/// Order-sensitive digest of everything that determines a campaign's
/// results: the full job list and the result-affecting base-config
/// knobs. `--resume` refuses a store whose digest differs, because
/// merging outcomes computed under different configs would produce a
/// report no single campaign could have produced. (`artifacts_dir` and
/// `workers` are deliberately excluded: worker count never changes
/// results — that is the engine's core invariant — and the artifact
/// path affects where AOT weights load from, not what they compute.)
pub fn campaign_digest(base: &TuningConfig, jobs: &[CampaignJob], shared: Option<SharedLearning>) -> u64 {
    let mut h = Fnv64::new();
    h.mix(jobs.len() as u64);
    for j in jobs {
        h.mix(j.backend.ordinal() as u64);
        for b in j.machine.bytes() {
            h.mix(b as u64);
        }
        for b in j.workload.name().bytes() {
            h.mix(b as u64);
        }
        h.mix(j.images as u64);
        h.mix(j.agent.ordinal() as u64);
        h.mix(j.seed);
    }
    h.mix(base.runs as u64);
    h.mix(base.eps_start.to_bits());
    h.mix(base.eps_end.to_bits());
    h.mix(base.gamma.to_bits() as u64);
    h.mix(base.lr.to_bits() as u64);
    h.mix(base.replay_capacity as u64);
    h.mix(base.replay_batch as u64);
    h.mix(base.replay_policy.ordinal() as u64);
    h.mix(base.replay_refresh_every as u64);
    h.mix(base.replay_refresh_batches as u64);
    h.mix(base.noise.to_bits());
    h.mix(base.seed);
    match shared {
        None => h.mix(0),
        Some(sl) => {
            h.mix(1);
            h.mix(sl.sync_every as u64);
            h.mix(sl.merge.ordinal() as u64);
            // Post-PR-8 knobs fold in only when non-default, so every
            // store written by an earlier build still validates against
            // the digest a current build computes for the same flags.
            if sl.mode != crate::coordinator::SyncMode::Sync
                || sl.hub_lr_schedule != crate::coordinator::HubLrSchedule::Constant
                || sl.hub_steps != 1
            {
                h.mix(2);
                h.mix(sl.mode.staleness() as u64);
                h.mix(matches!(sl.mode, crate::coordinator::SyncMode::Async { .. }) as u64);
                h.mix(sl.hub_lr_schedule.ordinal() as u64);
                h.mix(sl.hub_lr_schedule.period() as u64);
                h.mix(sl.hub_steps as u64);
            }
        }
    }
    h.finish()
}

/// The spill sink campaign workers write through: one [`ShardWriter`]
/// per worker shard. Successful outcomes are persisted (and may then
/// be dropped from memory); failed jobs are declined so the collector
/// keeps the error for the engine to surface.
pub struct OutcomeSink {
    writers: Vec<Mutex<ShardWriter>>,
}

impl OutcomeSink {
    pub fn create(dir: &Path, generation: u32, shards: usize) -> Result<OutcomeSink> {
        let writers = (0..shards.max(1))
            .map(|w| ShardWriter::create(dir, generation, w).map(Mutex::new))
            .collect::<Result<Vec<_>>>()?;
        Ok(OutcomeSink { writers })
    }

    /// Append one record directly (the job-order finalize path of
    /// shared campaigns); returns the bytes written.
    pub fn append(&self, shard: usize, index: usize, outcome: &JobOutcome) -> Result<usize> {
        let record = format::encode_record(index, outcome);
        let mut writer = self.writers[shard % self.writers.len()]
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        writer.append(&record)
    }
}

impl std::fmt::Debug for OutcomeSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OutcomeSink").field("shards", &self.writers.len()).finish()
    }
}

impl SpillSink<Result<JobOutcome>> for OutcomeSink {
    fn spill(&self, shard: usize, index: usize, item: &Result<JobOutcome>) -> Result<Option<usize>> {
        match item {
            Ok(outcome) => self.append(shard, index, outcome).map(Some),
            Err(_) => Ok(None),
        }
    }
}
