//! Bit-exact JSON encoding of campaign records plus the
//! length-prefixed frame format segment files are written in.
//!
//! Every floating-point field is serialized as its 16-hex-digit
//! IEEE-754 bit pattern (and every seed/digest as a 16-hex-digit
//! `u64`), so decode∘encode is the identity on bits — NaN payloads,
//! signed zeros and subnormals included. That round-trip identity is
//! what lets a resumed campaign rebuild a [`crate::campaign::CampaignReport`]
//! fingerprint that is *bitwise equal* to the uninterrupted run's: the
//! fingerprint mixes `f64::to_bits`, and this codec preserves exactly
//! those bits. See `docs/campaign_store.md` for the format layout.
//!
//! Frames are `{decimal payload length}\t{json}\n`. A reader treats an
//! incomplete trailing frame (the artifact a crash mid-append leaves)
//! as end-of-segment, but a corrupt *complete* frame is a hard error —
//! silent data loss must never masquerade as a clean resume.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, Context, Result};

use crate::backend::BackendId;
use crate::coordinator::{AgentKind, TuningOutcome};
use crate::metrics::recorder::{RunRecord, TuningLog};
use crate::metrics::stats::Summary;
use crate::mpi_t::{CvarId, CvarSet, PvarId, PvarStats};
use crate::simmpi::Machine;
use crate::util::json::{arr, num, obj, s, Json};
use crate::workloads::WorkloadKind;

use super::super::job::CampaignJob;
use super::super::report::JobOutcome;

/// Upper bound on one frame's payload; a header past this is corrupt,
/// not merely large (the biggest real record is a few hundred KiB).
const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// An `f64` as its 16-hex-digit bit pattern — exact for every value.
pub fn hex_f64(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

/// A `u64` (seed, digest, noise bits) as 16 hex digits.
pub fn hex_u64(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Decode a [`hex_u64`] field.
pub fn u64_of(j: &Json) -> Result<u64> {
    let t = j.as_str().context("expected a 16-hex-digit bits string")?;
    anyhow::ensure!(t.len() == 16, "hex-bits field must be 16 digits, got {t:?}");
    u64::from_str_radix(t, 16).with_context(|| format!("bad hex-bits field {t:?}"))
}

/// Decode a [`hex_f64`] field.
pub fn f64_of(j: &Json) -> Result<f64> {
    Ok(f64::from_bits(u64_of(j)?))
}

/// Decode a non-negative integer count (rejects fractions and values
/// past exact-f64 range, which `Json::as_usize` would silently accept).
pub fn usize_of(j: &Json) -> Result<usize> {
    let n = j.as_f64().context("expected a number")?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= 9_007_199_254_740_992.0,
        "expected a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

/// Encode a configuration as its backend name plus raw values.
pub fn encode_cvars(cv: &CvarSet) -> Json {
    obj(vec![
        ("backend", s(cv.backend().name())),
        ("values", arr(cv.as_slice().iter().map(|&v| num(v as f64)))),
    ])
}

/// Decode a configuration, revalidating every value against the
/// backend's descriptor domains: values are written through
/// [`CvarSet::set`] (which clamps), then compared back, so an
/// out-of-domain value in a tampered or stale store is an error rather
/// than a silently different configuration.
pub fn decode_cvars(j: &Json) -> Result<CvarSet> {
    let name = j.at(&["backend"])?.as_str().context("cvars.backend must be a string")?;
    let backend =
        BackendId::parse(name).with_context(|| format!("unknown backend {name:?} in store"))?;
    let values = j.at(&["values"])?.as_arr().context("cvars.values must be an array")?;
    let mut cv = CvarSet::defaults(backend);
    anyhow::ensure!(
        values.len() == cv.len(),
        "cvar count mismatch: store has {}, backend {} defines {}",
        values.len(),
        backend.name(),
        cv.len()
    );
    for (i, v) in values.iter().enumerate() {
        let raw = v.as_f64().context("cvar values must be numbers")?;
        anyhow::ensure!(
            raw.fract() == 0.0 && raw.abs() <= 9_007_199_254_740_992.0,
            "cvar value {raw} is not an exact integer"
        );
        cv.set(CvarId(i), raw as i64);
    }
    for (i, (&have, want)) in cv.as_slice().iter().zip(values).enumerate() {
        let want = want.as_f64().context("cvar values must be numbers")? as i64;
        anyhow::ensure!(
            have == want,
            "cvar {i} value {want} is outside backend {}'s domain (clamped to {have})",
            backend.name()
        );
    }
    Ok(cv)
}

fn encode_summary(sm: &Summary) -> Json {
    obj(vec![
        ("count", num(sm.count as f64)),
        ("mean", hex_f64(sm.mean)),
        ("max", hex_f64(sm.max)),
        ("min", hex_f64(sm.min)),
        ("median", hex_f64(sm.median)),
        ("std", hex_f64(sm.std)),
    ])
}

fn decode_summary(j: &Json) -> Result<Summary> {
    Ok(Summary {
        count: usize_of(j.at(&["count"])?)?,
        mean: f64_of(j.at(&["mean"])?)?,
        max: f64_of(j.at(&["max"])?)?,
        min: f64_of(j.at(&["min"])?)?,
        median: f64_of(j.at(&["median"])?)?,
        std: f64_of(j.at(&["std"])?)?,
    })
}

fn encode_pvars(p: &PvarStats) -> Json {
    arr(p.summaries.iter().map(|(id, sm)| {
        obj(vec![("id", num(id.0 as f64)), ("stats", encode_summary(sm))])
    }))
}

fn decode_pvars(j: &Json) -> Result<PvarStats> {
    let items = j.as_arr().context("pvars must be an array")?;
    let mut summaries = Vec::with_capacity(items.len());
    for it in items {
        let id = PvarId(usize_of(it.at(&["id"])?)?);
        summaries.push((id, decode_summary(it.at(&["stats"])?)?));
    }
    Ok(PvarStats { summaries })
}

fn encode_run(r: &RunRecord) -> Json {
    obj(vec![
        ("run", num(r.run_index as f64)),
        ("us", hex_f64(r.total_time_us)),
        ("reward", hex_f64(r.reward)),
        ("eps", hex_f64(r.epsilon)),
        ("action", r.action.map(|a| num(a as f64)).unwrap_or(Json::Null)),
        ("cvars", encode_cvars(&r.cvars)),
        ("pvars", encode_pvars(&r.pvars)),
    ])
}

fn decode_run(j: &Json) -> Result<RunRecord> {
    let action = match j.at(&["action"])? {
        Json::Null => None,
        v => Some(usize_of(v)?),
    };
    Ok(RunRecord {
        run_index: usize_of(j.at(&["run"])?)?,
        cvars: decode_cvars(j.at(&["cvars"])?)?,
        total_time_us: f64_of(j.at(&["us"])?)?,
        reward: f64_of(j.at(&["reward"])?)?,
        action,
        epsilon: f64_of(j.at(&["eps"])?)?,
        pvars: decode_pvars(j.at(&["pvars"])?)?,
    })
}

fn encode_log(log: &TuningLog) -> Json {
    obj(vec![
        ("workload", s(&log.workload)),
        ("images", num(log.images as f64)),
        ("runs", arr(log.runs.iter().map(encode_run))),
    ])
}

fn decode_log(j: &Json) -> Result<TuningLog> {
    let runs = j.at(&["runs"])?.as_arr().context("log.runs must be an array")?;
    Ok(TuningLog {
        workload: j.at(&["workload"])?.as_str().context("log.workload must be a string")?.into(),
        images: usize_of(j.at(&["images"])?)?,
        runs: runs.iter().map(decode_run).collect::<Result<_>>()?,
    })
}

fn encode_outcome(o: &TuningOutcome) -> Json {
    obj(vec![
        ("log", encode_log(&o.log)),
        ("best", encode_cvars(&o.best)),
        ("ensemble", encode_cvars(&o.ensemble)),
        ("reference_us", hex_f64(o.reference_us)),
        ("best_us", hex_f64(o.best_us)),
    ])
}

fn decode_outcome(j: &Json) -> Result<TuningOutcome> {
    Ok(TuningOutcome {
        log: decode_log(j.at(&["log"])?)?,
        best: decode_cvars(j.at(&["best"])?)?,
        ensemble: decode_cvars(j.at(&["ensemble"])?)?,
        reference_us: f64_of(j.at(&["reference_us"])?)?,
        best_us: f64_of(j.at(&["best_us"])?)?,
    })
}

/// Encode a job spec by canonical names (not ordinals, so stores stay
/// readable and survive enum reordering).
pub fn encode_job(job: &CampaignJob) -> Json {
    obj(vec![
        ("backend", s(job.backend.name())),
        ("machine", s(job.machine)),
        ("workload", s(job.workload.name())),
        ("images", num(job.images as f64)),
        ("agent", s(job.agent.name())),
        ("seed", hex_u64(job.seed)),
    ])
}

/// Decode a job spec, resolving every name against the live registries.
pub fn decode_job(j: &Json) -> Result<CampaignJob> {
    let backend_name = j.at(&["backend"])?.as_str().context("job.backend must be a string")?;
    let machine_name = j.at(&["machine"])?.as_str().context("job.machine must be a string")?;
    let workload_name = j.at(&["workload"])?.as_str().context("job.workload must be a string")?;
    let agent_name = j.at(&["agent"])?.as_str().context("job.agent must be a string")?;
    Ok(CampaignJob {
        backend: BackendId::parse(backend_name)
            .with_context(|| format!("unknown backend {backend_name:?} in store"))?,
        machine: Machine::by_name(machine_name)
            .with_context(|| format!("unknown machine {machine_name:?} in store"))?
            .name,
        workload: WorkloadKind::parse(workload_name)
            .with_context(|| format!("unknown workload {workload_name:?} in store"))?,
        images: usize_of(j.at(&["images"])?)?,
        agent: AgentKind::parse(agent_name)
            .with_context(|| format!("unknown agent {agent_name:?} in store"))?,
        seed: u64_of(j.at(&["seed"])?)?,
    })
}

/// Encode one completed-job record: the global job index plus the full
/// job spec and outcome.
pub fn encode_record(index: usize, r: &JobOutcome) -> Json {
    obj(vec![
        ("i", num(index as f64)),
        ("job", encode_job(&r.job)),
        ("outcome", encode_outcome(&r.outcome)),
    ])
}

/// The job index of a record (cheap peek, used by the segment merge).
pub fn record_index(j: &Json) -> Result<usize> {
    usize_of(j.at(&["i"])?)
}

/// Decode one completed-job record.
pub fn decode_record(j: &Json) -> Result<(usize, JobOutcome)> {
    Ok((
        record_index(j)?,
        JobOutcome { job: decode_job(j.at(&["job"])?)?, outcome: decode_outcome(j.at(&["outcome"])?)? },
    ))
}

/// Append one frame — `{payload byte length}\t{json}\n` — and return
/// the bytes written.
pub fn write_frame(w: &mut impl Write, record: &Json) -> Result<usize> {
    let payload = record.to_string();
    let header = format!("{}\t", payload.len());
    w.write_all(header.as_bytes())?;
    w.write_all(payload.as_bytes())?;
    w.write_all(b"\n")?;
    Ok(header.len() + payload.len() + 1)
}

/// Streaming frame reader. Stops cleanly at an incomplete trailing
/// frame (crash artifact; see [`FrameReader::truncated`]) but fails on
/// a corrupt complete frame.
#[derive(Debug)]
pub struct FrameReader<R> {
    inner: R,
    truncated: bool,
    frames: usize,
}

impl<R: BufRead> FrameReader<R> {
    pub fn new(inner: R) -> FrameReader<R> {
        FrameReader { inner, truncated: false, frames: 0 }
    }

    /// Whether reading stopped at a torn trailing frame.
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// The next complete frame, or `None` at end of input (including a
    /// torn tail).
    pub fn next_frame(&mut self) -> Result<Option<Json>> {
        if self.truncated {
            return Ok(None);
        }
        let mut header = Vec::new();
        self.inner.read_until(b'\t', &mut header)?;
        if header.is_empty() {
            return Ok(None);
        }
        if header.last() != Some(&b'\t') {
            self.truncated = true;
            return Ok(None);
        }
        header.pop();
        let text = std::str::from_utf8(&header).ok();
        let len: usize = match text.and_then(|t| t.parse().ok()) {
            Some(n) if n <= MAX_FRAME_BYTES => n,
            _ => bail!(
                "corrupt frame header {:?} after frame {}",
                String::from_utf8_lossy(&header),
                self.frames
            ),
        };
        // Payload plus its trailing newline, read exactly.
        let mut payload = vec![0u8; len + 1];
        let mut got = 0;
        while got < payload.len() {
            let n = self.inner.read(&mut payload[got..])?;
            if n == 0 {
                self.truncated = true;
                return Ok(None);
            }
            got += n;
        }
        anyhow::ensure!(
            payload.pop() == Some(b'\n'),
            "frame {} is missing its trailing newline",
            self.frames
        );
        let text = std::str::from_utf8(&payload)
            .with_context(|| format!("frame {} payload is not UTF-8", self.frames))?;
        let json = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("frame {}: {e}", self.frames))?;
        self.frames += 1;
        Ok(Some(json))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::TuningLog;

    fn sample_outcome() -> JobOutcome {
        let backend = BackendId::Coarrays;
        let mut cvars = CvarSet::defaults(backend);
        cvars.set(CvarId(0), 1);
        let mut log = TuningLog::new("lattice_boltzmann", 8);
        log.push(RunRecord {
            run_index: 0,
            cvars: cvars.clone(),
            total_time_us: 123.456_789,
            reward: -0.25,
            action: Some(3),
            epsilon: 0.9,
            pvars: PvarStats {
                summaries: vec![(
                    PvarId(2),
                    Summary { count: 4, mean: 1.5, max: 2.0, min: 1.0, median: 1.5, std: 0.5 },
                )],
            },
        });
        log.push(RunRecord {
            run_index: 1,
            cvars: cvars.clone(),
            total_time_us: f64::INFINITY,
            reward: f64::from_bits(0x7ff8_0000_dead_beef), // NaN payload
            action: None,
            epsilon: -0.0,
            pvars: PvarStats::default(),
        });
        JobOutcome {
            job: CampaignJob {
                backend,
                machine: "cheyenne",
                workload: WorkloadKind::LatticeBoltzmann,
                images: 8,
                agent: AgentKind::Tabular,
                seed: u64::MAX,
            },
            outcome: TuningOutcome {
                log,
                best: cvars.clone(),
                ensemble: cvars,
                reference_us: 200.0,
                best_us: 150.0,
            },
        }
    }

    #[test]
    fn record_round_trip_is_byte_identical() {
        let rec = encode_record(17, &sample_outcome());
        let (i, decoded) = decode_record(&rec).unwrap();
        assert_eq!(i, 17);
        // Re-encoding the decoded record must reproduce the bytes —
        // the bit-exactness claim the resume fingerprint rests on.
        assert_eq!(encode_record(17, &decoded).to_string(), rec.to_string());
    }

    #[test]
    fn frames_round_trip_and_tolerate_torn_tail() {
        let rec = encode_record(0, &sample_outcome());
        let mut buf = Vec::new();
        write_frame(&mut buf, &rec).unwrap();
        write_frame(&mut buf, &rec).unwrap();
        // Tear the second frame mid-payload, as a crash would.
        buf.truncate(buf.len() - 7);
        let mut r = FrameReader::new(&buf[..]);
        assert!(r.next_frame().unwrap().is_some());
        assert!(r.next_frame().unwrap().is_none());
        assert!(r.truncated());
    }

    #[test]
    fn corrupt_complete_frame_is_an_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"5\t{!!!}\n");
        let mut r = FrameReader::new(&buf[..]);
        assert!(r.next_frame().is_err());
        let mut bad_header = FrameReader::new(&b"x9\t{}\n"[..]);
        assert!(bad_header.next_frame().is_err());
    }

    #[test]
    fn agent_names_round_trip() {
        for k in AgentKind::ALL {
            assert_eq!(AgentKind::parse(k.name()), Some(k));
        }
    }
}
