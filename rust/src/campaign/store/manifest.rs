//! The store manifest: which campaign a store belongs to, how far it
//! got, and — for shared campaigns — the per-round hub digests a
//! resumed replay must reproduce.
//!
//! The manifest is metadata, not truth: the set of *completed jobs* is
//! always derived by scanning the segments ([`super::shard::scan_completed`]),
//! because frames are flushed per job while a counter written "later"
//! could be lost to the same crash that killed the campaign. What the
//! manifest does hold is (a) the campaign config digest, so `--resume`
//! refuses a store written under different flags, (b) the hub digest
//! after each completed merge round of a shared campaign, so a replay
//! that diverges is detected at the first bad round, and (c) the final
//! [`HubSummary`] once a shared campaign completes, so a finished
//! store rebuilds its report without re-simulating anything.
//!
//! Saves go through a temp file + rename so a crash mid-save leaves
//! the previous manifest intact.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{HubSummary, MergeMode, ReplayPolicyKind};
use crate::util::json::{arr, num, obj, s, Json};
use crate::workloads::WorkloadKind;

use super::format::{hex_u64, u64_of, usize_of};

pub const MANIFEST_FILE: &str = "manifest.json";
const MANIFEST_VERSION: usize = 1;

/// Which engine path wrote the store; the two have incompatible resume
/// semantics (skip-completed vs replay-validated), so a store is one
/// or the other forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreMode {
    Independent,
    Shared,
}

impl StoreMode {
    pub fn name(self) -> &'static str {
        match self {
            StoreMode::Independent => "independent",
            StoreMode::Shared => "shared",
        }
    }

    pub fn parse(t: &str) -> Option<StoreMode> {
        match t {
            "independent" => Some(StoreMode::Independent),
            "shared" => Some(StoreMode::Shared),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub mode: StoreMode,
    /// [`super::campaign_digest`] of the job list + result-affecting
    /// base config; resume refuses a mismatch.
    pub config_digest: u64,
    pub total_jobs: usize,
    /// Hub digest after each completed merge round (shared mode only).
    pub round_digests: Vec<u64>,
    /// Final hub summary (shared mode, complete stores only).
    pub hub: Option<HubSummary>,
    /// Set once every job's record is durable and verified.
    pub complete: bool,
}

impl Manifest {
    pub fn new(mode: StoreMode, config_digest: u64, total_jobs: usize) -> Manifest {
        Manifest { mode, config_digest, total_jobs, round_digests: Vec::new(), hub: None, complete: false }
    }

    pub fn path(dir: &Path) -> PathBuf {
        dir.join(MANIFEST_FILE)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", num(MANIFEST_VERSION as f64)),
            ("mode", s(self.mode.name())),
            ("config_digest", hex_u64(self.config_digest)),
            ("total_jobs", num(self.total_jobs as f64)),
            ("complete", Json::Bool(self.complete)),
            ("round_digests", arr(self.round_digests.iter().map(|&d| hex_u64(d)))),
            ("hub", self.hub.as_ref().map(encode_hub).unwrap_or(Json::Null)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let version = usize_of(j.at(&["version"])?)?;
        anyhow::ensure!(version == MANIFEST_VERSION, "unsupported manifest version {version}");
        let mode_name = j.at(&["mode"])?.as_str().context("manifest.mode must be a string")?;
        let mode = StoreMode::parse(mode_name)
            .with_context(|| format!("unknown store mode {mode_name:?}"))?;
        let rounds = j.at(&["round_digests"])?.as_arr().context("round_digests must be an array")?;
        let hub = match j.at(&["hub"])? {
            Json::Null => None,
            v => Some(decode_hub(v)?),
        };
        Ok(Manifest {
            mode,
            config_digest: u64_of(j.at(&["config_digest"])?)?,
            total_jobs: usize_of(j.at(&["total_jobs"])?)?,
            round_digests: rounds.iter().map(u64_of).collect::<Result<_>>()?,
            hub,
            complete: matches!(j.at(&["complete"])?, Json::Bool(true)),
        })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = Manifest::path(dir);
        let tmp = dir.join("manifest.json.tmp");
        std::fs::write(&tmp, self.to_json().to_string() + "\n")
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("installing {}", path.display()))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = Manifest::path(dir);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("{} is not a campaign store (no {MANIFEST_FILE})", dir.display()))?;
        let json = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Manifest::from_json(&json).with_context(|| format!("decoding {}", path.display()))
    }
}

fn encode_hub(h: &HubSummary) -> Json {
    obj(vec![
        ("merges", num(h.merges as f64)),
        ("replay_len", num(h.replay_len as f64)),
        ("total_transitions", num(h.total_transitions as f64)),
        ("policy", s(h.policy.name())),
        ("merge", s(h.merge.name())),
        ("occupancy", arr(h.occupancy.iter().map(|&n| num(n as f64)))),
        // Async/hub-optimizer extensions (PR 9). Encoded always —
        // decode tolerates their absence so pre-extension stores load.
        ("generations", num(h.generations as f64)),
        ("staleness", arr(h.staleness.iter().map(|&n| num(n as f64)))),
        ("lr_schedule", s(&h.lr_schedule.to_string())),
        ("hub_steps", num(h.hub_steps as f64)),
        ("digest", hex_u64(h.digest)),
    ])
}

fn decode_hub(j: &Json) -> Result<HubSummary> {
    let policy_name = j.at(&["policy"])?.as_str().context("hub.policy must be a string")?;
    let merge_name = j.at(&["merge"])?.as_str().context("hub.merge must be a string")?;
    let occ = j.at(&["occupancy"])?.as_arr().context("hub.occupancy must be an array")?;
    anyhow::ensure!(
        occ.len() == WorkloadKind::COUNT,
        "hub.occupancy has {} slots, this build defines {} workloads",
        occ.len(),
        WorkloadKind::COUNT
    );
    let mut occupancy = [0usize; WorkloadKind::COUNT];
    for (slot, v) in occupancy.iter_mut().zip(occ) {
        *slot = usize_of(v)?;
    }
    // Extension fields default when absent: stores written before the
    // async/hub-optimizer extensions still load (their campaigns could
    // only have run with the default values).
    let generations = match j.at(&["generations"]) {
        Ok(v) => usize_of(v)?,
        Err(_) => 0,
    };
    let mut staleness = [0usize; crate::coordinator::hub::STALENESS_BUCKETS];
    if let Ok(v) = j.at(&["staleness"]) {
        let buckets = v.as_arr().context("hub.staleness must be an array")?;
        anyhow::ensure!(
            buckets.len() == staleness.len(),
            "hub.staleness has {} buckets, this build defines {}",
            buckets.len(),
            staleness.len()
        );
        for (slot, b) in staleness.iter_mut().zip(buckets) {
            *slot = usize_of(b)?;
        }
    }
    let lr_schedule = match j.at(&["lr_schedule"]) {
        Ok(v) => {
            let name = v.as_str().context("hub.lr_schedule must be a string")?;
            crate::coordinator::HubLrSchedule::parse(name)
                .with_context(|| format!("unknown hub lr schedule {name:?}"))?
        }
        Err(_) => crate::coordinator::HubLrSchedule::Constant,
    };
    let hub_steps = match j.at(&["hub_steps"]) {
        Ok(v) => usize_of(v)?,
        Err(_) => 1,
    };
    Ok(HubSummary {
        merges: usize_of(j.at(&["merges"])?)?,
        replay_len: usize_of(j.at(&["replay_len"])?)?,
        total_transitions: usize_of(j.at(&["total_transitions"])?)?,
        policy: ReplayPolicyKind::parse(policy_name)
            .with_context(|| format!("unknown replay policy {policy_name:?}"))?,
        merge: MergeMode::parse(merge_name)
            .with_context(|| format!("unknown merge mode {merge_name:?}"))?,
        occupancy,
        generations,
        staleness,
        lr_schedule,
        hub_steps,
        digest: u64_of(j.at(&["digest"])?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_round_trips_with_and_without_hub() {
        let mut m = Manifest::new(StoreMode::Shared, 0xdead_beef_0123_4567, 42);
        m.round_digests = vec![1, u64::MAX, 7];
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.mode, StoreMode::Shared);
        assert_eq!(back.config_digest, m.config_digest);
        assert_eq!(back.total_jobs, 42);
        assert_eq!(back.round_digests, m.round_digests);
        assert!(back.hub.is_none());
        assert!(!back.complete);

        m.hub = Some(HubSummary {
            merges: 3,
            replay_len: 10,
            total_transitions: 30,
            policy: ReplayPolicyKind::Stratified,
            merge: MergeMode::Grads,
            occupancy: [1; WorkloadKind::COUNT],
            generations: 5,
            staleness: [2, 2, 1, 0, 0, 0, 0, 0],
            lr_schedule: crate::coordinator::HubLrSchedule::InvSqrt { period: 20 },
            hub_steps: 3,
            digest: 0x0123_4567_89ab_cdef,
        });
        m.complete = true;
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.hub, m.hub);
        assert!(back.complete);
    }

    #[test]
    fn pre_extension_hub_blocks_decode_with_defaults() {
        // A manifest written before the async/hub-optimizer extensions
        // has no generations/staleness/lr_schedule/hub_steps keys; it
        // must decode to the default (inactive) values.
        let legacy = Json::parse(
            r#"{"merges": 2, "replay_len": 4, "total_transitions": 4,
                "policy": "uniform", "merge": "weights",
                "occupancy": [4, 0, 0, 0, 0, 0, 0, 0],
                "digest": "00000000000000ff"}"#,
        )
        .unwrap();
        // Guard: the literal above must track WorkloadKind::COUNT.
        assert_eq!(
            legacy.at(&["occupancy"]).unwrap().as_arr().unwrap().len(),
            WorkloadKind::COUNT
        );
        let hub = decode_hub(&legacy).unwrap();
        assert_eq!(hub.generations, 0);
        assert_eq!(hub.staleness, [0; crate::coordinator::hub::STALENESS_BUCKETS]);
        assert_eq!(hub.lr_schedule, crate::coordinator::HubLrSchedule::Constant);
        assert_eq!(hub.hub_steps, 1);
        assert!(!hub.extensions_active());
    }
}
