//! Campaign job specs: one job = tune one (machine, workload, images)
//! cell with one agent, from one deterministic seed.

use crate::backend::BackendId;
use crate::coordinator::AgentKind;
use crate::simmpi::Machine;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

/// One independent unit of campaign work: a full §5 tuning session of
/// `workload` at `images` processes on `machine`, driven by `agent`
/// over `backend`'s tunable runtime, seeded with `seed`. Jobs carry
/// everything that varies per cell — including the machine model and
/// the backend, the same way `Machine` was lifted in the
/// shared-learning refactor — so one worker pool can span testbeds
/// (and, for independent campaigns, backends). Shared settings (run
/// budget, hyper-parameters) live in the engine's base
/// [`crate::coordinator::TuningConfig`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CampaignJob {
    /// Which tunable runtime this cell tunes.
    pub backend: BackendId,
    /// Machine-model preset name (presets are fully determined by
    /// name; see [`Machine::by_name`]). Stored as the name rather than
    /// the struct so jobs stay `Copy + Eq`.
    pub machine: &'static str,
    pub workload: WorkloadKind,
    pub images: usize,
    pub agent: AgentKind,
    pub seed: u64,
}

impl CampaignJob {
    /// Compact `machine/workload@images` label for tables and logs.
    pub fn label(&self) -> String {
        format!("{}/{}@{}", self.machine, self.workload.name(), self.images)
    }

    /// Resolve the machine-model preset.
    pub fn resolve_machine(&self) -> anyhow::Result<Machine> {
        Machine::by_name(self.machine)
            .ok_or_else(|| anyhow::anyhow!("unknown machine {:?}", self.machine))
    }
}

/// Build the (machine × workload × images) cross-product job list with
/// deterministic per-job seeds.
///
/// Each job's seed is drawn from an independent child stream forked off
/// one master generator ([`Rng::fork`]), so the seed assigned to cell
/// `k` depends only on `master_seed` and `k` — never on which worker
/// thread eventually runs the job. This is what makes campaign results
/// bit-identical across worker counts. For a single machine the cell
/// indexing (and therefore every job seed) is identical to the old
/// machine-less grid.
pub fn job_grid(
    backend: BackendId,
    machines: &[Machine],
    workloads: &[WorkloadKind],
    image_counts: &[usize],
    agent: AgentKind,
    master_seed: u64,
) -> Vec<CampaignJob> {
    let mut master = Rng::new(master_seed);
    let mut jobs = Vec::with_capacity(machines.len() * workloads.len() * image_counts.len());
    for machine in machines {
        for &workload in workloads {
            for &images in image_counts {
                let mut stream = master.fork(jobs.len() as u64 + 1);
                jobs.push(CampaignJob {
                    backend,
                    machine: machine.name,
                    workload,
                    images,
                    agent,
                    seed: stream.next_u64(),
                });
            }
        }
    }
    jobs
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn grid_covers_cross_product_in_stable_order() {
        let jobs = job_grid(
            BackendId::Coarrays,
            &[Machine::cheyenne(), Machine::edison()],
            &[WorkloadKind::Icar, WorkloadKind::CloverLeaf],
            &[16, 32],
            AgentKind::Tabular,
            5,
        );
        assert_eq!(jobs.len(), 8);
        assert_eq!(jobs[0].machine, "cheyenne");
        assert_eq!(jobs[0].workload, WorkloadKind::Icar);
        assert_eq!(jobs[0].images, 16);
        assert_eq!(jobs[3].workload, WorkloadKind::CloverLeaf);
        assert_eq!(jobs[3].images, 32);
        assert_eq!(jobs[4].machine, "edison");
        assert_eq!(jobs[7].workload, WorkloadKind::CloverLeaf);
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let machines = [Machine::cheyenne(), Machine::edison()];
        let a = job_grid(
            BackendId::Coarrays, &machines, &WorkloadKind::TRAINING, &[8, 16],
            AgentKind::Tabular, 9,
        );
        let b = job_grid(
            BackendId::Coarrays, &machines, &WorkloadKind::TRAINING, &[8, 16],
            AgentKind::Tabular, 9,
        );
        assert_eq!(a, b);
        let mut seeds: Vec<u64> = a.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), a.len(), "per-job seeds must be unique");
    }

    #[test]
    fn single_machine_grid_keeps_the_legacy_seed_assignment() {
        // Lifting the machine into the job must not re-seed existing
        // single-machine campaigns: cell k still forks stream k+1.
        let jobs = job_grid(
            BackendId::Coarrays,
            &[Machine::cheyenne()],
            &[WorkloadKind::Icar],
            &[16, 32],
            AgentKind::Tabular,
            9,
        );
        let mut master = Rng::new(9);
        assert_eq!(jobs[0].seed, master.fork(1).next_u64());
        let mut master = Rng::new(9);
        master.fork(1);
        assert_eq!(jobs[1].seed, master.fork(2).next_u64());
    }

    #[test]
    fn different_master_seeds_give_different_job_seeds() {
        let a = job_grid(
            BackendId::Coarrays, &[Machine::cheyenne()], &[WorkloadKind::Icar], &[16],
            AgentKind::Tabular, 1,
        );
        let b = job_grid(
            BackendId::Coarrays, &[Machine::cheyenne()], &[WorkloadKind::Icar], &[16],
            AgentKind::Tabular, 2,
        );
        assert_ne!(a[0].seed, b[0].seed);
    }

    #[test]
    fn label_is_compact_and_machine_resolves() {
        let j = CampaignJob {
            backend: BackendId::Coarrays,
            machine: "edison",
            workload: WorkloadKind::Icar,
            images: 256,
            agent: AgentKind::Tabular,
            seed: 0,
        };
        assert_eq!(j.label(), "edison/icar@256");
        assert_eq!(j.resolve_machine().unwrap().name, "edison");
        let bad = CampaignJob { machine: "summit", ..j };
        assert!(bad.resolve_machine().is_err());
    }
}
