//! Campaign job specs: one job = tune one (workload, images) cell with
//! one agent, from one deterministic seed.

use crate::coordinator::AgentKind;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

/// One independent unit of campaign work: a full §5 tuning session of
/// `workload` at `images` processes, driven by `agent`, seeded with
/// `seed`. Jobs carry everything that varies per cell; shared settings
/// (machine model, run budget, hyper-parameters) live in the engine's
/// base [`crate::coordinator::TuningConfig`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CampaignJob {
    pub workload: WorkloadKind,
    pub images: usize,
    pub agent: AgentKind,
    pub seed: u64,
}

impl CampaignJob {
    /// Compact `workload@images` label for tables and logs.
    pub fn label(&self) -> String {
        format!("{}@{}", self.workload.name(), self.images)
    }
}

/// Build the (workload × images) cross-product job list with
/// deterministic per-job seeds.
///
/// Each job's seed is drawn from an independent child stream forked off
/// one master generator ([`Rng::fork`]), so the seed assigned to cell
/// `k` depends only on `master_seed` and `k` — never on which worker
/// thread eventually runs the job. This is what makes campaign results
/// bit-identical across worker counts.
pub fn job_grid(
    workloads: &[WorkloadKind],
    image_counts: &[usize],
    agent: AgentKind,
    master_seed: u64,
) -> Vec<CampaignJob> {
    let mut master = Rng::new(master_seed);
    let mut jobs = Vec::with_capacity(workloads.len() * image_counts.len());
    for &workload in workloads {
        for &images in image_counts {
            let mut stream = master.fork(jobs.len() as u64 + 1);
            jobs.push(CampaignJob { workload, images, agent, seed: stream.next_u64() });
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_cross_product_in_stable_order() {
        let jobs = job_grid(
            &[WorkloadKind::Icar, WorkloadKind::CloverLeaf],
            &[16, 32],
            AgentKind::Tabular,
            5,
        );
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].workload, WorkloadKind::Icar);
        assert_eq!(jobs[0].images, 16);
        assert_eq!(jobs[3].workload, WorkloadKind::CloverLeaf);
        assert_eq!(jobs[3].images, 32);
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let a = job_grid(&WorkloadKind::TRAINING, &[8, 16], AgentKind::Tabular, 9);
        let b = job_grid(&WorkloadKind::TRAINING, &[8, 16], AgentKind::Tabular, 9);
        assert_eq!(a, b);
        let mut seeds: Vec<u64> = a.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), a.len(), "per-job seeds must be unique");
    }

    #[test]
    fn different_master_seeds_give_different_job_seeds() {
        let a = job_grid(&[WorkloadKind::Icar], &[16], AgentKind::Tabular, 1);
        let b = job_grid(&[WorkloadKind::Icar], &[16], AgentKind::Tabular, 2);
        assert_ne!(a[0].seed, b[0].seed);
    }

    #[test]
    fn label_is_compact() {
        let j = CampaignJob {
            workload: WorkloadKind::Icar,
            images: 256,
            agent: AgentKind::Tabular,
            seed: 0,
        };
        assert_eq!(j.label(), "icar@256");
    }
}
