//! The parallel tuning-campaign engine (§5.4 at scale).
//!
//! The paper's methodology needs ≥ 20 tuning runs per application per
//! scale, and a full §6 evaluation sweeps many (workload, images)
//! cells on several machine models — thousands of simulated runs with
//! an embarrassingly-parallel structure: every cell is an independent
//! seeded tuning session. This module exploits that structure:
//!
//! * [`CampaignJob`] / [`job_grid`] — job specs with deterministic
//!   per-job seeds forked from one master stream ([`crate::util::rng::Rng::fork`]),
//!   so a cell's randomness depends only on the master seed and the
//!   cell index, never on scheduling;
//! * [`CampaignEngine`] — a `std::thread` worker pool (no external
//!   dependencies) that fans jobs across cores via a shared atomic
//!   cursor and runs each with its own [`crate::coordinator::Controller`];
//! * [`ShardedCollector`] — per-worker result shards merged back in
//!   job-index order, so the output is invariant to thread count;
//! * [`EpisodeCache`] — a memo table over `(workload, images, CvarSet,
//!   machine, noise, seeds)` that lets ensemble scoring, baselines and
//!   sweeps skip re-simulating configurations they have already
//!   measured;
//! * [`CampaignReport`] — the merged per-job [`crate::metrics::recorder::TuningLog`]s
//!   plus summary statistics ([`crate::metrics::stats`]), a JSON export,
//!   and a [`CampaignReport::fingerprint`] digest used to assert
//!   bit-identical results across worker counts.
//!
//! Two execution modes share that machinery:
//!
//! * [`CampaignEngine::run`] — **independent** sessions (PR 1): every
//!   job is an isolated learner;
//! * [`CampaignEngine::run_shared`] ([`shared`]) — **shared learning**:
//!   the same jobs coupled through a
//!   [`crate::coordinator::LearnerHub`], pulling/pushing weight and
//!   replay snapshots at a fixed cadence with job-order-sequenced
//!   merges. With `--sync-mode async --staleness N` the round barrier
//!   is replaced by a bounded-staleness window ([`async_shared`]):
//!   contributions merge the moment a segment ends, and a start gate
//!   keeps every merge within `N` hub generations of its pull.
//!
//! Both modes also run against an on-disk [`store`] (the spillable,
//! crash-resumable campaign store): [`CampaignEngine::run_spilled`]
//! bounds collector memory by spilling each completed job to per-shard
//! segment files and streaming them back through a
//! [`ReportAccumulator`] in job-index order, and
//! [`CampaignEngine::run_shared_spilled`] checkpoints per-round hub
//! digests so a killed campaign resumes (independent: skip finished
//! jobs; shared: replay with digest validation) with a fingerprint
//! bitwise identical to an uninterrupted in-memory run. See
//! `docs/campaign_store.md`.
//!
//! The contract the whole module is built around: **campaign results
//! are a pure function of the job list and the base config**. Worker
//! count, scheduling order and cache hit/miss interleaving change
//! wall-clock time, never numbers — in both modes (the shared-mode
//! fingerprint also covers the hub's final state), in memory or
//! through the store.

mod async_shared;
mod cache;
mod collector;
mod engine;
mod job;
mod report;
mod shared;
pub mod store;

pub use cache::{EpisodeCache, EpisodeKey};
pub use collector::{CollectorError, ShardedCollector, SpillSink};
pub use engine::{
    evaluate_config, CampaignConfig, CampaignEngine, EvalSpec, SpillOptions, SpillRun,
    StraggleSpec,
};
pub use job::{job_grid, CampaignJob};
pub use report::{
    ablation_table, CampaignReport, JobOutcome, JobRow, ReportAccumulator, SpilledReport,
};
pub use store::{campaign_digest, CampaignStore};
