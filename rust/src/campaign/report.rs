//! Campaign reports: per-job tuning logs merged into one summary.

use std::time::Duration;

use crate::coordinator::{HubSummary, TuningOutcome};
use crate::metrics::stats::{geomean, Summary};
use crate::util::bench::Table;
use crate::util::fnv::Fnv64;
use crate::util::json::{arr, num, obj, s, Json};

use super::job::CampaignJob;

/// One finished campaign job: the spec plus its full tuning outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job: CampaignJob,
    pub outcome: TuningOutcome,
}

/// The merged result of one campaign: job outcomes in job order plus
/// execution metadata.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub results: Vec<JobOutcome>,
    /// End-to-end campaign wall clock.
    pub wall_clock: Duration,
    /// Worker threads the engine actually used.
    pub workers: usize,
    /// Final hub state for shared-learning campaigns (`None` for
    /// independent campaigns).
    pub hub: Option<HubSummary>,
}

impl CampaignReport {
    /// Best-run improvement per job, in job order.
    pub fn improvements(&self) -> Vec<f64> {
        self.results.iter().map(|r| r.outcome.improvement()).collect()
    }

    /// Geometric-mean speedup (`1 + improvement`) across cells — the §6
    /// cross-workload headline number.
    pub fn geomean_speedup(&self) -> f64 {
        let speedups: Vec<f64> = self.improvements().iter().map(|i| 1.0 + i).collect();
        geomean(&speedups)
    }

    /// Distribution of per-cell improvements (mean/median/min/max/std).
    pub fn improvement_summary(&self) -> Summary {
        Summary::of(&self.improvements())
    }

    /// Total simulated application runs across every job's tuning log
    /// (references included).
    pub fn total_app_runs(&self) -> usize {
        self.results.iter().map(|r| r.outcome.log.runs.len()).sum()
    }

    /// Order-sensitive digest of every job's spec, per-run total times
    /// and configurations — plus, for shared campaigns, the final hub
    /// state (master weights and global replay) — FNV-1a over the raw
    /// bits.
    ///
    /// Two campaign runs produced the same tuning trajectories (and,
    /// in shared mode, the same distributed-learner state) if and only
    /// if their fingerprints match — this is what the 1-worker vs
    /// N-worker determinism checks compare, and what a resumed spilled
    /// campaign must reproduce bit-for-bit (the streaming path in
    /// [`ReportAccumulator`] folds the same `mix_outcome`/`mix_hub`
    /// sequence, so the two can never diverge).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        for r in &self.results {
            mix_outcome(&mut h, r);
        }
        if let Some(hub) = &self.hub {
            mix_hub(&mut h, hub);
        }
        h.finish()
    }

    /// JSON export: campaign metadata, per-job summaries and the full
    /// per-run logs (for EXPERIMENTS.md / offline analysis).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("mode", s(if self.hub.is_some() { "shared" } else { "independent" })),
            ("workers", num(self.workers as f64)),
            ("wall_clock_ms", num(self.wall_clock.as_secs_f64() * 1e3)),
            ("total_app_runs", num(self.total_app_runs() as f64)),
            ("geomean_speedup", num(self.geomean_speedup())),
            (
                "jobs",
                arr(self.results.iter().map(|r| {
                    obj(vec![
                        ("label", s(&r.job.label())),
                        ("backend", s(r.job.backend.name())),
                        ("machine", s(r.job.machine)),
                        ("seed", num(r.job.seed as f64)),
                        ("reference_us", num(r.outcome.reference_us)),
                        ("best_us", num(r.outcome.best_us)),
                        ("improvement", num(r.outcome.improvement())),
                        ("ensemble", s(&r.outcome.ensemble.to_string())),
                        ("log", r.outcome.log.to_json()),
                    ])
                })),
            ),
        ];
        if let Some(hub) = &self.hub {
            let occupancy: Vec<(&str, Json)> = crate::workloads::WorkloadKind::ALL
                .iter()
                .zip(&hub.occupancy)
                .filter(|(_, &n)| n > 0)
                .map(|(kind, &n)| (kind.name(), num(n as f64)))
                .collect();
            let mut hub_fields = vec![
                ("merges", num(hub.merges as f64)),
                ("replay_len", num(hub.replay_len as f64)),
                ("total_transitions", num(hub.total_transitions as f64)),
                ("replay_policy", s(hub.policy.name())),
                ("merge_mode", s(hub.merge.name())),
                ("occupancy", obj(occupancy)),
            ];
            // Gated like `mix_hub`: synchronous default-optimizer
            // campaigns emit the exact PR 8 JSON shape.
            if hub.extensions_active() {
                hub_fields.push(("generations", num(hub.generations as f64)));
                hub_fields.push((
                    "staleness_histogram",
                    arr(hub.staleness.iter().map(|&n| num(n as f64))),
                ));
                hub_fields.push(("hub_lr_schedule", s(&hub.lr_schedule.to_string())));
                hub_fields.push(("hub_steps", num(hub.hub_steps as f64)));
            }
            hub_fields.push(("digest", s(&format!("{:016x}", hub.digest))));
            fields.push(("hub", obj(hub_fields)));
        }
        obj(fields)
    }
}

/// Fold one job's spec and outcome into a campaign fingerprint — the
/// per-result body of [`CampaignReport::fingerprint`], shared with the
/// streaming [`ReportAccumulator`] so the two paths are one sequence
/// of `mix` calls by construction.
fn mix_outcome(h: &mut Fnv64, r: &JobOutcome) {
    h.mix(r.job.backend.ordinal() as u64);
    for b in r.job.machine.bytes() {
        h.mix(b as u64);
    }
    for b in r.job.workload.name().bytes() {
        h.mix(b as u64);
    }
    h.mix(r.job.images as u64);
    h.mix(r.job.seed);
    for run in &r.outcome.log.runs {
        h.mix(run.total_time_us.to_bits());
        for &v in run.cvars.as_slice() {
            h.mix(v as u64);
        }
    }
    h.mix(r.outcome.best_us.to_bits());
    h.mix(r.outcome.reference_us.to_bits());
}

/// Fold the final hub state into a campaign fingerprint (shared-mode
/// tail of [`CampaignReport::fingerprint`]).
fn mix_hub(h: &mut Fnv64, hub: &HubSummary) {
    h.mix(hub.merges as u64);
    h.mix(hub.replay_len as u64);
    h.mix(hub.total_transitions as u64);
    h.mix(hub.policy.ordinal() as u64);
    h.mix(hub.merge.ordinal() as u64);
    for &n in &hub.occupancy {
        h.mix(n as u64);
    }
    // Async/hub-optimizer extensions fold in only when active so every
    // pre-existing synchronous campaign keeps its PR 8 fingerprint.
    if hub.extensions_active() {
        h.mix(hub.generations as u64);
        for &n in &hub.staleness {
            h.mix(n as u64);
        }
        h.mix(hub.lr_schedule.ordinal() as u64);
        h.mix(hub.lr_schedule.period() as u64);
        h.mix(hub.hub_steps as u64);
    }
    h.mix(hub.digest);
}

/// Per-job summary row a streaming aggregation retains: everything the
/// CLI tables and summary statistics need, without the full tuning log.
#[derive(Debug, Clone, Copy)]
pub struct JobRow {
    pub job: CampaignJob,
    pub reference_us: f64,
    pub best_us: f64,
    /// Application runs in this job's tuning log.
    pub runs: usize,
}

impl JobRow {
    /// Best-run improvement; same degenerate-reference guard as
    /// [`TuningOutcome::improvement`].
    pub fn improvement(&self) -> f64 {
        if !(self.reference_us > 0.0 && self.reference_us.is_finite()) {
            return 0.0;
        }
        (self.reference_us - self.best_us) / self.reference_us
    }
}

/// Streaming replacement for building a [`CampaignReport`] in memory:
/// push outcomes **in job-index order**, one at a time, and finish
/// into a [`SpilledReport`] whose fingerprint is bit-identical to
/// [`CampaignReport::fingerprint`] over the same sequence. Memory held
/// is one [`JobRow`] per job (no logs, no cvar histories) — the
/// aggregation side of the bounded-memory spill path.
#[derive(Debug, Default)]
pub struct ReportAccumulator {
    h: Fnv64,
    rows: Vec<JobRow>,
    total_app_runs: usize,
}

impl ReportAccumulator {
    pub fn new() -> ReportAccumulator {
        ReportAccumulator::default()
    }

    /// Fold the next outcome. Order matters: the digest is
    /// order-sensitive, and callers feed it from the job-index-order
    /// segment merge.
    pub fn push(&mut self, r: &JobOutcome) {
        mix_outcome(&mut self.h, r);
        self.total_app_runs += r.outcome.log.runs.len();
        self.rows.push(JobRow {
            job: r.job,
            reference_us: r.outcome.reference_us,
            best_us: r.outcome.best_us,
            runs: r.outcome.log.runs.len(),
        });
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn finish(
        mut self,
        wall_clock: Duration,
        workers: usize,
        hub: Option<HubSummary>,
    ) -> SpilledReport {
        if let Some(hub) = &hub {
            mix_hub(&mut self.h, hub);
        }
        SpilledReport {
            rows: self.rows,
            wall_clock,
            workers,
            hub,
            fingerprint: self.h.finish(),
            total_app_runs: self.total_app_runs,
            jobs_loaded: 0,
            jobs_executed: 0,
        }
    }
}

/// The bounded-memory counterpart of [`CampaignReport`], produced by
/// streaming a campaign store through a [`ReportAccumulator`]: summary
/// rows plus the precomputed fingerprint.
#[derive(Debug, Clone)]
pub struct SpilledReport {
    pub rows: Vec<JobRow>,
    pub wall_clock: Duration,
    pub workers: usize,
    pub hub: Option<HubSummary>,
    fingerprint: u64,
    total_app_runs: usize,
    /// Jobs answered from the store by `--resume` (not re-executed).
    pub jobs_loaded: usize,
    /// Jobs executed by this process.
    pub jobs_executed: usize,
}

impl SpilledReport {
    /// The campaign fingerprint — bit-identical to what
    /// [`CampaignReport::fingerprint`] returns for the same outcomes.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Best-run improvement per job, in job order.
    pub fn improvements(&self) -> Vec<f64> {
        self.rows.iter().map(|r| r.improvement()).collect()
    }

    /// Geometric-mean speedup across cells (see
    /// [`CampaignReport::geomean_speedup`]).
    pub fn geomean_speedup(&self) -> f64 {
        let speedups: Vec<f64> = self.improvements().iter().map(|i| 1.0 + i).collect();
        geomean(&speedups)
    }

    /// Distribution of per-cell improvements.
    pub fn improvement_summary(&self) -> Summary {
        Summary::of(&self.improvements())
    }

    /// Total simulated application runs across every job's tuning log.
    pub fn total_app_runs(&self) -> usize {
        self.total_app_runs
    }
}

/// Per-cell comparison table of an independent campaign and its
/// shared-learning counterpart over the same job list — the one
/// rendering shared by `campaign --shared`, `benches/campaign.rs` and
/// `examples/training_campaign.rs --shared`.
pub fn ablation_table(independent: &CampaignReport, shared: &CampaignReport) -> Table {
    let mut t = Table::new(&[
        "machine", "workload", "images", "reference (µs)", "independent", "shared",
    ]);
    for (a, b) in independent.results.iter().zip(&shared.results) {
        t.row(vec![
            a.job.machine.to_string(),
            a.job.workload.name().to_string(),
            a.job.images.to_string(),
            format!("{:.0}", a.outcome.reference_us),
            format!("{:+.1}%", a.outcome.improvement() * 100.0),
            format!("{:+.1}%", b.outcome.improvement() * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::coordinator::AgentKind;
    use crate::metrics::recorder::TuningLog;
    use crate::mpi_t::CvarSet;
    use crate::workloads::WorkloadKind;

    fn outcome(reference: f64, best: f64) -> TuningOutcome {
        let mut log = TuningLog::new("icar", 8);
        for (i, t) in [reference, best].iter().enumerate() {
            log.push(crate::metrics::recorder::RunRecord {
                run_index: i,
                cvars: CvarSet::vanilla(),
                total_time_us: *t,
                reward: 0.0,
                action: None,
                epsilon: 1.0,
                pvars: crate::mpi_t::PvarStats::default(),
            });
        }
        TuningOutcome {
            log,
            best: CvarSet::vanilla(),
            ensemble: CvarSet::vanilla(),
            reference_us: reference,
            best_us: best,
        }
    }

    fn report(cells: &[(f64, f64)]) -> CampaignReport {
        CampaignReport {
            results: cells
                .iter()
                .map(|&(reference, best)| JobOutcome {
                    job: CampaignJob {
                        backend: crate::backend::BackendId::Coarrays,
                        machine: "cheyenne",
                        workload: WorkloadKind::Icar,
                        images: 8,
                        agent: AgentKind::Tabular,
                        seed: 1,
                    },
                    outcome: outcome(reference, best),
                })
                .collect(),
            wall_clock: Duration::from_millis(5),
            workers: 2,
            hub: None,
        }
    }

    #[test]
    fn summary_numbers_are_consistent() {
        let r = report(&[(100.0, 80.0), (100.0, 90.0)]);
        assert_eq!(r.improvements(), vec![0.2, 0.1]);
        assert_eq!(r.total_app_runs(), 4);
        let s = r.improvement_summary();
        assert_eq!(s.count, 2);
        assert!((s.mean - 0.15).abs() < 1e-12);
        assert!((r.geomean_speedup() - (1.2f64 * 1.1).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_is_sensitive_to_run_times() {
        let a = report(&[(100.0, 80.0)]);
        let b = report(&[(100.0, 80.0)]);
        let c = report(&[(100.0, 81.0)]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_covers_machine_and_hub_state() {
        let a = report(&[(100.0, 80.0)]);
        let mut other_machine = report(&[(100.0, 80.0)]);
        other_machine.results[0].job.machine = "edison";
        assert_ne!(a.fingerprint(), other_machine.fingerprint());
        let mut other_backend = report(&[(100.0, 80.0)]);
        other_backend.results[0].job.backend = crate::backend::BackendId::Collectives;
        assert_ne!(a.fingerprint(), other_backend.fingerprint());

        let mut occupancy = [0usize; WorkloadKind::COUNT];
        occupancy[WorkloadKind::Icar.ordinal()] = 12;
        let mut shared = report(&[(100.0, 80.0)]);
        shared.hub = Some(crate::coordinator::HubSummary {
            merges: 3,
            replay_len: 12,
            total_transitions: 12,
            policy: crate::coordinator::ReplayPolicyKind::Uniform,
            merge: crate::coordinator::MergeMode::Weights,
            occupancy,
            generations: 0,
            staleness: [0; 8],
            lr_schedule: crate::coordinator::HubLrSchedule::Constant,
            hub_steps: 1,
            digest: 0xabc,
        });
        assert_ne!(a.fingerprint(), shared.fingerprint());
        let mut shared2 = shared.clone();
        assert_eq!(shared.fingerprint(), shared2.fingerprint());
        shared2.hub.as_mut().unwrap().digest = 0xdef;
        assert_ne!(shared.fingerprint(), shared2.fingerprint());
        // Policy and retention shape are part of the fingerprint too.
        let mut other_policy = shared.clone();
        other_policy.hub.as_mut().unwrap().policy =
            crate::coordinator::ReplayPolicyKind::Stratified;
        assert_ne!(shared.fingerprint(), other_policy.fingerprint());
        let mut other_occupancy = shared.clone();
        other_occupancy.hub.as_mut().unwrap().occupancy[WorkloadKind::Icar.ordinal()] = 11;
        assert_ne!(shared.fingerprint(), other_occupancy.fingerprint());
        let mut other_merge = shared.clone();
        other_merge.hub.as_mut().unwrap().merge = crate::coordinator::MergeMode::Grads;
        assert_ne!(shared.fingerprint(), other_merge.fingerprint());
        assert_eq!(
            other_merge.to_json().at(&["hub", "merge_mode"]).unwrap().as_str().unwrap(),
            "grads"
        );
        // JSON labels the mode and carries the hub block.
        let j = shared.to_json();
        assert_eq!(j.at(&["mode"]).unwrap().as_str().unwrap(), "shared");
        assert!(j.at(&["hub", "merges"]).is_ok());
        assert_eq!(j.at(&["hub", "replay_policy"]).unwrap().as_str().unwrap(), "uniform");
        assert_eq!(j.at(&["hub", "occupancy", "icar"]).unwrap().as_usize().unwrap(), 12);
        assert_eq!(a.to_json().at(&["mode"]).unwrap().as_str().unwrap(), "independent");
    }

    #[test]
    fn async_extensions_split_fingerprint_and_json_only_when_active() {
        let mut occupancy = [0usize; WorkloadKind::COUNT];
        occupancy[WorkloadKind::Icar.ordinal()] = 4;
        let hub = crate::coordinator::HubSummary {
            merges: 4,
            replay_len: 4,
            total_transitions: 4,
            policy: crate::coordinator::ReplayPolicyKind::Uniform,
            merge: crate::coordinator::MergeMode::Weights,
            occupancy,
            generations: 0,
            staleness: [0; 8],
            lr_schedule: crate::coordinator::HubLrSchedule::Constant,
            hub_steps: 1,
            digest: 0x77,
        };
        let mut sync = report(&[(100.0, 80.0)]);
        sync.hub = Some(hub);
        // Inactive extensions: the PR 8 JSON shape, no new keys.
        assert!(sync.to_json().at(&["hub", "generations"]).is_err());
        assert!(sync.to_json().at(&["hub", "digest"]).is_ok());
        // Active: fingerprint splits and the keys appear.
        let mut async_run = sync.clone();
        {
            let h = async_run.hub.as_mut().unwrap();
            h.generations = 4;
            h.staleness = [2, 1, 1, 0, 0, 0, 0, 0];
        }
        assert_ne!(sync.fingerprint(), async_run.fingerprint());
        let j = async_run.to_json();
        assert_eq!(j.at(&["hub", "generations"]).unwrap().as_usize().unwrap(), 4);
        assert_eq!(
            j.at(&["hub", "staleness_histogram"]).unwrap().as_arr().unwrap().len(),
            8
        );
        // Two async runs differing only in observed staleness differ.
        let mut other = async_run.clone();
        other.hub.as_mut().unwrap().staleness = [4, 0, 0, 0, 0, 0, 0, 0];
        assert_ne!(async_run.fingerprint(), other.fingerprint());
        // A scheduled hub optimizer alone also activates the gate.
        let mut scheduled = sync.clone();
        scheduled.hub.as_mut().unwrap().lr_schedule =
            crate::coordinator::HubLrSchedule::InvSqrt { period: 50 };
        assert_ne!(sync.fingerprint(), scheduled.fingerprint());
        assert_eq!(
            scheduled.to_json().at(&["hub", "hub_lr_schedule"]).unwrap().as_str().unwrap(),
            "invsqrt:50"
        );
        // The streaming accumulator folds the same gated sequence.
        let mut acc = ReportAccumulator::new();
        for jr in &async_run.results {
            acc.push(jr);
        }
        let sp = acc.finish(async_run.wall_clock, async_run.workers, async_run.hub);
        assert_eq!(sp.fingerprint(), async_run.fingerprint());
    }

    #[test]
    fn json_shape() {
        let r = report(&[(100.0, 80.0)]);
        let j = r.to_json();
        assert_eq!(j.at(&["workers"]).unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.at(&["jobs"]).unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn accumulator_matches_in_memory_fingerprint_and_summaries() {
        let mut r = report(&[(100.0, 80.0), (90.0, 70.0), (0.0, 5.0)]);
        let mut occupancy = [0usize; WorkloadKind::COUNT];
        occupancy[WorkloadKind::Icar.ordinal()] = 6;
        r.hub = Some(crate::coordinator::HubSummary {
            merges: 2,
            replay_len: 6,
            total_transitions: 6,
            policy: crate::coordinator::ReplayPolicyKind::Prioritized,
            merge: crate::coordinator::MergeMode::Weights,
            occupancy,
            generations: 0,
            staleness: [0; 8],
            lr_schedule: crate::coordinator::HubLrSchedule::Constant,
            hub_steps: 1,
            digest: 0x1234,
        });
        let mut acc = ReportAccumulator::new();
        for jr in &r.results {
            acc.push(jr);
        }
        let sp = acc.finish(r.wall_clock, r.workers, r.hub.clone());
        assert_eq!(sp.fingerprint(), r.fingerprint());
        assert_eq!(sp.total_app_runs(), r.total_app_runs());
        assert_eq!(sp.improvements(), r.improvements());
        assert_eq!(sp.geomean_speedup().to_bits(), r.geomean_speedup().to_bits());
        assert_eq!(sp.improvement_summary().mean, r.improvement_summary().mean);
        // The degenerate-reference guard carried over to JobRow.
        assert_eq!(sp.rows[2].improvement(), 0.0);
    }

    #[test]
    fn accumulator_without_hub_matches_too() {
        let r = report(&[(100.0, 80.0)]);
        let mut acc = ReportAccumulator::new();
        for jr in &r.results {
            acc.push(jr);
        }
        assert_eq!(acc.len(), 1);
        let sp = acc.finish(r.wall_clock, r.workers, None);
        assert_eq!(sp.fingerprint(), r.fingerprint());
    }
}
