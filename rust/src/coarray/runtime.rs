//! Lowering CAF programs to `simmpi` operations (the LIBCAF_MPI role).
//!
//! Key ABI decisions mirrored from OpenCoarrays' MPI transport:
//!
//! * a CAF **put** is a non-blocking `MPI_Put`; remote completion is
//!   deferred to the next flush/sync (`eager_flush` forces a flush right
//!   after every put instead — the conservative pre-3.x behaviour);
//! * a CAF **get** is blocking (`MPI_Get` + `MPI_Win_flush`);
//! * **`sync all`** is `MPI_Win_flush_all` + barrier;
//! * **`sync images(j)`** is flush(j) + event exchange with `j`;
//! * **events** lower to small eager puts with target-side counting.

use super::program::{CafOp, CafProgram};
use crate::simmpi::{Op, Program};

/// Lowering options (ablation knobs for the runtime itself).
#[derive(Debug, Clone)]
pub struct RuntimeOptions {
    /// Flush after every put (disables communication/computation
    /// overlap; matches early LIBCAF_MPI). Default off.
    pub eager_flush: bool,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        RuntimeOptions { eager_flush: false }
    }
}

/// Lower one image's CAF program to simulator ops.
pub fn lower(prog: &CafProgram, opts: &RuntimeOptions) -> Program {
    let rank = |image: usize| image - 1; // Fortran 1-based -> rank
    let mut out = Vec::with_capacity(prog.ops.len() + 8);
    for op in &prog.ops {
        match *op {
            CafOp::Compute { us } => out.push(Op::Compute { us }),
            CafOp::Put { image, bytes } => {
                out.push(Op::Put { target: rank(image), bytes });
                if opts.eager_flush {
                    out.push(Op::Flush { target: rank(image) });
                }
            }
            CafOp::Get { image, bytes } => out.push(Op::Get { source: rank(image), bytes }),
            CafOp::SyncAll => out.push(Op::SyncAll),
            CafOp::SyncImages { image } => {
                // Pairwise: complete my puts to j, tell j, wait for j.
                out.push(Op::Flush { target: rank(image) });
                out.push(Op::EventPost { target: rank(image) });
                out.push(Op::EventWait { count: 1 });
            }
            CafOp::EventPost { image } => out.push(Op::EventPost { target: rank(image) }),
            CafOp::EventWait { count } => out.push(Op::EventWait { count }),
            CafOp::CoSum { bytes } => out.push(Op::CoSum { bytes }),
            CafOp::CoBroadcast { bytes } => out.push(Op::CoBroadcast { bytes }),
            CafOp::Flush { image } => out.push(Op::Flush { target: rank(image) }),
            CafOp::SyncTeam { team, size } => out.push(Op::TeamBarrier { team, size }),
            CafOp::TeamCoSum { team, size, bytes } => {
                out.push(Op::TeamCoSum { team, size, bytes })
            }
        }
    }
    out
}

/// Lower a whole team; panics if programs disagree on team size or an
/// image is missing (every rank must have exactly one program).
pub fn lower_all(progs: &[CafProgram], opts: &RuntimeOptions) -> Vec<Program> {
    assert!(!progs.is_empty(), "empty team");
    let n = progs[0].num_images;
    assert!(
        progs.iter().all(|p| p.num_images == n),
        "inconsistent num_images across programs"
    );
    assert_eq!(progs.len(), n, "need one program per image");
    let mut seen = vec![false; n];
    for p in progs {
        assert!(!seen[p.image - 1], "duplicate program for image {}", p.image);
        seen[p.image - 1] = true;
    }
    let mut by_rank: Vec<&CafProgram> = progs.iter().collect();
    by_rank.sort_by_key(|p| p.image);
    by_rank.iter().map(|p| lower(p, opts)).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::CvarSet;
    use crate::simmpi::{Engine, Machine, SimConfig};

    fn team2() -> Vec<CafProgram> {
        let mut a = CafProgram::new(1, 2);
        a.compute(10.0).put(2, 2048).sync_all();
        let mut b = CafProgram::new(2, 2);
        b.compute(12.0).sync_all();
        vec![a, b]
    }

    #[test]
    fn put_lowers_nonblocking_by_default() {
        let ops = lower(&team2()[0], &RuntimeOptions::default());
        assert_eq!(
            ops,
            vec![
                Op::Compute { us: 10.0 },
                Op::Put { target: 1, bytes: 2048 },
                Op::SyncAll
            ]
        );
    }

    #[test]
    fn eager_flush_inserts_flushes() {
        let ops = lower(&team2()[0], &RuntimeOptions { eager_flush: true });
        assert!(ops.contains(&Op::Flush { target: 1 }));
    }

    #[test]
    fn sync_images_is_flush_post_wait() {
        let mut p = CafProgram::new(1, 2);
        p.sync_images(2);
        let ops = lower(&p, &RuntimeOptions::default());
        assert_eq!(
            ops,
            vec![
                Op::Flush { target: 1 },
                Op::EventPost { target: 1 },
                Op::EventWait { count: 1 }
            ]
        );
    }

    #[test]
    fn lowered_team_actually_runs() {
        let progs = lower_all(&team2(), &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 2);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, progs).run();
        assert!(stats.total_time_us > 10.0);
        assert_eq!(stats.eager_msgs, 1);
    }

    #[test]
    #[should_panic(expected = "one program per image")]
    fn lower_all_requires_full_team() {
        let progs = vec![CafProgram::new(1, 2)];
        lower_all(&progs, &RuntimeOptions::default());
    }

    #[test]
    fn teams_partition_synchronization() {
        // 4 images in two teams of 2: each team syncs and reduces
        // independently; a fast team must not wait for a slow one.
        let mut progs = Vec::new();
        for img in 1..=4usize {
            let team = if img <= 2 { 1 } else { 2 };
            let mut p = CafProgram::new(img, 4);
            // team 2 computes 10x longer
            p.compute(if team == 1 { 100.0 } else { 1000.0 });
            p.sync_team(team, 2);
            p.team_co_sum(team, 2, 64);
            progs.push(p);
        }
        let lowered = lower_all(&progs, &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 4);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, lowered).run();
        // Total bounded by the slow team, not 2x it (teams independent).
        assert!(stats.total_time_us >= 1000.0);
        assert!(stats.total_time_us < 1200.0, "teams must not serialize: {}", stats.total_time_us);
    }

    #[test]
    fn pairwise_sync_completes_in_sim() {
        // sync images between both images must not deadlock.
        let mut a = CafProgram::new(1, 2);
        a.put(2, 4096).sync_images(2);
        let mut b = CafProgram::new(2, 2);
        b.sync_images(1);
        let progs = lower_all(&[a, b], &RuntimeOptions::default());
        let mut cfg = SimConfig::new(Machine::cheyenne(), CvarSet::vanilla(), 2);
        cfg.noise = 0.0;
        let stats = Engine::new(cfg, progs).run();
        assert_eq!(stats.events_processed, 2);
    }
}
