//! Coarray Fortran program surface: what a CAF workload expresses.
//!
//! Image indices are **1-based** as in Fortran (`this_image()`,
//! `num_images()`); lowering converts to 0-based ranks.

/// One CAF statement in an image's execution trace.
#[derive(Debug, Clone, PartialEq)]
pub enum CafOp {
    /// Local work for `us` microseconds.
    Compute { us: f64 },
    /// `a(...)[img] = ...` — one-sided put of `bytes` to image `img`.
    Put { image: usize, bytes: u64 },
    /// `... = a(...)[img]` — one-sided get of `bytes` from image `img`.
    Get { image: usize, bytes: u64 },
    /// `sync all`.
    SyncAll,
    /// `sync images(img)` approximated as flush + pairwise events.
    SyncImages { image: usize },
    /// `event post(ev[img])`.
    EventPost { image: usize },
    /// `event wait(ev, until_count=n)`.
    EventWait { count: u32 },
    /// `co_sum(x)` with `bytes` per image.
    CoSum { bytes: u64 },
    /// `co_broadcast(x, source_image=1)`.
    CoBroadcast { bytes: u64 },
    /// Explicit `flush` of outstanding puts to one image (the ABI emits
    /// these around remote-completion points).
    Flush { image: usize },
    /// `sync team` — barrier over the images sharing `team`
    /// (Fortran 2018 teams; OpenCoarrays ships a partial
    /// implementation, §4.2). `size` is the team's member count.
    SyncTeam { team: u32, size: u32 },
    /// `co_sum` scoped to the current team.
    TeamCoSum { team: u32, size: u32, bytes: u64 },
}

/// An image's whole program plus its identity.
#[derive(Debug, Clone)]
pub struct CafProgram {
    /// 1-based image index.
    pub image: usize,
    /// Total images in the team.
    pub num_images: usize,
    pub ops: Vec<CafOp>,
}

impl CafProgram {
    pub fn new(image: usize, num_images: usize) -> CafProgram {
        assert!((1..=num_images).contains(&image), "image {image} of {num_images}");
        CafProgram { image, num_images, ops: Vec::new() }
    }

    // Builder helpers so workloads read like CAF pseudocode.

    pub fn compute(&mut self, us: f64) -> &mut Self {
        self.ops.push(CafOp::Compute { us });
        self
    }

    pub fn put(&mut self, image: usize, bytes: u64) -> &mut Self {
        self.check_image(image);
        self.ops.push(CafOp::Put { image, bytes });
        self
    }

    pub fn get(&mut self, image: usize, bytes: u64) -> &mut Self {
        self.check_image(image);
        self.ops.push(CafOp::Get { image, bytes });
        self
    }

    pub fn sync_all(&mut self) -> &mut Self {
        self.ops.push(CafOp::SyncAll);
        self
    }

    pub fn sync_images(&mut self, image: usize) -> &mut Self {
        self.check_image(image);
        self.ops.push(CafOp::SyncImages { image });
        self
    }

    pub fn event_post(&mut self, image: usize) -> &mut Self {
        self.check_image(image);
        self.ops.push(CafOp::EventPost { image });
        self
    }

    pub fn event_wait(&mut self, count: u32) -> &mut Self {
        self.ops.push(CafOp::EventWait { count });
        self
    }

    pub fn co_sum(&mut self, bytes: u64) -> &mut Self {
        self.ops.push(CafOp::CoSum { bytes });
        self
    }

    pub fn co_broadcast(&mut self, bytes: u64) -> &mut Self {
        self.ops.push(CafOp::CoBroadcast { bytes });
        self
    }

    pub fn flush(&mut self, image: usize) -> &mut Self {
        self.check_image(image);
        self.ops.push(CafOp::Flush { image });
        self
    }

    pub fn sync_team(&mut self, team: u32, size: u32) -> &mut Self {
        assert!(size as usize <= self.num_images, "team larger than world");
        self.ops.push(CafOp::SyncTeam { team, size });
        self
    }

    pub fn team_co_sum(&mut self, team: u32, size: u32, bytes: u64) -> &mut Self {
        assert!(size as usize <= self.num_images, "team larger than world");
        self.ops.push(CafOp::TeamCoSum { team, size, bytes });
        self
    }

    fn check_image(&self, image: usize) {
        assert!(
            (1..=self.num_images).contains(&image),
            "remote image {image} out of range 1..={}",
            self.num_images
        );
        assert_ne!(image, self.image, "self-communication not modeled");
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let mut p = CafProgram::new(1, 4);
        p.compute(10.0).put(2, 1024).sync_all();
        assert_eq!(p.ops.len(), 3);
        assert_eq!(p.ops[1], CafOp::Put { image: 2, bytes: 1024 });
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_image() {
        CafProgram::new(1, 4).put(5, 10);
    }

    #[test]
    #[should_panic(expected = "self-communication")]
    fn rejects_self_put() {
        CafProgram::new(2, 4).put(2, 10);
    }
}
