//! `coarray` — an OpenCoarrays-style runtime ABI over `simmpi`.
//!
//! OpenCoarrays (§4.2) defines an ABI translating coarray Fortran's
//! high-level communication/synchronization into calls to a transport
//! (LIBCAF_MPI uses MPI-3 passive-target RMA almost exclusively). This
//! module reproduces that shape: workloads author per-image programs
//! against the CAF surface ([`CafProgram`]), and [`runtime`] lowers them
//! to `simmpi` one-sided operations, mirroring LIBCAF_MPI's choices
//! (puts are non-blocking until a flush/sync; gets are blocking;
//! `sync all` is flush_all + barrier; events map to tiny eager sends).
//!
//! The lowering is where the PMPI interposition hooks observe traffic —
//! AITuning never needs the workload's source, exactly as in the paper.

pub mod program;
pub mod runtime;

pub use program::{CafOp, CafProgram};
pub use runtime::{lower, lower_all, RuntimeOptions};
