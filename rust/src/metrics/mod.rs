//! Run statistics, summaries and experiment recording.

pub mod recorder;
pub mod stats;

pub use recorder::{RunRecord, TuningLog};
pub use stats::Summary;
