//! Experiment recording: per-run records and tuning logs, exportable as
//! JSON (for EXPERIMENTS.md) or CSV.

use crate::mpi_t::{CvarSet, PvarStats};
use crate::util::json::{arr, num, obj, s, Json};

/// Everything recorded about one application run during tuning.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub run_index: usize,
    pub cvars: CvarSet,
    pub total_time_us: f64,
    pub reward: f64,
    pub action: Option<usize>,
    pub epsilon: f64,
    pub pvars: PvarStats,
}

/// Accumulated log of one tuning campaign.
#[derive(Debug, Default, Clone)]
pub struct TuningLog {
    pub workload: String,
    pub images: usize,
    pub runs: Vec<RunRecord>,
}

impl TuningLog {
    pub fn new(workload: &str, images: usize) -> TuningLog {
        TuningLog { workload: workload.to_string(), images, runs: Vec::new() }
    }

    pub fn push(&mut self, rec: RunRecord) {
        self.runs.push(rec);
    }

    pub fn best_run(&self) -> Option<&RunRecord> {
        self.runs
            .iter()
            .min_by(|a, b| a.total_time_us.total_cmp(&b.total_time_us))
    }

    /// Reference (first) run time, if any.
    pub fn reference_time_us(&self) -> Option<f64> {
        self.runs.first().map(|r| r.total_time_us)
    }

    /// Relative improvement of the best run over the reference.
    pub fn best_improvement(&self) -> Option<f64> {
        let reference = self.reference_time_us()?;
        let best = self.best_run()?.total_time_us;
        Some((reference - best) / reference)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("workload", s(&self.workload)),
            ("images", num(self.images as f64)),
            (
                "runs",
                arr(self.runs.iter().map(|r| {
                    obj(vec![
                        ("run", num(r.run_index as f64)),
                        ("total_time_us", num(r.total_time_us)),
                        ("reward", num(r.reward)),
                        ("epsilon", num(r.epsilon)),
                        (
                            "action",
                            r.action.map(|a| num(a as f64)).unwrap_or(Json::Null),
                        ),
                        ("cvars", s(&r.cvars.to_string())),
                    ])
                })),
            ),
        ])
    }

    /// CSV rows: run,total_time_us,reward,action,epsilon
    pub fn to_csv(&self) -> String {
        let mut out = String::from("run,total_time_us,reward,action,epsilon\n");
        for r in &self.runs {
            out.push_str(&format!(
                "{},{:.3},{:.6},{},{:.4}\n",
                r.run_index,
                r.total_time_us,
                r.reward,
                r.action.map(|a| a.to_string()).unwrap_or_default(),
                r.epsilon
            ));
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn rec(i: usize, t: f64) -> RunRecord {
        RunRecord {
            run_index: i,
            cvars: CvarSet::vanilla(),
            total_time_us: t,
            reward: 0.0,
            action: Some(1),
            epsilon: 0.5,
            pvars: PvarStats::default(),
        }
    }

    #[test]
    fn best_and_improvement() {
        let mut log = TuningLog::new("icar", 256);
        log.push(rec(0, 100.0));
        log.push(rec(1, 80.0));
        log.push(rec(2, 90.0));
        assert_eq!(log.best_run().unwrap().run_index, 1);
        assert!((log.best_improvement().unwrap() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn json_and_csv_shapes() {
        let mut log = TuningLog::new("icar", 256);
        log.push(rec(0, 100.0));
        let j = log.to_json();
        assert_eq!(j.at(&["images"]).unwrap().as_usize().unwrap(), 256);
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("run,"));
    }

    #[test]
    fn empty_log_has_no_best() {
        let log = TuningLog::new("x", 1);
        assert!(log.best_run().is_none());
        assert!(log.best_improvement().is_none());
    }
}
