//! Scalar summary statistics (avg, max, min, median, std, count).
//!
//! The paper collects "statistics of the values ... (e.g. average, max,
//! min, median)" at `MPI_Finalize` time (§5.1); this is that summary.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub max: f64,
    pub min: f64,
    pub median: f64,
    pub std: f64,
}

impl Default for Summary {
    fn default() -> Self {
        Summary { count: 0, mean: 0.0, max: 0.0, min: 0.0, median: 0.0, std: 0.0 }
    }
}

impl Summary {
    /// Summarize a sample; empty samples give the zero summary.
    ///
    /// `std` is the *sample* standard deviation (Bessel-corrected,
    /// `/ (n - 1)`): per-run pvar samples are small, and the population
    /// form systematically understated the spread in the state features
    /// fed to the agent. A single observation has no spread estimate and
    /// reports 0.0.
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary::default();
        }
        let n = values.len() as f64;
        let mean = values.iter().sum::<f64>() / n;
        let var = if values.len() < 2 {
            0.0
        } else {
            values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1.0)
        };
        let mut sorted: Vec<f64> = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if sorted.len() % 2 == 1 {
            sorted[sorted.len() / 2]
        } else {
            0.5 * (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2])
        };
        Summary {
            count: values.len(),
            mean,
            max: sorted[sorted.len() - 1],
            min: sorted[0],
            median,
            std: var.sqrt(),
        }
    }
}

/// Median of an integer sample (used by ensemble inference, §5.4).
///
/// For an even-length sample this returns the **lower** of the two
/// middle elements — never a midpoint average. The callers feed cvar
/// values through here, and averaging two legal cvar settings can
/// fabricate a value no run ever executed (e.g. a power-of-two eager
/// threshold halfway between two tested thresholds); `Summary::of`
/// keeps the averaged even median because f64 metrics have no such
/// legality constraint. (The previous `values[len / 2]` took the
/// *upper* middle, so even-sized §5.4 ensembles systematically shipped
/// the larger cvar value.)
pub fn median_i64(values: &mut Vec<i64>) -> i64 {
    assert!(!values.is_empty(), "median of empty sample");
    values.sort_unstable();
    values[(values.len() - 1) / 2]
}

/// Geometric mean (used for cross-workload campaign reporting).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    (values.iter().map(|v| v.max(1e-300).ln()).sum::<f64>() / values.len() as f64).exp()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.count, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.median, 2.5);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // Sample (Bessel-corrected) std: var = 5/3 for this sample.
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn single_observation_has_zero_std() {
        // n == 1 carries no spread information; with Bessel's n - 1
        // divisor it must report 0.0, not NaN.
        let s = Summary::of(&[42.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 42.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(Summary::of(&[1.0, 2.0, 9.0]).median, 2.0);
        assert_eq!(median_i64(&mut vec![5, 1, 3]), 3);
        // Even length: f64 summaries average the middles; the integer
        // median takes the LOWER middle (an observed value, never a
        // fabricated midpoint).
        assert_eq!(Summary::of(&[1.0, 2.0, 3.0, 9.0]).median, 2.5);
        assert_eq!(median_i64(&mut vec![9, 1, 3, 2]), 2);
        assert_eq!(median_i64(&mut vec![7, 7]), 7);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.std, 0.0);
    }
}
