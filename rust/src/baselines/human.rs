//! The paper's human baseline (§6.2): an expert's "reasonable guess" —
//! raise the eager/rendezvous threshold by an order of magnitude, leave
//! everything else at defaults.

use crate::mpi_t::{CvarId, CvarSet, MPICH_CVARS};

/// The manually-optimized configuration from the paper's Figure 1.
pub fn human_tuned() -> CvarSet {
    let mut cv = CvarSet::vanilla();
    let default_eager = MPICH_CVARS[5].default;
    cv.set(CvarId(5), default_eager * 10);
    cv
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn eager_limit_is_10x_default() {
        let cv = human_tuned();
        assert_eq!(cv.eager_max(), 1_310_720);
        // everything else untouched
        assert!(!cv.async_progress());
        assert_eq!(cv.polls_before_yield(), 1000);
    }
}
