//! Tuning baselines AITuning is compared against.
//!
//! * [`human`] — the paper's §6.2 manual tuning: "increased the eager
//!   limit by an order of magnitude higher than the default while
//!   leaving all the other settings as in the default configuration";
//! * [`RandomSearch`] — same run budget, uniformly random configs;
//! * [`Evolutionary`] — a (µ+λ) mutation/selection loop in the spirit of
//!   the AutoTune/PTF related work (§2, Sikora et al.);
//! * [`grid_search`] — exhaustive over a coarse grid (ground truth for
//!   small studies; exponential, use sparingly).

mod evolutionary;
mod human;
mod random;

pub use evolutionary::Evolutionary;
pub use human::human_tuned;
pub use random::{grid_search, RandomSearch};

use anyhow::Result;

use crate::mpi_t::CvarSet;

/// A fixed-budget configuration searcher (the baseline interface).
pub trait Searcher {
    fn name(&self) -> &'static str;

    /// Spend `budget` evaluations through `eval` and return the best
    /// configuration found and its measured time.
    fn search(
        &mut self,
        budget: usize,
        eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
    ) -> Result<(CvarSet, f64)>;
}
