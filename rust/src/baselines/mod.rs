//! Tuning baselines AITuning is compared against.
//!
//! * [`human`] — the paper's §6.2 manual tuning: "increased the eager
//!   limit by an order of magnitude higher than the default while
//!   leaving all the other settings as in the default configuration";
//! * [`RandomSearch`] — same run budget, uniformly random configs;
//! * [`Evolutionary`] — a (µ+λ) mutation/selection loop in the spirit of
//!   the AutoTune/PTF related work (§2, Sikora et al.);
//! * [`grid_search`] — exhaustive over a coarse grid (ground truth for
//!   small studies; exponential, use sparingly).

mod evolutionary;
mod human;
mod random;

pub use evolutionary::Evolutionary;
pub use human::human_tuned;
pub use random::{grid_search, grid_search_batched, grid_search_batched_for, RandomSearch};

use anyhow::Result;

use crate::mpi_t::CvarSet;

/// A fixed-budget configuration searcher (the baseline interface).
pub trait Searcher {
    fn name(&self) -> &'static str;

    /// Spend `budget` evaluations through `eval` and return the best
    /// configuration found and its measured time.
    fn search(
        &mut self,
        budget: usize,
        eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
    ) -> Result<(CvarSet, f64)>;

    /// Batched variant: `eval_batch` scores a slice of candidates at
    /// once (the campaign engine fans it across worker threads) and
    /// returns one time per candidate, in order.
    ///
    /// Searchers whose candidate generation does not depend on earlier
    /// scores within a batch override this to expose real batches
    /// (random search: the whole budget; evolutionary: one generation);
    /// the default degrades to one-at-a-time scoring and matches
    /// [`Searcher::search`] exactly.
    fn search_batched(
        &mut self,
        budget: usize,
        eval_batch: &mut dyn FnMut(&[CvarSet]) -> Result<Vec<f64>>,
    ) -> Result<(CvarSet, f64)> {
        let mut eval = |cv: &CvarSet| {
            let times = eval_batch(std::slice::from_ref(cv))?;
            check_batch_len(times.len(), 1)?;
            Ok(times[0])
        };
        self.search(budget, &mut eval)
    }
}

/// Check an `eval_batch` reply length (shared by the implementations).
pub(crate) fn check_batch_len(got: usize, want: usize) -> Result<()> {
    anyhow::ensure!(got == want, "eval_batch returned {got} times for {want} configs");
    Ok(())
}

/// Index of the smallest time, first on ties — the shared winner rule
/// that keeps every batched search path identical to its serial twin.
pub(crate) fn argmin(times: &[f64]) -> usize {
    let mut best = 0;
    for (i, &t) in times.iter().enumerate().skip(1) {
        if t < times[best] {
            best = i;
        }
    }
    best
}
