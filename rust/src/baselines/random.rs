//! Random search and coarse grid search baselines.

use anyhow::Result;

use crate::mpi_t::{CvarDomain, CvarId, CvarSet, MPICH_CVARS};
use crate::util::rng::Rng;

use super::Searcher;

/// Uniform random sampling over the full cvar space.
pub struct RandomSearch {
    rng: Rng,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch { rng: Rng::new(seed) }
    }

    /// One uniformly random configuration.
    pub fn sample(&mut self) -> CvarSet {
        let mut cv = CvarSet::vanilla();
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            let v = match d.domain {
                CvarDomain::Bool => self.rng.range_i64(0, 1),
                CvarDomain::Int { lo, hi, step } => {
                    let steps = (hi - lo) / step;
                    lo + self.rng.range_i64(0, steps) * step
                }
            };
            cv.set(CvarId(i), v);
        }
        cv
    }
}

impl Searcher for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn search(
        &mut self,
        budget: usize,
        eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
    ) -> Result<(CvarSet, f64)> {
        // First evaluation is always vanilla (same protocol as AITuning:
        // the reference run counts against the budget).
        let mut best = CvarSet::vanilla();
        let mut best_t = eval(&best)?;
        for _ in 1..budget {
            let cand = self.sample();
            let t = eval(&cand)?;
            if t < best_t {
                best = cand;
                best_t = t;
            }
        }
        Ok((best, best_t))
    }
}

/// Exhaustive search over a coarse grid: booleans × a few levels of each
/// integer cvar. Exponential — intended for ground-truthing small
/// studies, not production tuning.
pub fn grid_search(
    levels: usize,
    eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
) -> Result<(CvarSet, f64)> {
    assert!(levels >= 2, "need at least lo/hi levels");
    let mut axes: Vec<Vec<i64>> = Vec::new();
    for d in MPICH_CVARS {
        match d.domain {
            CvarDomain::Bool => axes.push(vec![0, 1]),
            CvarDomain::Int { lo, hi, .. } => {
                let mut vals = Vec::with_capacity(levels);
                for k in 0..levels {
                    let f = k as f64 / (levels - 1) as f64;
                    vals.push(lo + ((hi - lo) as f64 * f) as i64);
                }
                axes.push(vals);
            }
        }
    }
    let mut best: Option<(CvarSet, f64)> = None;
    let mut idx = vec![0usize; axes.len()];
    loop {
        let mut cv = CvarSet::vanilla();
        for (c, &i) in idx.iter().enumerate() {
            cv.set(CvarId(c), axes[c][i]);
        }
        let t = eval(&cv)?;
        if best.as_ref().map(|(_, bt)| t < *bt).unwrap_or(true) {
            best = Some((cv, t));
        }
        // odometer increment
        let mut c = 0;
        loop {
            if c == axes.len() {
                return Ok(best.unwrap());
            }
            idx[c] += 1;
            if idx[c] < axes[c].len() {
                break;
            }
            idx[c] = 0;
            c += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_domains() {
        let mut rs = RandomSearch::new(1);
        for _ in 0..100 {
            let cv = rs.sample();
            assert!(cv.eager_max() >= 1024 && cv.eager_max() <= 8 * 1024 * 1024);
            assert!(cv.get(CvarId(0)) <= 1);
        }
    }

    #[test]
    fn search_returns_best_of_budget() {
        let mut rs = RandomSearch::new(2);
        // Score: prefer async progress on.
        let mut eval = |cv: &CvarSet| -> Result<f64> {
            Ok(if cv.async_progress() { 1.0 } else { 2.0 })
        };
        let (best, t) = rs.search(30, &mut eval).unwrap();
        assert!(best.async_progress());
        assert_eq!(t, 1.0);
    }

    #[test]
    fn grid_covers_corners() {
        let mut count = 0usize;
        let mut eval = |cv: &CvarSet| -> Result<f64> {
            count += 1;
            Ok(-(cv.eager_max() as f64)) // prefer max eager
        };
        let (best, _) = grid_search(2, &mut eval).unwrap();
        assert_eq!(count, 2usize.pow(6)); // 6 axes, 2 levels each
        assert_eq!(best.eager_max(), 8 * 1024 * 1024);
    }
}
