//! Random search and coarse grid search baselines.

use anyhow::Result;

use crate::backend::BackendId;
use crate::mpi_t::{CvarDomain, CvarId, CvarSet};
use crate::util::rng::Rng;

use super::Searcher;

/// Uniform random sampling over the full cvar space of one backend.
pub struct RandomSearch {
    rng: Rng,
    backend: BackendId,
}

impl RandomSearch {
    /// Searcher over the coarrays (paper) space.
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch::for_backend(seed, BackendId::Coarrays)
    }

    pub fn for_backend(seed: u64, backend: BackendId) -> RandomSearch {
        RandomSearch { rng: Rng::new(seed), backend }
    }

    /// One uniformly random configuration.
    pub fn sample(&mut self) -> CvarSet {
        let mut cv = CvarSet::defaults(self.backend);
        for (i, d) in self.backend.cvars().iter().enumerate() {
            let v = match d.domain {
                CvarDomain::Bool => self.rng.range_i64(0, 1),
                CvarDomain::Int { lo, hi, step } => {
                    let steps = (hi - lo) / step;
                    lo + self.rng.range_i64(0, steps) * step
                }
                CvarDomain::Choice { options } => {
                    self.rng.range_i64(0, options.len() as i64 - 1)
                }
            };
            cv.set(CvarId(i), v);
        }
        cv
    }
}

impl Searcher for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn search(
        &mut self,
        budget: usize,
        eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
    ) -> Result<(CvarSet, f64)> {
        // First evaluation is always the backend's defaults (same
        // protocol as AITuning: the reference run counts against the
        // budget).
        let mut best = CvarSet::defaults(self.backend);
        let mut best_t = eval(&best)?;
        for _ in 1..budget {
            let cand = self.sample();
            let t = eval(&cand)?;
            if t < best_t {
                best = cand;
                best_t = t;
            }
        }
        Ok((best, best_t))
    }

    /// Random search has no sequential dependency between evaluations,
    /// so the whole budget is one batch: the candidate list (vanilla
    /// first, then `budget - 1` samples in generator order) is built up
    /// front and scored in a single parallel fan-out. Picks the same
    /// winner as the serial path (first minimum on ties).
    fn search_batched(
        &mut self,
        budget: usize,
        eval_batch: &mut dyn FnMut(&[CvarSet]) -> Result<Vec<f64>>,
    ) -> Result<(CvarSet, f64)> {
        let mut candidates = vec![CvarSet::defaults(self.backend)];
        for _ in 1..budget {
            candidates.push(self.sample());
        }
        let times = eval_batch(&candidates)?;
        super::check_batch_len(times.len(), candidates.len())?;
        let best = super::argmin(&times);
        Ok((candidates.swap_remove(best), times[best]))
    }
}

/// Exhaustive search over a coarse grid: booleans × a few levels of each
/// integer cvar. Exponential — intended for ground-truthing small
/// studies, not production tuning.
pub fn grid_search(
    levels: usize,
    eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
) -> Result<(CvarSet, f64)> {
    let mut eval_batch =
        |configs: &[CvarSet]| -> Result<Vec<f64>> { configs.iter().map(&mut *eval).collect() };
    grid_search_batched(levels, &mut eval_batch)
}

/// [`grid_search`] with the grid enumerated up front and scored in one
/// batch, so the campaign engine can fan the (exponential) evaluation
/// across worker threads. Visits grid points in the same odometer order
/// as the serial path and picks the same winner (first minimum).
pub fn grid_search_batched(
    levels: usize,
    eval_batch: &mut dyn FnMut(&[CvarSet]) -> Result<Vec<f64>>,
) -> Result<(CvarSet, f64)> {
    grid_search_batched_for(BackendId::Coarrays, levels, eval_batch)
}

/// Backend-generic grid search (choice cvars enumerate every option).
pub fn grid_search_batched_for(
    backend: BackendId,
    levels: usize,
    eval_batch: &mut dyn FnMut(&[CvarSet]) -> Result<Vec<f64>>,
) -> Result<(CvarSet, f64)> {
    assert!(levels >= 2, "need at least lo/hi levels");
    let mut axes: Vec<Vec<i64>> = Vec::new();
    for d in backend.cvars() {
        match d.domain {
            CvarDomain::Bool => axes.push(vec![0, 1]),
            CvarDomain::Int { lo, hi, .. } => {
                let mut vals = Vec::with_capacity(levels);
                for k in 0..levels {
                    let f = k as f64 / (levels - 1) as f64;
                    vals.push(lo + ((hi - lo) as f64 * f) as i64);
                }
                axes.push(vals);
            }
            CvarDomain::Choice { options } => {
                axes.push((0..options.len() as i64).collect());
            }
        }
    }
    // Enumerate the full grid in odometer order.
    let mut grid = Vec::new();
    let mut idx = vec![0usize; axes.len()];
    'outer: loop {
        let mut cv = CvarSet::defaults(backend);
        for (c, &i) in idx.iter().enumerate() {
            cv.set(CvarId(c), axes[c][i]);
        }
        grid.push(cv);
        let mut c = 0;
        loop {
            if c == axes.len() {
                break 'outer;
            }
            idx[c] += 1;
            if idx[c] < axes[c].len() {
                break;
            }
            idx[c] = 0;
            c += 1;
        }
    }
    let times = eval_batch(&grid)?;
    super::check_batch_len(times.len(), grid.len())?;
    let best = super::argmin(&times);
    Ok((grid.swap_remove(best), times[best]))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn samples_respect_domains() {
        let mut rs = RandomSearch::new(1);
        for _ in 0..100 {
            let cv = rs.sample();
            assert!(cv.eager_max() >= 1024 && cv.eager_max() <= 8 * 1024 * 1024);
            assert!(cv.get(CvarId(0)) <= 1);
        }
    }

    #[test]
    fn search_returns_best_of_budget() {
        let mut rs = RandomSearch::new(2);
        // Score: prefer async progress on.
        let mut eval = |cv: &CvarSet| -> Result<f64> {
            Ok(if cv.async_progress() { 1.0 } else { 2.0 })
        };
        let (best, t) = rs.search(30, &mut eval).unwrap();
        assert!(best.async_progress());
        assert_eq!(t, 1.0);
    }

    #[test]
    fn batched_search_matches_serial() {
        let score =
            |cv: &CvarSet| cv.eager_max() as f64 + if cv.async_progress() { 0.0 } else { 1e9 };
        let mut serial = RandomSearch::new(4);
        let (a, ta) = serial.search(25, &mut |cv: &CvarSet| Ok(score(cv))).unwrap();
        let mut batched = RandomSearch::new(4);
        let mut eval_b =
            |cvs: &[CvarSet]| -> Result<Vec<f64>> { Ok(cvs.iter().map(score).collect()) };
        let (b, tb) = batched.search_batched(25, &mut eval_b).unwrap();
        assert_eq!(a, b);
        assert_eq!(ta, tb);
    }

    #[test]
    fn grid_covers_corners() {
        let mut count = 0usize;
        let mut eval = |cv: &CvarSet| -> Result<f64> {
            count += 1;
            Ok(-(cv.eager_max() as f64)) // prefer max eager
        };
        let (best, _) = grid_search(2, &mut eval).unwrap();
        assert_eq!(count, 2usize.pow(6)); // 6 axes, 2 levels each
        assert_eq!(best.eager_max(), 8 * 1024 * 1024);
    }
}
