//! (µ+λ) evolutionary search — the heuristic family the AutoTune/PTF
//! line of related work uses for MPI parameter tuning (§2).

use anyhow::Result;

use crate::backend::BackendId;
use crate::mpi_t::{CvarDomain, CvarId, CvarSet};
use crate::util::rng::Rng;

use super::random::RandomSearch;
use super::Searcher;

/// (µ+λ) evolutionary searcher with per-gene mutation.
pub struct Evolutionary {
    rng: Rng,
    backend: BackendId,
    /// Parents kept per generation.
    pub mu: usize,
    /// Offspring per generation.
    pub lambda: usize,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
}

impl Evolutionary {
    /// Searcher over the coarrays (paper) space.
    pub fn new(seed: u64) -> Evolutionary {
        Evolutionary::for_backend(seed, BackendId::Coarrays)
    }

    pub fn for_backend(seed: u64, backend: BackendId) -> Evolutionary {
        Evolutionary { rng: Rng::new(seed), backend, mu: 3, lambda: 6, mutation_rate: 0.35 }
    }

    fn mutate(&mut self, parent: &CvarSet) -> CvarSet {
        let mut child = parent.clone();
        for (i, d) in self.backend.cvars().iter().enumerate() {
            if !self.rng.chance(self.mutation_rate) {
                continue;
            }
            let id = CvarId(i);
            let v = match d.domain {
                CvarDomain::Bool => 1 - child.get(id).clamp(0, 1),
                CvarDomain::Int { step, .. } => {
                    // Geometric-ish jump: ±(1..16) steps.
                    let magnitude = 1 << self.rng.range_i64(0, 4);
                    let dir = if self.rng.chance(0.5) { 1 } else { -1 };
                    child.get(id) + dir * magnitude * step
                }
                CvarDomain::Choice { options } => {
                    // Re-draw the option uniformly.
                    self.rng.range_i64(0, options.len() as i64 - 1)
                }
            };
            child.set(id, v); // set() clamps to the domain
        }
        child
    }
}

impl Searcher for Evolutionary {
    fn name(&self) -> &'static str {
        "evolutionary"
    }

    fn search(
        &mut self,
        budget: usize,
        eval: &mut dyn FnMut(&CvarSet) -> Result<f64>,
    ) -> Result<(CvarSet, f64)> {
        let mut spent = 0usize;
        let mut population: Vec<(CvarSet, f64)> = Vec::new();

        // Seed: the backend defaults + random immigrants.
        let vanilla = CvarSet::defaults(self.backend);
        population.push((vanilla.clone(), eval(&vanilla)?));
        spent += 1;
        let mut seeder = RandomSearch::for_backend(self.rng.next_u64(), self.backend);
        while population.len() < self.mu && spent < budget {
            let cand = seeder.sample();
            let t = eval(&cand)?;
            spent += 1;
            population.push((cand, t));
        }

        while spent < budget {
            population.sort_by(|a, b| a.1.total_cmp(&b.1));
            population.truncate(self.mu);
            let n_children = self.lambda.min(budget - spent);
            for k in 0..n_children {
                let parent = population[k % population.len()].0.clone();
                let child = self.mutate(&parent);
                let t = eval(&child)?;
                spent += 1;
                population.push((child, t));
            }
        }
        population.sort_by(|a, b| a.1.total_cmp(&b.1));
        Ok(population.swap_remove(0))
    }

    /// Within one generation no child's score influences another child,
    /// so each generation (and the initial vanilla + immigrant seeding)
    /// is scored as one batch the campaign engine parallelizes.
    /// Candidate generation consumes the mutation RNG in the same order
    /// as [`Searcher::search`], so both paths explore identical
    /// configurations.
    fn search_batched(
        &mut self,
        budget: usize,
        eval_batch: &mut dyn FnMut(&[CvarSet]) -> Result<Vec<f64>>,
    ) -> Result<(CvarSet, f64)> {
        let mut spent = 0usize;
        let mut population: Vec<(CvarSet, f64)> = Vec::new();

        // Seed generation: defaults + random immigrants, one batch.
        let mut seeds = vec![CvarSet::defaults(self.backend)];
        let mut seeder = RandomSearch::for_backend(self.rng.next_u64(), self.backend);
        while seeds.len() < self.mu && seeds.len() < budget {
            seeds.push(seeder.sample());
        }
        let times = eval_batch(&seeds)?;
        super::check_batch_len(times.len(), seeds.len())?;
        spent += seeds.len();
        population.extend(seeds.into_iter().zip(times));

        while spent < budget {
            population.sort_by(|a, b| a.1.total_cmp(&b.1));
            population.truncate(self.mu);
            let n_children = self.lambda.min(budget - spent);
            let mut children: Vec<CvarSet> = Vec::with_capacity(n_children);
            for k in 0..n_children {
                // Mirror the serial path exactly: there the population
                // grows by one per child, so parent k indexes into
                // parents *plus the children generated so far*.
                let idx = k % (population.len() + k);
                let parent = if idx < population.len() {
                    population[idx].0.clone()
                } else {
                    children[idx - population.len()].clone()
                };
                children.push(self.mutate(&parent));
            }
            let times = eval_batch(&children)?;
            super::check_batch_len(times.len(), children.len())?;
            spent += children.len();
            population.extend(children.into_iter().zip(times));
        }
        population.sort_by(|a, b| a.1.total_cmp(&b.1));
        Ok(population.swap_remove(0))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn respects_budget_exactly() {
        let mut evo = Evolutionary::new(5);
        let mut count = 0usize;
        let mut eval = |_: &CvarSet| -> Result<f64> {
            count += 1;
            Ok(count as f64)
        };
        evo.search(20, &mut eval).unwrap();
        assert_eq!(count, 20);
    }

    #[test]
    fn finds_async_progress_on_separable_objective() {
        let mut evo = Evolutionary::new(7);
        let mut eval = |cv: &CvarSet| -> Result<f64> {
            let mut t = 100.0;
            if cv.async_progress() {
                t -= 30.0;
            }
            t += (cv.eager_max() as f64 - 1_000_000.0).abs() / 1e6;
            Ok(t)
        };
        let (best, _) = evo.search(60, &mut eval).unwrap();
        assert!(best.async_progress());
    }

    #[test]
    fn batched_search_matches_serial() {
        let score = |cv: &CvarSet| {
            let mut t = 100.0;
            if cv.async_progress() {
                t -= 30.0;
            }
            t + (cv.eager_max() as f64 - 1_000_000.0).abs() / 1e6
        };
        let mut serial = Evolutionary::new(21);
        let (a, ta) = serial.search(40, &mut |cv: &CvarSet| Ok(score(cv))).unwrap();
        let mut batched = Evolutionary::new(21);
        let mut eval_b =
            |cvs: &[CvarSet]| -> Result<Vec<f64>> { Ok(cvs.iter().map(score).collect()) };
        let (b, tb) = batched.search_batched(40, &mut eval_b).unwrap();
        assert_eq!(a, b);
        assert_eq!(ta, tb);
    }

    #[test]
    fn mutation_stays_in_domain() {
        let mut evo = Evolutionary::new(9);
        let mut cv = CvarSet::vanilla();
        for _ in 0..200 {
            cv = evo.mutate(&cv);
            assert!(cv.eager_max() >= 1024 && cv.eager_max() <= 8 * 1024 * 1024);
            assert!(cv.piggyback_size() >= 0 && cv.piggyback_size() <= 262_144);
        }
    }
}
