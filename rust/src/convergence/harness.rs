//! The §5.5 convergence harness: run the exact same RL machinery
//! (state/action/reward/replay/agent) against the synthetic models and
//! measure how close the final configuration is to the known best.

use anyhow::Result;

use crate::backend::BackendId;
use crate::coordinator::{
    actions::Action, Agent, AgentKind, DqnAgent, ReplayBuffer, TabularAgent, Transition,
    NUM_ACTIONS, STATE_DIM,
};
use crate::mpi_t::{CvarSet, MPICH_CVARS};
use crate::util::rng::Rng;

use super::models::SyntheticModel;

/// Configuration of one convergence simulation.
#[derive(Debug, Clone)]
pub struct ConvergenceConfig {
    pub agent: AgentKind,
    /// Tuning runs (the paper uses longer horizons here than the 20-run
    /// inference recipe — this is a stress test of the learner itself).
    pub runs: usize,
    /// Gaussian noise level (fraction; paper up to 0.30).
    pub noise: f64,
    pub eps_start: f64,
    pub eps_end: f64,
    pub gamma: f32,
    pub lr: f32,
    pub seed: u64,
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for ConvergenceConfig {
    fn default() -> ConvergenceConfig {
        ConvergenceConfig {
            agent: AgentKind::Tabular,
            runs: 150,
            noise: 0.0,
            eps_start: 0.9,
            eps_end: 0.05,
            gamma: 0.9,
            lr: 2e-3,
            seed: 0,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }
}

/// Outcome of one convergence simulation.
#[derive(Debug, Clone)]
pub struct ConvergenceReport {
    /// Final configuration after the run budget.
    pub final_cvars: CvarSet,
    /// Best configuration seen.
    pub best_cvars: CvarSet,
    /// Normalized distance of best config to the model's known optimum.
    pub best_distance: f64,
    /// Best observed mean-time ratio vs the model's optimal time.
    pub best_ratio: f64,
    /// Observed times per run.
    pub trajectory: Vec<f64>,
}

/// Build the state vector from a synthetic observation.
fn synth_state(
    total: f64,
    reference: f64,
    aux: &[f64],
    cvars: &CvarSet,
    run: usize,
) -> Vec<f32> {
    let mut s = vec![0.0f32; STATE_DIM];
    s[0] = (aux.first().copied().unwrap_or(0.0) as f32).clamp(-5.0, 5.0);
    s[1] = (aux.get(1).copied().unwrap_or(0.0) as f32 / 10.0).clamp(-5.0, 5.0);
    s[8] = (((reference - total) / reference) as f32).clamp(-2.0, 2.0);
    s[9] = 0.5;
    s[10..16].copy_from_slice(&cvars.normalized());
    s[16] = (run as f32 / 100.0).min(2.0);
    s
}

/// Run one convergence simulation.
pub fn run_convergence(
    model: &SyntheticModel,
    cfg: &ConvergenceConfig,
) -> Result<ConvergenceReport> {
    let mut rng = Rng::new(cfg.seed);
    let mut agent: Box<dyn Agent> = match cfg.agent {
        AgentKind::Dqn => Box::new(DqnAgent::native(BackendId::Coarrays, &mut rng)),
        AgentKind::DqnAot => {
            Box::new(DqnAgent::load(&cfg.artifacts_dir, &mut rng, BackendId::Coarrays)?)
        }
        AgentKind::DqnTarget => Box::new(DqnAgent::load_with_mode(
            &cfg.artifacts_dir,
            &mut rng,
            true,
            BackendId::Coarrays,
        )?),
        AgentKind::Tabular => Box::new(TabularAgent::new(NUM_ACTIONS)),
    };
    let mut replay = ReplayBuffer::new(4096);
    let mut cvars = CvarSet::vanilla();

    // Reference run (vanilla).
    let reference = model.observe(&cvars, cfg.noise, &mut rng).total_time_us;
    let mut prev_state = synth_state(reference, reference, &[0.0, 0.0], &cvars, 0);

    let mut best_cvars = cvars.clone();
    let mut best_mean = model.mean_time(&cvars);
    let mut trajectory = Vec::with_capacity(cfg.runs);

    for i in 1..=cfg.runs {
        let f = (i - 1) as f64 / (cfg.runs.max(2) - 1) as f64;
        let eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * f;
        let action_idx = if rng.chance(eps) {
            rng.below(NUM_ACTIONS as u64) as usize
        } else {
            crate::runtime::argmax(&agent.q_values(&prev_state)?)
        };
        cvars = Action::from_index(MPICH_CVARS, action_idx).apply(&cvars);

        let obs = model.observe(&cvars, cfg.noise, &mut rng);
        trajectory.push(obs.total_time_us);
        let reward = (((reference - obs.total_time_us) / reference) as f32).clamp(-1.0, 1.0);
        let state = synth_state(obs.total_time_us, reference, &obs.aux, &cvars, i);
        replay.push(Transition {
            state: prev_state,
            action: action_idx,
            reward,
            next_state: state.clone(),
            done: i == cfg.runs,
            // Synthetic models stand in for no real application.
            workload: None,
        });
        let batch = replay.sample(32, &mut rng);
        agent.train(&batch, cfg.lr, cfg.gamma)?;
        prev_state = state;

        // Track best by the *noise-free* mean so the report measures
        // true convergence, not a lucky noisy draw.
        let mean = model.mean_time(&cvars);
        if mean < best_mean {
            best_mean = mean;
            best_cvars = cvars.clone();
        }
    }

    Ok(ConvergenceReport {
        best_distance: model.distance_to_best(&best_cvars),
        best_ratio: best_mean / model.optimal_time(),
        final_cvars: cvars,
        best_cvars,
        trajectory,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::CvarId;

    #[test]
    fn finds_bool_step_without_noise() {
        let model = SyntheticModel::BoolStep { cvar: CvarId(0), gain: 0.3 };
        let cfg = ConvergenceConfig { runs: 120, seed: 11, ..Default::default() };
        let rep = run_convergence(&model, &cfg).unwrap();
        assert_eq!(rep.best_distance, 0.0, "should find async progress: {:?}", rep.best_cvars);
        assert!(rep.best_ratio < 1.01);
    }

    #[test]
    fn approaches_parabola_optimum_under_noise() {
        // POLLS_BEFORE_YIELD parabola with optimum at 2600 (16 steps up).
        let model = SyntheticModel::Parabola { cvar: CvarId(4), best: 2600, curvature: 12.0 };
        let cfg = ConvergenceConfig { runs: 400, noise: 0.10, seed: 13, ..Default::default() };
        let rep = run_convergence(&model, &cfg).unwrap();
        assert!(
            rep.best_distance < 0.05,
            "best {:?} distance {}",
            rep.best_cvars.get(CvarId(4)),
            rep.best_distance
        );
    }

    #[test]
    fn trajectory_length_matches_runs() {
        let model = SyntheticModel::BoolStep { cvar: CvarId(2), gain: 0.1 };
        let cfg = ConvergenceConfig { runs: 25, seed: 1, ..Default::default() };
        let rep = run_convergence(&model, &cfg).unwrap();
        assert_eq!(rep.trajectory.len(), 25);
    }
}
