//! Synthetic cvar→pvar models with a known optimum (§5.5).
//!
//! The paper's example: "a simulated performance variable ... a function
//! of one control variable, for example in the shape of a parabola,
//! with a global minimum." We implement that parabola family plus a
//! coupled two-variable extension (their stated future work) and a
//! boolean-shift model, each with Gaussian observation noise.

use crate::mpi_t::{CvarDomain, CvarId, CvarSet, MPICH_CVARS};
use crate::util::rng::Rng;

/// Synthetic observation: a "total time" plus auxiliary pvар values.
#[derive(Debug, Clone)]
pub struct SyntheticPvars {
    pub total_time_us: f64,
    pub aux: Vec<f64>,
}

/// A known-optimum model mapping configurations to noisy pvars.
#[derive(Debug, Clone)]
pub enum SyntheticModel {
    /// Parabola in one integer cvar: minimum at `best`.
    Parabola { cvar: CvarId, best: i64, curvature: f64 },
    /// Parabola in one cvar whose optimum shifts with a boolean cvar
    /// (two-variable coupling — the paper's future-work case).
    CoupledParabola {
        int_cvar: CvarId,
        bool_cvar: CvarId,
        best_off: i64,
        best_on: i64,
        bool_gain: f64,
        curvature: f64,
    },
    /// Step model: a boolean cvar shifts time by `gain` (e.g. async
    /// progress on a put-heavy code).
    BoolStep { cvar: CvarId, gain: f64 },
}

impl SyntheticModel {
    /// Baseline (noise-free) time at the vanilla configuration.
    pub const BASE_US: f64 = 1000.0;

    /// Noise-free evaluation.
    pub fn mean_time(&self, cv: &CvarSet) -> f64 {
        match *self {
            SyntheticModel::Parabola { cvar, best, curvature } => {
                let x = normalized_distance(cvar, cv.get(cvar), best);
                Self::BASE_US * (1.0 + curvature * x * x)
            }
            SyntheticModel::CoupledParabola {
                int_cvar,
                bool_cvar,
                best_off,
                best_on,
                bool_gain,
                curvature,
            } => {
                let on = cv.get(bool_cvar) != 0;
                let best = if on { best_on } else { best_off };
                let x = normalized_distance(int_cvar, cv.get(int_cvar), best);
                let base = if on { 1.0 - bool_gain } else { 1.0 };
                Self::BASE_US * base * (1.0 + curvature * x * x)
            }
            SyntheticModel::BoolStep { cvar, gain } => {
                let on = cv.get(cvar) != 0;
                Self::BASE_US * if on { 1.0 - gain } else { 1.0 }
            }
        }
    }

    /// The model's known-best achievable mean time.
    pub fn optimal_time(&self) -> f64 {
        match *self {
            SyntheticModel::Parabola { .. } => Self::BASE_US,
            SyntheticModel::CoupledParabola { bool_gain, .. } => Self::BASE_US * (1.0 - bool_gain),
            SyntheticModel::BoolStep { gain, .. } => Self::BASE_US * (1.0 - gain),
        }
    }

    /// Noisy observation (noise = std-dev fraction of the value, §5.5
    /// explores up to 0.30).
    pub fn observe(&self, cv: &CvarSet, noise: f64, rng: &mut Rng) -> SyntheticPvars {
        let mean = self.mean_time(cv);
        let total = mean * (1.0 + noise * rng.normal()).max(0.05);
        // Auxiliary pvars: noisy echoes correlated with the objective,
        // standing in for queue lengths / op timers.
        let aux = vec![
            (mean / Self::BASE_US - 1.0) * 10.0 * (1.0 + noise * rng.normal()),
            total / 100.0,
        ];
        SyntheticPvars { total_time_us: total, aux }
    }

    /// How far (in normalized domain units, 0..1) a configuration's
    /// relevant cvar is from the model's optimum.
    pub fn distance_to_best(&self, cv: &CvarSet) -> f64 {
        match *self {
            SyntheticModel::Parabola { cvar, best, .. } => {
                normalized_distance(cvar, cv.get(cvar), best).abs()
            }
            SyntheticModel::CoupledParabola { int_cvar, bool_cvar, best_on, .. } => {
                let bool_miss = if cv.get(bool_cvar) != 0 { 0.0 } else { 1.0 };
                let x = normalized_distance(int_cvar, cv.get(int_cvar), best_on).abs();
                (bool_miss + x) / 2.0
            }
            SyntheticModel::BoolStep { cvar, gain: _ } => {
                if cv.get(cvar) != 0 {
                    0.0
                } else {
                    1.0
                }
            }
        }
    }
}

/// |v − best| normalized by the cvar's domain width.
fn normalized_distance(cvar: CvarId, v: i64, best: i64) -> f64 {
    match MPICH_CVARS[cvar.0].domain {
        CvarDomain::Bool => (v - best).abs() as f64,
        CvarDomain::Int { lo, hi, .. } => (v - best) as f64 / (hi - lo).max(1) as f64,
        CvarDomain::Choice { options } => {
            (v - best).abs() as f64 / (options.len() as i64 - 1).max(1) as f64
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn parabola_minimum_at_best() {
        let m = SyntheticModel::Parabola { cvar: CvarId(4), best: 1400, curvature: 8.0 };
        let mut at_best = CvarSet::vanilla();
        at_best.set(CvarId(4), 1400);
        let mut off = CvarSet::vanilla();
        off.set(CvarId(4), 50_000);
        assert!(m.mean_time(&at_best) < m.mean_time(&off));
        assert!((m.mean_time(&at_best) - m.optimal_time()).abs() < 1e-9);
    }

    #[test]
    fn coupled_model_rewards_bool() {
        let m = SyntheticModel::CoupledParabola {
            int_cvar: CvarId(5),
            bool_cvar: CvarId(0),
            best_off: 131_072,
            best_on: 1_310_720,
            bool_gain: 0.25,
            curvature: 4.0,
        };
        let mut on = CvarSet::vanilla();
        on.set(CvarId(0), 1);
        on.set(CvarId(5), 1_310_720);
        assert!(m.mean_time(&on) < m.mean_time(&CvarSet::vanilla()));
        assert_eq!(m.distance_to_best(&on), 0.0);
    }

    #[test]
    fn noise_scales_with_level() {
        let m = SyntheticModel::BoolStep { cvar: CvarId(0), gain: 0.3 };
        let cv = CvarSet::vanilla();
        let spread = |noise: f64| {
            let mut rng = Rng::new(1);
            let xs: Vec<f64> =
                (0..500).map(|_| m.observe(&cv, noise, &mut rng).total_time_us).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64).sqrt()
        };
        assert!(spread(0.3) > spread(0.05) * 3.0);
    }
}
