//! §5.5 — convergence simulations of the RL machinery.
//!
//! "In these simulations, there was no OpenCoarray library to tune, just
//! models. Each model included a handful of simulated control and
//! performance variables with known behavior and added Gaussian noise.
//! ... Even with high level of noise (up to 30% of the value of the
//! performance variables), our algorithm has always been able to find a
//! set of control variables reasonably close to the known best."

mod harness;
mod models;

pub use harness::{run_convergence, ConvergenceConfig, ConvergenceReport};
pub use models::{SyntheticModel, SyntheticPvars};
