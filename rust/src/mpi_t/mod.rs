//! MPI Tool Information Interface (MPI_T) — the introspection layer.
//!
//! Faithful reconstruction of the paper's §4/§5.1 architecture: *control
//! variables* steer the MPI implementation and must be set **before**
//! `MPI_Init`; *performance variables* (queue lengths, wait times) are
//! read through handles inside a *session* created **after** `MPI_Init`.
//! `Probe`s validate user-defined performance values (datatype, range)
//! before they enter a `Collection`, and the PMPI shim lets AITuning hook
//! init/finalize/flush without touching the runtime's source.

mod collection;
mod cvar;
mod pmpi;
mod probe;
mod pvar;
mod registry;
mod session;

pub use collection::{
    Collection, CollectionCreator, CollectivesCollectionCreator, MpichCollectionCreator,
};
pub use cvar::{
    CvarDescriptor, CvarDomain, CvarId, CvarSet, CvarValue, ALLREDUCE_ALGORITHMS,
    BCAST_ALGORITHMS, COLLECTIVE_CVARS, MPICH_CVARS, NUM_CVARS,
};
pub use pmpi::{NullHooks, PmpiHooks, PmpiLayer};
pub use probe::{Probe, ProbeError};
pub use pvar::{
    PvarClass, PvarDescriptor, PvarId, PvarStats, UserDefinedPvar, COLLECTIVE_PVARS,
    MPICH_PVARS, NUM_PVARS, TOTAL_TIME_PVAR,
};
pub use registry::{
    registry_for, registry_for_backend, BackendRegistry, MpichRegistry, VariableRegistry,
};
pub use session::{InitState, Session, SessionError};
