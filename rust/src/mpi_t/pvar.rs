//! Performance variables: what AITuning observes.
//!
//! The paper uses one MPICH pvar (`unexpected_recvq_length`) plus several
//! *user-defined* pvars registered through probes (MPI_Win_flush / put /
//! get times and total application time, §5.3). Time-like pvars can be
//! declared **Relative** (§5.1): the first run stores the absolute value
//! as a reference and later runs report `reference − current`, so a
//! positive value reads as an improvement.

use crate::metrics::stats::Summary;

/// Identifier for a performance variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PvarId(pub usize);

/// MPI_T performance-variable classes (subset used here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PvarClass {
    /// Queue length at sample time (e.g. unexpected message queue).
    Level,
    /// Elapsed time of an operation, microseconds.
    Timer,
    /// Monotonic event count.
    Counter,
}

/// Static description of a performance variable.
#[derive(Debug, Clone)]
pub struct PvarDescriptor {
    pub id: PvarId,
    pub name: &'static str,
    pub class: PvarClass,
    /// Paper §5.1: relative pvars are standardized against the first run.
    pub relative: bool,
    /// Valid range for probe validation.
    pub range: (f64, f64),
}

/// The pvar set for MPICH-3.2.1 per the paper (§5.3): the MPICH-exposed
/// unexpected queue length plus user-defined timing pvars.
pub const MPICH_PVARS: &[PvarDescriptor] = &[
    PvarDescriptor {
        id: PvarId(0),
        name: "unexpected_recvq_length",
        class: PvarClass::Level,
        relative: false,
        range: (0.0, 1e9),
    },
    PvarDescriptor {
        id: PvarId(1),
        name: "win_flush_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(2),
        name: "put_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(3),
        name: "get_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(4),
        name: "total_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e15),
    },
];

/// Number of pvars in the MPICH (coarrays backend) collection.
pub const NUM_PVARS: usize = 5;

/// Index of the total-application-time pvar — shared across every
/// backend's schema by convention, so the reward basis and the
/// [`crate::coordinator::relative::RelativeTracker`] total lookup are
/// schema-independent.
pub const TOTAL_TIME_PVAR: PvarId = PvarId(4);

/// The collectives backend's pvar schema: per-collective-class timers
/// plus the observed payload sizes and total application time.
pub const COLLECTIVE_PVARS: &[PvarDescriptor] = &[
    PvarDescriptor {
        id: PvarId(0),
        name: "bcast_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(1),
        name: "allreduce_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(2),
        name: "barrier_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(3),
        name: "coll_payload_bytes",
        class: PvarClass::Level,
        relative: false,
        range: (0.0, 1e12),
    },
    PvarDescriptor {
        id: PvarId(4),
        name: "total_time_us",
        class: PvarClass::Timer,
        relative: true,
        range: (0.0, 1e15),
    },
];

/// A user-defined performance variable (§5.1, Listing 2): values are
/// registered through a [`crate::mpi_t::Probe`] during the run, and the
/// end-of-run statistics feed the RL state.
#[derive(Debug, Clone)]
pub struct UserDefinedPvar {
    pub descriptor: PvarDescriptor,
    values: Vec<f64>,
}

impl UserDefinedPvar {
    pub fn new(descriptor: PvarDescriptor) -> UserDefinedPvar {
        UserDefinedPvar { descriptor, values: Vec::new() }
    }

    /// Record one observation (Listing 3: `registerValue`).
    pub fn register_value(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// End-of-run statistics (avg, max, min, median — §5.1).
    pub fn summarize(&self) -> Summary {
        Summary::of(&self.values)
    }

    pub fn reset(&mut self) {
        self.values.clear();
    }
}

/// End-of-run statistics for every pvar in a collection, in registry
/// order. This is the paper's "state representation passed to the AI
/// component" before standardization.
#[derive(Debug, Clone, Default)]
pub struct PvarStats {
    pub summaries: Vec<(PvarId, Summary)>,
}

impl PvarStats {
    pub fn get(&self, id: PvarId) -> Option<&Summary> {
        self.summaries.iter().find(|(pid, _)| *pid == id).map(|(_, s)| s)
    }

    /// Total application time (the reward's basis), if recorded.
    pub fn total_time_us(&self) -> Option<f64> {
        self.get(TOTAL_TIME_PVAR).map(|s| s.max)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn register_and_summarize() {
        let mut p = UserDefinedPvar::new(MPICH_PVARS[1].clone());
        for v in [1.0, 3.0, 2.0] {
            p.register_value(v);
        }
        let s = p.summarize();
        assert_eq!(s.max, 3.0);
        assert_eq!(s.min, 1.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.median, 2.0);
        p.reset();
        assert!(p.values().is_empty());
    }

    #[test]
    fn pvar_table_is_consistent() {
        assert_eq!(MPICH_PVARS.len(), NUM_PVARS);
        for table in [MPICH_PVARS, COLLECTIVE_PVARS] {
            for (i, d) in table.iter().enumerate() {
                assert_eq!(d.id.0, i);
                assert!(d.range.0 <= d.range.1);
            }
            // total_time must be relative (paper: cannot be absolute)
            // and sit at the schema-independent index.
            assert_eq!(table[TOTAL_TIME_PVAR.0].name, "total_time_us");
            assert!(table[TOTAL_TIME_PVAR.0].relative);
        }
    }

    #[test]
    fn stats_lookup() {
        let mut st = PvarStats::default();
        st.summaries.push((PvarId(4), Summary::of(&[5.0, 7.0])));
        assert_eq!(st.total_time_us(), Some(7.0));
        assert!(st.get(PvarId(0)).is_none());
    }
}
