//! MPI_T sessions and init-ordering enforcement.
//!
//! The paper stresses two ordering rules it discovered (§4.1/§5.1):
//! *control variables* must be modified **before** `MPI_Init`, and
//! *performance-variable* handles/sessions must be created **after**
//! `MPI_Init`. [`InitState`] enforces both; [`Session`] scopes pvar
//! access the way MPI_T sessions isolate readers.

use std::fmt;

use super::cvar::{CvarId, CvarSet};
use super::pvar::{PvarId, UserDefinedPvar};

/// Errors from violating MPI_T ordering or handle rules.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    CvarAfterInit(CvarId),
    SessionBeforeInit,
    NoSession(PvarId),
    DoubleInit,
    FinalizeBeforeInit,
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::CvarAfterInit(id) => {
                write!(f, "control variable {id:?} modified after MPI_Init")
            }
            SessionError::SessionBeforeInit => {
                write!(f, "performance session created before MPI_Init")
            }
            SessionError::NoSession(id) => {
                write!(f, "performance variable {id:?} read outside a session")
            }
            SessionError::DoubleInit => write!(f, "MPI_Init called twice"),
            SessionError::FinalizeBeforeInit => write!(f, "MPI_Finalize before MPI_Init"),
        }
    }
}

impl std::error::Error for SessionError {}

/// Lifecycle of the (simulated) MPI library within one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitState {
    PreInit,
    Initialized,
    Finalized,
}

/// The MPI_T access layer for one application run: owns the cvar set
/// (frozen at init) and the pvar sessions.
#[derive(Debug)]
pub struct Session {
    state: InitState,
    cvars: CvarSet,
    /// Sessions created after init; each owns its user-defined pvars.
    open_sessions: usize,
}

impl Session {
    pub fn new() -> Session {
        Session { state: InitState::PreInit, cvars: CvarSet::vanilla(), open_sessions: 0 }
    }

    pub fn state(&self) -> InitState {
        self.state
    }

    /// Write a control variable; only legal before `MPI_Init` (§5.1:
    /// "it is important to modify all the control variables values
    /// before calling MPI_Init").
    pub fn cvar_write(&mut self, id: CvarId, value: i64) -> Result<(), SessionError> {
        if self.state != InitState::PreInit {
            return Err(SessionError::CvarAfterInit(id));
        }
        self.cvars.set(id, value);
        Ok(())
    }

    /// Bulk-apply a configuration before init.
    pub fn set_all_cvars(&mut self, set: &CvarSet) -> Result<(), SessionError> {
        if self.state != InitState::PreInit {
            return Err(SessionError::CvarAfterInit(CvarId(0)));
        }
        self.cvars = set.clone();
        Ok(())
    }

    /// `MPI_Init` — freezes the cvar set.
    pub fn init(&mut self) -> Result<(), SessionError> {
        match self.state {
            InitState::PreInit => {
                self.state = InitState::Initialized;
                Ok(())
            }
            _ => Err(SessionError::DoubleInit),
        }
    }

    /// Create a pvar session (only after init).
    pub fn create_pvar_session(&mut self) -> Result<PvarSessionHandle, SessionError> {
        if self.state != InitState::Initialized {
            return Err(SessionError::SessionBeforeInit);
        }
        self.open_sessions += 1;
        Ok(PvarSessionHandle { index: self.open_sessions - 1, pvars: Vec::new() })
    }

    /// `MPI_Finalize`.
    pub fn finalize(&mut self) -> Result<(), SessionError> {
        match self.state {
            InitState::Initialized => {
                self.state = InitState::Finalized;
                Ok(())
            }
            _ => Err(SessionError::FinalizeBeforeInit),
        }
    }

    /// The frozen configuration the (simulated) library runs with.
    pub fn effective_cvars(&self) -> &CvarSet {
        &self.cvars
    }
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

/// A pvar session: isolates a set of user-defined pvars to one part of
/// the code (§4.1: "a session provides a way to isolate the use of a
/// performance variable").
#[derive(Debug)]
pub struct PvarSessionHandle {
    pub index: usize,
    pub pvars: Vec<UserDefinedPvar>,
}

impl PvarSessionHandle {
    /// Register a user-defined pvar; returns its handle id in-session.
    pub fn add_pvar(&mut self, pvar: UserDefinedPvar) -> PvarId {
        self.pvars.push(pvar);
        PvarId(self.pvars.len() - 1)
    }

    pub fn pvar_mut(&mut self, id: PvarId) -> Option<&mut UserDefinedPvar> {
        self.pvars.get_mut(id.0)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::pvar::MPICH_PVARS;

    #[test]
    fn cvar_write_only_pre_init() {
        let mut s = Session::new();
        assert!(s.cvar_write(CvarId(5), 262_144).is_ok());
        s.init().unwrap();
        assert_eq!(
            s.cvar_write(CvarId(5), 1024),
            Err(SessionError::CvarAfterInit(CvarId(5)))
        );
        assert_eq!(s.effective_cvars().eager_max(), 262_144);
    }

    #[test]
    fn pvar_session_only_post_init() {
        let mut s = Session::new();
        assert_eq!(s.create_pvar_session().unwrap_err(), SessionError::SessionBeforeInit);
        s.init().unwrap();
        let mut h = s.create_pvar_session().unwrap();
        let id = h.add_pvar(UserDefinedPvar::new(MPICH_PVARS[1].clone()));
        assert!(h.pvar_mut(id).is_some());
    }

    #[test]
    fn lifecycle_enforced() {
        let mut s = Session::new();
        assert_eq!(s.finalize(), Err(SessionError::FinalizeBeforeInit));
        s.init().unwrap();
        assert_eq!(s.init(), Err(SessionError::DoubleInit));
        s.finalize().unwrap();
        assert_eq!(s.finalize(), Err(SessionError::FinalizeBeforeInit));
    }
}
