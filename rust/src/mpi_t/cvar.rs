//! Control variables: the knobs AITuning tunes.
//!
//! Descriptors are grouped into per-backend registries: the six
//! MPICH-3.2.1 cvars from the paper (§5.3) for the coarrays runtime,
//! and the collective-algorithm selectors for the collectives runtime.
//! A [`CvarSet`] carries its [`BackendId`], so domain clamping,
//! normalization and display always consult the right table.

use std::fmt;

use crate::backend::BackendId;

/// Identifier for a control variable (index into the registry order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CvarId(pub usize);

/// Value domain of a control variable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CvarDomain {
    /// Boolean toggle (0/1), e.g. `MPIR_CVAR_ASYNC_PROGRESS`.
    Bool,
    /// Integer range with a fixed tuning step, e.g.
    /// `MPIR_CVAR_CH3_EAGER_MAX_MSG_SIZE` stepping by 1024.
    Int { lo: i64, hi: i64, step: i64 },
    /// Enumerated choice (categorical), e.g. a collective-algorithm
    /// selector. Values are indices into `options`; stepping moves to
    /// the neighbouring option, and the action space additionally gets
    /// one direct *select* action per option (see
    /// [`crate::coordinator::actions`]).
    Choice { options: &'static [&'static str] },
}

/// Static description of a control variable.
#[derive(Debug, Clone)]
pub struct CvarDescriptor {
    pub id: CvarId,
    pub name: &'static str,
    pub domain: CvarDomain,
    pub default: i64,
    pub description: &'static str,
}

impl CvarDescriptor {
    /// Clamp a raw value into this cvar's domain.
    pub fn clamp(&self, v: i64) -> i64 {
        match self.domain {
            CvarDomain::Bool => i64::from(v != 0),
            CvarDomain::Int { lo, hi, .. } => v.clamp(lo, hi),
            CvarDomain::Choice { options } => v.clamp(0, options.len() as i64 - 1),
        }
    }

    /// One tuning step up/down (paper §5.2: fixed per-cvar step;
    /// booleans toggle, choices move to the neighbouring option).
    pub fn step(&self, current: i64, up: bool) -> i64 {
        match self.domain {
            CvarDomain::Bool => i64::from(current == 0),
            CvarDomain::Int { step, .. } => {
                self.clamp(current + if up { step } else { -step })
            }
            CvarDomain::Choice { .. } => self.clamp(current + if up { 1 } else { -1 }),
        }
    }

    /// Normalize a value into [0, 1] for the RL state vector.
    pub fn normalize(&self, v: i64) -> f32 {
        match self.domain {
            CvarDomain::Bool => v as f32,
            CvarDomain::Int { lo, hi, .. } => {
                if hi == lo {
                    0.0
                } else {
                    (v - lo) as f32 / (hi - lo) as f32
                }
            }
            CvarDomain::Choice { options } => {
                if options.len() <= 1 {
                    0.0
                } else {
                    v as f32 / (options.len() - 1) as f32
                }
            }
        }
    }
}

/// The MPICH-3.2.1 control-variable set the paper tunes (§5.3) — the
/// coarrays backend's registry.
pub const MPICH_CVARS: &[CvarDescriptor] = &[
    CvarDescriptor {
        id: CvarId(0),
        name: "MPIR_CVAR_ASYNC_PROGRESS",
        domain: CvarDomain::Bool,
        default: 0,
        description: "helper thread makes MPI communication progress asynchronously",
    },
    CvarDescriptor {
        id: CvarId(1),
        name: "MPIR_CVAR_CH3_ENABLE_HCOLL",
        domain: CvarDomain::Bool,
        default: 0,
        description: "enable optimized (hierarchical) collective algorithms",
    },
    CvarDescriptor {
        id: CvarId(2),
        name: "MPIR_CVAR_CH3_RMA_DELAY_ISSUING_FOR_PIGGYBACKING",
        domain: CvarDomain::Bool,
        default: 0,
        description: "delay issuing small RMA ops to piggyback them on lock/flush messages",
    },
    CvarDescriptor {
        id: CvarId(3),
        name: "MPIR_CVAR_CH3_RMA_OP_PIGGYBACK_LOCK_DATA_SIZE",
        domain: CvarDomain::Int { lo: 0, hi: 262_144, step: 4096 },
        default: 65_536,
        description: "max data size piggybacked on an RMA lock message",
    },
    CvarDescriptor {
        id: CvarId(4),
        name: "MPIR_CVAR_POLLS_BEFORE_YIELD",
        domain: CvarDomain::Int { lo: 0, hi: 100_000, step: 100 },
        default: 1000,
        description: "progress-engine polls before yielding the core",
    },
    CvarDescriptor {
        id: CvarId(5),
        name: "MPIR_CVAR_CH3_EAGER_MAX_MSG_SIZE",
        domain: CvarDomain::Int { lo: 1024, hi: 8 * 1024 * 1024, step: 1024 },
        default: 131_072,
        description: "message-size threshold switching from eager to rendezvous protocol",
    },
];

/// Broadcast algorithm options of the collectives backend (value =
/// index into this list).
pub const BCAST_ALGORITHMS: &[&str] =
    &["binomial", "scatter_allgather", "scatter_ring_allgather"];

/// Allreduce algorithm options of the collectives backend.
pub const ALLREDUCE_ALGORITHMS: &[&str] = &["recursive_doubling", "ring"];

/// The collectives backend's registry: MPICH collective-algorithm
/// selectors (categorical), a pipeline segment size, and the SMP
/// (hierarchical) toggle — the tuning space of Hunold &
/// Carpen-Amarie's performance-guidelines work.
pub const COLLECTIVE_CVARS: &[CvarDescriptor] = &[
    CvarDescriptor {
        id: CvarId(0),
        name: "MPIR_CVAR_BCAST_INTRA_ALGORITHM",
        domain: CvarDomain::Choice { options: BCAST_ALGORITHMS },
        default: 0,
        description: "algorithm used for MPI_Bcast inside a communicator",
    },
    CvarDescriptor {
        id: CvarId(1),
        name: "MPIR_CVAR_ALLREDUCE_INTRA_ALGORITHM",
        domain: CvarDomain::Choice { options: ALLREDUCE_ALGORITHMS },
        default: 0,
        description: "algorithm used for MPI_Allreduce inside a communicator",
    },
    CvarDescriptor {
        id: CvarId(2),
        name: "MPIR_CVAR_COLL_SEGMENT_SIZE",
        domain: CvarDomain::Int { lo: 8192, hi: 1 << 20, step: 32_768 },
        default: 1 << 20,
        description: "pipeline segment size for segmented collective algorithms (bytes)",
    },
    CvarDescriptor {
        id: CvarId(3),
        name: "MPIR_CVAR_ENABLE_SMP_COLLECTIVES",
        domain: CvarDomain::Bool,
        default: 0,
        description: "use node-hierarchical (SMP-aware) collective algorithms",
    },
];

/// Number of tunable cvars in the coarrays (paper) backend. The
/// coarrays state/action layout compiled into the AOT artifacts
/// depends on this; other backends size everything dynamically.
pub const NUM_CVARS: usize = 6;

/// A concrete assignment of values to all control variables of one
/// backend's registry. Ordered (backend tag, then values) so ordered
/// containers keyed by configurations — e.g. the persisted episode
/// cache — iterate in a canonical, insertion-independent order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CvarSet {
    backend: BackendId,
    values: Vec<i64>,
}

/// Typed view of one value (for display).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CvarValue {
    Bool(bool),
    Int(i64),
    /// Choice index plus its option name.
    Choice(usize, &'static str),
}

impl CvarSet {
    /// All defaults of the coarrays backend — the "vanilla" MPICH
    /// configuration of the paper (the historical constructor).
    pub fn vanilla() -> CvarSet {
        CvarSet::defaults(BackendId::Coarrays)
    }

    /// All defaults of `backend`'s registry.
    pub fn defaults(backend: BackendId) -> CvarSet {
        CvarSet { backend, values: backend.cvars().iter().map(|d| d.default).collect() }
    }

    /// The backend whose registry this set indexes.
    pub fn backend(&self) -> BackendId {
        self.backend
    }

    /// The backing descriptor table.
    pub fn table(&self) -> &'static [CvarDescriptor] {
        self.backend.cvars()
    }

    /// Number of cvars in the set.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn get(&self, id: CvarId) -> i64 {
        self.values[id.0]
    }

    /// Set with domain clamping.
    pub fn set(&mut self, id: CvarId, v: i64) {
        self.values[id.0] = self.table()[id.0].clamp(v);
    }

    pub fn typed(&self, id: CvarId) -> CvarValue {
        match self.table()[id.0].domain {
            CvarDomain::Bool => CvarValue::Bool(self.values[id.0] != 0),
            CvarDomain::Int { .. } => CvarValue::Int(self.values[id.0]),
            CvarDomain::Choice { options } => {
                let i = self.values[id.0] as usize;
                CvarValue::Choice(i, options[i])
            }
        }
    }

    // Typed accessors used by the simulator hot path (coarrays layout;
    // the debug assert catches a set from the wrong registry before it
    // silently misreads an index).

    pub fn async_progress(&self) -> bool {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[0] != 0
    }

    pub fn enable_hcoll(&self) -> bool {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[1] != 0
    }

    pub fn delay_piggyback(&self) -> bool {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[2] != 0
    }

    pub fn piggyback_size(&self) -> i64 {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[3]
    }

    pub fn polls_before_yield(&self) -> i64 {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[4]
    }

    pub fn eager_max(&self) -> i64 {
        debug_assert_eq!(self.backend, BackendId::Coarrays);
        self.values[5]
    }

    /// Normalized values for the RL state vector, registry order.
    pub fn normalized(&self) -> Vec<f32> {
        self.table()
            .iter()
            .zip(&self.values)
            .map(|(d, &v)| d.normalize(v))
            .collect()
    }

    pub fn as_slice(&self) -> &[i64] {
        &self.values
    }
}

impl Default for CvarSet {
    fn default() -> Self {
        Self::vanilla()
    }
}

impl fmt::Display for CvarSet {
    /// Compact `NAME=value` pairs with the `MPIR_CVAR_` prefix stripped;
    /// choice cvars print the selected option's name.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, d) in self.table().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            let short = d.name.strip_prefix("MPIR_CVAR_").unwrap_or(d.name);
            match self.typed(CvarId(i)) {
                CvarValue::Choice(_, name) => write!(f, "{short}={name}")?,
                _ => write!(f, "{short}={}", self.values[i])?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn vanilla_matches_defaults() {
        let v = CvarSet::vanilla();
        assert_eq!(v.backend(), BackendId::Coarrays);
        assert_eq!(v.len(), NUM_CVARS);
        assert!(!v.async_progress());
        assert_eq!(v.eager_max(), 131_072);
        assert_eq!(v.polls_before_yield(), 1000);
    }

    #[test]
    fn set_clamps_to_domain() {
        let mut v = CvarSet::vanilla();
        v.set(CvarId(5), -5);
        assert_eq!(v.eager_max(), 1024);
        v.set(CvarId(5), i64::MAX);
        assert_eq!(v.eager_max(), 8 * 1024 * 1024);
        v.set(CvarId(0), 17);
        assert_eq!(v.get(CvarId(0)), 1);
    }

    #[test]
    fn step_respects_bounds_and_toggles() {
        let d = &MPICH_CVARS[5];
        assert_eq!(d.step(131_072, true), 132_096);
        assert_eq!(d.step(1024, false), 1024); // clamped at lo
        let b = &MPICH_CVARS[0];
        assert_eq!(b.step(0, true), 1);
        assert_eq!(b.step(1, true), 0); // toggle regardless of direction
    }

    #[test]
    fn choice_domain_steps_and_clamps() {
        let d = &COLLECTIVE_CVARS[0];
        assert_eq!(d.step(0, true), 1);
        assert_eq!(d.step(2, true), 2); // clamped at last option
        assert_eq!(d.step(0, false), 0); // clamped at first option
        assert_eq!(d.clamp(99), BCAST_ALGORITHMS.len() as i64 - 1);
        assert_eq!(d.clamp(-3), 0);
        assert!((d.normalize(2) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn collectives_defaults_and_typed_views() {
        let cv = CvarSet::defaults(BackendId::Collectives);
        assert_eq!(cv.backend(), BackendId::Collectives);
        assert_eq!(cv.len(), COLLECTIVE_CVARS.len());
        assert_eq!(cv.typed(CvarId(0)), CvarValue::Choice(0, "binomial"));
        assert_eq!(cv.get(CvarId(2)), 1 << 20);
        let mut tuned = cv.clone();
        tuned.set(CvarId(1), 1);
        assert_eq!(tuned.typed(CvarId(1)), CvarValue::Choice(1, "ring"));
        assert_ne!(tuned, cv);
    }

    #[test]
    fn normalize_in_unit_range() {
        for table in [MPICH_CVARS, COLLECTIVE_CVARS] {
            for d in table {
                let n = d.normalize(d.default);
                assert!((0.0..=1.0).contains(&n), "{}: {n}", d.name);
            }
        }
    }

    #[test]
    fn display_is_compact() {
        let s = CvarSet::vanilla().to_string();
        assert!(s.contains("ASYNC_PROGRESS=0"), "{s}");
        assert!(s.contains("CH3_EAGER_MAX_MSG_SIZE=131072"), "{s}");
        let c = CvarSet::defaults(BackendId::Collectives).to_string();
        assert!(c.contains("BCAST_INTRA_ALGORITHM=binomial"), "{c}");
    }
}
