//! Control variables: the knobs AITuning tunes.
//!
//! The six MPICH-3.2.1 cvars from the paper (§5.3), each with its domain
//! and the fixed action "step" AITuning uses to change it (§5.2).

use std::fmt;

/// Identifier for a control variable (index into the registry order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CvarId(pub usize);

/// Value domain of a control variable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CvarDomain {
    /// Boolean toggle (0/1), e.g. `MPIR_CVAR_ASYNC_PROGRESS`.
    Bool,
    /// Integer range with a fixed tuning step, e.g.
    /// `MPIR_CVAR_CH3_EAGER_MAX_MSG_SIZE` stepping by 1024.
    Int { lo: i64, hi: i64, step: i64 },
}

/// Static description of a control variable.
#[derive(Debug, Clone)]
pub struct CvarDescriptor {
    pub id: CvarId,
    pub name: &'static str,
    pub domain: CvarDomain,
    pub default: i64,
    pub description: &'static str,
}

impl CvarDescriptor {
    /// Clamp a raw value into this cvar's domain.
    pub fn clamp(&self, v: i64) -> i64 {
        match self.domain {
            CvarDomain::Bool => i64::from(v != 0),
            CvarDomain::Int { lo, hi, .. } => v.clamp(lo, hi),
        }
    }

    /// One tuning step up/down (paper §5.2: fixed per-cvar step;
    /// booleans toggle).
    pub fn step(&self, current: i64, up: bool) -> i64 {
        match self.domain {
            CvarDomain::Bool => i64::from(current == 0),
            CvarDomain::Int { step, .. } => {
                self.clamp(current + if up { step } else { -step })
            }
        }
    }

    /// Normalize a value into [0, 1] for the RL state vector.
    pub fn normalize(&self, v: i64) -> f32 {
        match self.domain {
            CvarDomain::Bool => v as f32,
            CvarDomain::Int { lo, hi, .. } => {
                if hi == lo {
                    0.0
                } else {
                    (v - lo) as f32 / (hi - lo) as f32
                }
            }
        }
    }
}

/// The MPICH-3.2.1 control-variable set the paper tunes (§5.3).
pub const MPICH_CVARS: &[CvarDescriptor] = &[
    CvarDescriptor {
        id: CvarId(0),
        name: "MPIR_CVAR_ASYNC_PROGRESS",
        domain: CvarDomain::Bool,
        default: 0,
        description: "helper thread makes MPI communication progress asynchronously",
    },
    CvarDescriptor {
        id: CvarId(1),
        name: "MPIR_CVAR_CH3_ENABLE_HCOLL",
        domain: CvarDomain::Bool,
        default: 0,
        description: "enable optimized (hierarchical) collective algorithms",
    },
    CvarDescriptor {
        id: CvarId(2),
        name: "MPIR_CVAR_CH3_RMA_DELAY_ISSUING_FOR_PIGGYBACKING",
        domain: CvarDomain::Bool,
        default: 0,
        description: "delay issuing small RMA ops to piggyback them on lock/flush messages",
    },
    CvarDescriptor {
        id: CvarId(3),
        name: "MPIR_CVAR_CH3_RMA_OP_PIGGYBACK_LOCK_DATA_SIZE",
        domain: CvarDomain::Int { lo: 0, hi: 262_144, step: 4096 },
        default: 65_536,
        description: "max data size piggybacked on an RMA lock message",
    },
    CvarDescriptor {
        id: CvarId(4),
        name: "MPIR_CVAR_POLLS_BEFORE_YIELD",
        domain: CvarDomain::Int { lo: 0, hi: 100_000, step: 100 },
        default: 1000,
        description: "progress-engine polls before yielding the core",
    },
    CvarDescriptor {
        id: CvarId(5),
        name: "MPIR_CVAR_CH3_EAGER_MAX_MSG_SIZE",
        domain: CvarDomain::Int { lo: 1024, hi: 8 * 1024 * 1024, step: 1024 },
        default: 131_072,
        description: "message-size threshold switching from eager to rendezvous protocol",
    },
];

/// Number of tunable cvars (state/action layout depends on this).
pub const NUM_CVARS: usize = 6;

/// A concrete assignment of values to all control variables.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CvarSet {
    values: [i64; NUM_CVARS],
}

/// Typed view of one value (for display).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CvarValue {
    Bool(bool),
    Int(i64),
}

impl CvarSet {
    /// All defaults — the "vanilla" MPICH configuration of the paper.
    pub fn vanilla() -> CvarSet {
        let mut values = [0i64; NUM_CVARS];
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            values[i] = d.default;
        }
        CvarSet { values }
    }

    pub fn get(&self, id: CvarId) -> i64 {
        self.values[id.0]
    }

    /// Set with domain clamping.
    pub fn set(&mut self, id: CvarId, v: i64) {
        self.values[id.0] = MPICH_CVARS[id.0].clamp(v);
    }

    pub fn typed(&self, id: CvarId) -> CvarValue {
        match MPICH_CVARS[id.0].domain {
            CvarDomain::Bool => CvarValue::Bool(self.values[id.0] != 0),
            CvarDomain::Int { .. } => CvarValue::Int(self.values[id.0]),
        }
    }

    // Typed accessors used by the simulator hot path.

    pub fn async_progress(&self) -> bool {
        self.values[0] != 0
    }

    pub fn enable_hcoll(&self) -> bool {
        self.values[1] != 0
    }

    pub fn delay_piggyback(&self) -> bool {
        self.values[2] != 0
    }

    pub fn piggyback_size(&self) -> i64 {
        self.values[3]
    }

    pub fn polls_before_yield(&self) -> i64 {
        self.values[4]
    }

    pub fn eager_max(&self) -> i64 {
        self.values[5]
    }

    /// Normalized values for the RL state vector, registry order.
    pub fn normalized(&self) -> [f32; NUM_CVARS] {
        let mut out = [0.0f32; NUM_CVARS];
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            out[i] = d.normalize(self.values[i]);
        }
        out
    }

    pub fn as_slice(&self) -> &[i64; NUM_CVARS] {
        &self.values
    }
}

impl Default for CvarSet {
    fn default() -> Self {
        Self::vanilla()
    }
}

impl fmt::Display for CvarSet {
    /// Compact `NAME=value` pairs with the `MPIR_CVAR_` prefix stripped.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, d) in MPICH_CVARS.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            let short = d.name.strip_prefix("MPIR_CVAR_").unwrap_or(d.name);
            write!(f, "{short}={}", self.values[i])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_matches_defaults() {
        let v = CvarSet::vanilla();
        assert!(!v.async_progress());
        assert_eq!(v.eager_max(), 131_072);
        assert_eq!(v.polls_before_yield(), 1000);
    }

    #[test]
    fn set_clamps_to_domain() {
        let mut v = CvarSet::vanilla();
        v.set(CvarId(5), -5);
        assert_eq!(v.eager_max(), 1024);
        v.set(CvarId(5), i64::MAX);
        assert_eq!(v.eager_max(), 8 * 1024 * 1024);
        v.set(CvarId(0), 17);
        assert_eq!(v.get(CvarId(0)), 1);
    }

    #[test]
    fn step_respects_bounds_and_toggles() {
        let d = &MPICH_CVARS[5];
        assert_eq!(d.step(131_072, true), 132_096);
        assert_eq!(d.step(1024, false), 1024); // clamped at lo
        let b = &MPICH_CVARS[0];
        assert_eq!(b.step(0, true), 1);
        assert_eq!(b.step(1, true), 0); // toggle regardless of direction
    }

    #[test]
    fn normalize_in_unit_range() {
        for d in MPICH_CVARS {
            let n = d.normalize(d.default);
            assert!((0.0..=1.0).contains(&n), "{}: {n}", d.name);
        }
    }

    #[test]
    fn display_is_compact() {
        let s = CvarSet::vanilla().to_string();
        assert!(s.contains("ASYNC_PROGRESS=0"), "{s}");
        assert!(s.contains("CH3_EAGER_MAX_MSG_SIZE=131072"), "{s}");
    }
}
