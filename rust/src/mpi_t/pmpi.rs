//! PMPI interposition shim (§5.1, Listings 1 and 3).
//!
//! The paper plugs AITuning into OpenCoarrays *without changing its
//! source* by wrapping `MPI_Init_thread`, `MPI_Win_flush`, and
//! `MPI_Finalize` through the MPI profiling interface. Here the simulated
//! coarray runtime calls through [`PmpiLayer`], which invokes the
//! registered [`PmpiHooks`] around each intercepted call — same design
//! property: the runtime knows nothing about AITuning.

use super::session::Session;

/// Hooks AITuning registers around intercepted MPI calls.
pub trait PmpiHooks {
    /// Called at the top of the `MPI_Init_thread` wrapper, **before**
    /// `PMPI_Init_thread` — where `AITuning_start` and
    /// `AITuning_setControlVariables` run (Listing 1).
    fn before_init(&mut self, session: &mut Session);

    /// Called after `PMPI_Init_thread` — where
    /// `AITuning_setPerformanceVariables` runs.
    fn after_init(&mut self, session: &mut Session);

    /// Called with the measured duration of each `MPI_Win_flush`
    /// (Listing 3: `flush_time_p->registerValue(...)`).
    fn on_win_flush(&mut self, duration_us: f64);

    /// Called with each put/get completion time (user-defined pvars).
    fn on_put(&mut self, duration_us: f64);
    fn on_get(&mut self, duration_us: f64);

    /// Sampled unexpected-message-queue length (the MPICH pvar).
    fn on_umq_sample(&mut self, length: usize);

    /// Called in the `MPI_Finalize` wrapper with total time — where the
    /// whole machine-learning step happens in the paper.
    fn on_finalize(&mut self, session: &mut Session, total_time_us: f64);
}

/// No-op hooks: the runtime without AITuning attached (the PMPI shim
/// composes with these when tuning is disabled).
#[derive(Debug, Default)]
pub struct NullHooks;

impl PmpiHooks for NullHooks {
    fn before_init(&mut self, _: &mut Session) {}
    fn after_init(&mut self, _: &mut Session) {}
    fn on_win_flush(&mut self, _: f64) {}
    fn on_put(&mut self, _: f64) {}
    fn on_get(&mut self, _: f64) {}
    fn on_umq_sample(&mut self, _: usize) {}
    fn on_finalize(&mut self, _: &mut Session, _: f64) {}
}

/// The interposition layer: owns the session and dispatches wrappers.
pub struct PmpiLayer<'h> {
    pub session: Session,
    hooks: &'h mut dyn PmpiHooks,
}

impl<'h> PmpiLayer<'h> {
    pub fn new(hooks: &'h mut dyn PmpiHooks) -> PmpiLayer<'h> {
        PmpiLayer { session: Session::new(), hooks }
    }

    /// The `MPI_Init_thread` wrapper: hooks before and after PMPI init.
    pub fn mpi_init_thread(&mut self) -> Result<(), super::session::SessionError> {
        self.hooks.before_init(&mut self.session);
        self.session.init()?;
        self.hooks.after_init(&mut self.session);
        Ok(())
    }

    pub fn record_win_flush(&mut self, duration_us: f64) {
        self.hooks.on_win_flush(duration_us);
    }

    pub fn record_put(&mut self, duration_us: f64) {
        self.hooks.on_put(duration_us);
    }

    pub fn record_get(&mut self, duration_us: f64) {
        self.hooks.on_get(duration_us);
    }

    pub fn record_umq_sample(&mut self, length: usize) {
        self.hooks.on_umq_sample(length);
    }

    /// The `MPI_Finalize` wrapper.
    pub fn mpi_finalize(
        &mut self,
        total_time_us: f64,
    ) -> Result<(), super::session::SessionError> {
        self.session.finalize()?;
        self.hooks.on_finalize(&mut self.session, total_time_us);
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::cvar::CvarId;

    #[derive(Default)]
    struct Recorder {
        events: Vec<String>,
    }

    impl PmpiHooks for Recorder {
        fn before_init(&mut self, session: &mut Session) {
            // AITuning sets cvars here — must still be legal.
            session.cvar_write(CvarId(0), 1).unwrap();
            self.events.push("before_init".into());
        }
        fn after_init(&mut self, session: &mut Session) {
            assert!(session.create_pvar_session().is_ok());
            self.events.push("after_init".into());
        }
        fn on_win_flush(&mut self, d: f64) {
            self.events.push(format!("flush {d}"));
        }
        fn on_put(&mut self, _: f64) {}
        fn on_get(&mut self, _: f64) {}
        fn on_umq_sample(&mut self, _: usize) {}
        fn on_finalize(&mut self, _: &mut Session, t: f64) {
            self.events.push(format!("finalize {t}"));
        }
    }

    #[test]
    fn wrapper_ordering_matches_listing1() {
        let mut hooks = Recorder::default();
        {
            let mut layer = PmpiLayer::new(&mut hooks);
            layer.mpi_init_thread().unwrap();
            assert!(layer.session.effective_cvars().async_progress());
            layer.record_win_flush(3.5);
            layer.mpi_finalize(100.0).unwrap();
        }
        assert_eq!(hooks.events, vec!["before_init", "after_init", "flush 3.5", "finalize 100"]);
    }
}
