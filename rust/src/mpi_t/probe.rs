//! Probes: validated readers of performance values (§5.1, Listing 2/3).
//!
//! "This class makes sure that the performance variables read using
//! MPI_T or any other way (user defined included), respect certain
//! criteria, like datatype, precision, and range."

use std::fmt;

use super::pvar::{PvarClass, PvarDescriptor};

/// Probe validation failure.
#[derive(Debug, Clone, PartialEq)]
pub enum ProbeError {
    OutOfRange { name: &'static str, value: f64, lo: f64, hi: f64 },
    NonFinite { name: &'static str },
    NotIntegral { name: &'static str, value: f64 },
}

impl fmt::Display for ProbeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProbeError::OutOfRange { name, value, lo, hi } => {
                write!(f, "pvar {name}: value {value} outside range [{lo}, {hi}]")
            }
            ProbeError::NonFinite { name } => write!(f, "pvar {name}: non-finite value"),
            ProbeError::NotIntegral { name, value } => {
                write!(f, "pvar {name}: counter/level must be integral, got {value}")
            }
        }
    }
}

impl std::error::Error for ProbeError {}

/// A probe bound to one pvar descriptor.
#[derive(Debug, Clone)]
pub struct Probe {
    pub descriptor: PvarDescriptor,
    accepted: usize,
    rejected: usize,
}

impl Probe {
    pub fn new(descriptor: PvarDescriptor) -> Probe {
        Probe { descriptor, accepted: 0, rejected: 0 }
    }

    /// Validate one observation; returns the value if acceptable.
    pub fn check(&mut self, value: f64) -> Result<f64, ProbeError> {
        let name = self.descriptor.name;
        if !value.is_finite() {
            self.rejected += 1;
            return Err(ProbeError::NonFinite { name });
        }
        let (lo, hi) = self.descriptor.range;
        if value < lo || value > hi {
            self.rejected += 1;
            return Err(ProbeError::OutOfRange { name, value, lo, hi });
        }
        if matches!(self.descriptor.class, PvarClass::Level | PvarClass::Counter)
            && value.fract() != 0.0
        {
            self.rejected += 1;
            return Err(ProbeError::NotIntegral { name, value });
        }
        self.accepted += 1;
        Ok(value)
    }

    pub fn accepted(&self) -> usize {
        self.accepted
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::pvar::MPICH_PVARS;

    #[test]
    fn accepts_valid_timer() {
        let mut p = Probe::new(MPICH_PVARS[1].clone());
        assert_eq!(p.check(12.5), Ok(12.5));
        assert_eq!(p.accepted(), 1);
    }

    #[test]
    fn rejects_out_of_range_and_nan() {
        let mut p = Probe::new(MPICH_PVARS[1].clone());
        assert!(matches!(p.check(-1.0), Err(ProbeError::OutOfRange { .. })));
        assert!(matches!(p.check(f64::NAN), Err(ProbeError::NonFinite { .. })));
        assert_eq!(p.rejected(), 2);
    }

    #[test]
    fn level_must_be_integral() {
        let mut p = Probe::new(MPICH_PVARS[0].clone()); // unexpected_recvq_length
        assert_eq!(p.check(3.0), Ok(3.0));
        assert!(matches!(p.check(3.5), Err(ProbeError::NotIntegral { .. })));
    }
}
