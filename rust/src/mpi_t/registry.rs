//! Variable discovery: the MPI_T introspection entry point.
//!
//! MPI_T deliberately leaves the variable set implementation-specific
//! (§4: "it is not possible to define variables that all MPI
//! implementations must provide"); discovery is how a tool learns what a
//! given library exposes. [`VariableRegistry`] is that discovery surface.

use anyhow::{bail, Result};

use crate::backend::BackendId;

use super::cvar::{CvarDescriptor, CvarId, MPICH_CVARS};
use super::pvar::{PvarDescriptor, MPICH_PVARS};

/// Discovery interface over one library's MPI_T variables.
pub trait VariableRegistry {
    /// `MPI_T_cvar_get_num`-alike.
    fn num_cvars(&self) -> usize;

    /// `MPI_T_cvar_get_info`-alike.
    fn cvar_info(&self, index: usize) -> Option<&CvarDescriptor>;

    /// Look a cvar up by name (tools address variables by name since
    /// indices are implementation-specific).
    fn cvar_by_name(&self, name: &str) -> Option<&CvarDescriptor>;

    fn num_pvars(&self) -> usize;

    fn pvar_info(&self, index: usize) -> Option<&PvarDescriptor>;

    fn pvar_by_name(&self, name: &str) -> Option<&PvarDescriptor>;
}

/// MPICH-3.2.1's registry.
#[derive(Debug, Default)]
pub struct MpichRegistry;

impl VariableRegistry for MpichRegistry {
    fn num_cvars(&self) -> usize {
        MPICH_CVARS.len()
    }

    fn cvar_info(&self, index: usize) -> Option<&CvarDescriptor> {
        MPICH_CVARS.get(index)
    }

    fn cvar_by_name(&self, name: &str) -> Option<&CvarDescriptor> {
        MPICH_CVARS.iter().find(|d| d.name == name)
    }

    fn num_pvars(&self) -> usize {
        MPICH_PVARS.len()
    }

    fn pvar_info(&self, index: usize) -> Option<&PvarDescriptor> {
        MPICH_PVARS.get(index)
    }

    fn pvar_by_name(&self, name: &str) -> Option<&PvarDescriptor> {
        MPICH_PVARS.iter().find(|d| d.name == name)
    }
}

/// Registry over any backend's variable tables — the discovery surface
/// a [`crate::backend::TunableRuntime`] exposes.
#[derive(Debug, Clone, Copy)]
pub struct BackendRegistry(pub BackendId);

impl VariableRegistry for BackendRegistry {
    fn num_cvars(&self) -> usize {
        self.0.cvars().len()
    }

    fn cvar_info(&self, index: usize) -> Option<&CvarDescriptor> {
        self.0.cvars().get(index)
    }

    fn cvar_by_name(&self, name: &str) -> Option<&CvarDescriptor> {
        self.0.cvars().iter().find(|d| d.name == name)
    }

    fn num_pvars(&self) -> usize {
        self.0.runtime().pvars().len()
    }

    fn pvar_info(&self, index: usize) -> Option<&PvarDescriptor> {
        self.0.runtime().pvars().get(index)
    }

    fn pvar_by_name(&self, name: &str) -> Option<&PvarDescriptor> {
        self.0.runtime().pvars().iter().find(|d| d.name == name)
    }
}

/// Resolve a registry for a communication layer string, as
/// `AITuning_start("MPICH")` does in the paper (Listing 1).
pub fn registry_for(layer: &str) -> Result<Box<dyn VariableRegistry>> {
    match layer {
        "MPICH" => Ok(Box::new(MpichRegistry)),
        "MPICH-collectives" => Ok(Box::new(BackendRegistry(BackendId::Collectives))),
        other => bail!(
            "no MPI_T registry for layer {other:?} (supported: MPICH, MPICH-collectives); \
             GASNet and OpenMPI collections are future work in the paper"
        ),
    }
}

/// Registry for a backend id (CLI cvar lookups).
pub fn registry_for_backend(backend: BackendId) -> BackendRegistry {
    BackendRegistry(backend)
}

/// Convenience: the CvarId for a cvar name, via the MPICH registry.
pub fn cvar_id(name: &str) -> Option<CvarId> {
    MpichRegistry.cvar_by_name(name).map(|d| d.id)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn discovery_counts() {
        let r = MpichRegistry;
        assert_eq!(r.num_cvars(), 6);
        assert_eq!(r.num_pvars(), 5);
        assert!(r.cvar_info(5).is_some());
        assert!(r.cvar_info(6).is_none());
    }

    #[test]
    fn lookup_by_name() {
        let r = MpichRegistry;
        let d = r.cvar_by_name("MPIR_CVAR_CH3_EAGER_MAX_MSG_SIZE").unwrap();
        assert_eq!(d.id, CvarId(5));
        assert!(r.pvar_by_name("unexpected_recvq_length").is_some());
        assert!(r.cvar_by_name("NOPE").is_none());
    }

    #[test]
    fn registry_for_layers() {
        assert!(registry_for("MPICH").is_ok());
        assert!(registry_for("MPICH-collectives").is_ok());
        assert!(registry_for("GASNet").is_err());
    }

    #[test]
    fn backend_registry_discovers_collective_variables() {
        let r = registry_for_backend(BackendId::Collectives);
        assert_eq!(r.num_cvars(), 4);
        assert_eq!(r.num_pvars(), 5);
        let d = r.cvar_by_name("MPIR_CVAR_BCAST_INTRA_ALGORITHM").unwrap();
        assert_eq!(d.id, CvarId(0));
        assert!(r.pvar_by_name("allreduce_time_us").is_some());
        assert!(r.cvar_by_name("MPIR_CVAR_ASYNC_PROGRESS").is_none());
        // The coarrays backend registry agrees with the historical
        // MPICH registry.
        let c = registry_for_backend(BackendId::Coarrays);
        assert_eq!(c.num_cvars(), MpichRegistry.num_cvars());
        assert_eq!(c.num_pvars(), MpichRegistry.num_pvars());
    }

    #[test]
    fn cvar_id_helper() {
        assert_eq!(cvar_id("MPIR_CVAR_ASYNC_PROGRESS"), Some(CvarId(0)));
        assert_eq!(cvar_id("NOPE"), None);
    }
}
