//! Collections: the per-implementation bundles of control + performance
//! variables (§5.1): "a specific CollectionCreator is instantiated ...
//! The actual collection (in our case MPICHCollectionCreator) has
//! predefined lists of control and performance variables".

use super::cvar::{CvarDescriptor, MPICH_CVARS};
use super::probe::Probe;
use super::pvar::{PvarDescriptor, PvarStats, UserDefinedPvar, MPICH_PVARS};
use crate::metrics::stats::Summary;

/// A live collection for one run: descriptors + probes + observations.
#[derive(Debug)]
pub struct Collection {
    pub layer: String,
    pub cvars: Vec<CvarDescriptor>,
    pub pvars: Vec<UserDefinedPvar>,
    pub probes: Vec<Probe>,
}

impl Collection {
    /// Record a validated observation for pvar `idx`.
    pub fn register(&mut self, idx: usize, value: f64) -> bool {
        match self.probes[idx].check(value) {
            Ok(v) => {
                self.pvars[idx].register_value(v);
                true
            }
            Err(_) => false,
        }
    }

    /// End-of-run statistics for every pvar, in registry order (§5.1:
    /// collected in the `MPI_Finalize` wrapper).
    pub fn finalize_stats(&self) -> PvarStats {
        PvarStats {
            summaries: self
                .pvars
                .iter()
                .map(|p| (p.descriptor.id, p.summarize()))
                .collect(),
        }
    }

    /// Reset observations for the next run (probes keep their counters).
    pub fn reset(&mut self) {
        for p in &mut self.pvars {
            p.reset();
        }
    }

    /// Per-pvar summaries paired with names (reporting).
    pub fn named_summaries(&self) -> Vec<(&'static str, Summary)> {
        self.pvars
            .iter()
            .map(|p| (p.descriptor.name, p.summarize()))
            .collect()
    }
}

/// Factory trait: one implementation per communication library.
pub trait CollectionCreator {
    /// Library name this creator handles (e.g. "MPICH").
    fn layer(&self) -> &'static str;

    /// Predefined cvar list.
    fn control_variables(&self) -> Vec<CvarDescriptor>;

    /// Predefined pvar list.
    fn performance_variables(&self) -> Vec<PvarDescriptor>;

    /// Build a live collection with probes attached.
    fn create(&self) -> Collection {
        let pvars: Vec<UserDefinedPvar> = self
            .performance_variables()
            .into_iter()
            .map(UserDefinedPvar::new)
            .collect();
        let probes = pvars.iter().map(|p| Probe::new(p.descriptor.clone())).collect();
        Collection {
            layer: self.layer().to_string(),
            cvars: self.control_variables(),
            pvars,
            probes,
        }
    }
}

/// The MPICH-3.2.1 collection creator from the paper.
#[derive(Debug, Default)]
pub struct MpichCollectionCreator;

impl CollectionCreator for MpichCollectionCreator {
    fn layer(&self) -> &'static str {
        "MPICH"
    }

    fn control_variables(&self) -> Vec<CvarDescriptor> {
        MPICH_CVARS.to_vec()
    }

    fn performance_variables(&self) -> Vec<PvarDescriptor> {
        MPICH_PVARS.to_vec()
    }
}

/// Collection creator for the collectives backend: algorithm-selector
/// cvars plus per-collective-class timing pvars.
#[derive(Debug, Default)]
pub struct CollectivesCollectionCreator;

impl CollectionCreator for CollectivesCollectionCreator {
    fn layer(&self) -> &'static str {
        "MPICH-collectives"
    }

    fn control_variables(&self) -> Vec<CvarDescriptor> {
        super::cvar::COLLECTIVE_CVARS.to_vec()
    }

    fn performance_variables(&self) -> Vec<PvarDescriptor> {
        super::pvar::COLLECTIVE_PVARS.to_vec()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn mpich_collection_has_paper_variables() {
        let c = MpichCollectionCreator.create();
        assert_eq!(c.layer, "MPICH");
        assert_eq!(c.cvars.len(), 6);
        assert_eq!(c.pvars.len(), 5);
        assert_eq!(c.probes.len(), 5);
        let names: Vec<_> = c.cvars.iter().map(|d| d.name).collect();
        assert!(names.contains(&"MPIR_CVAR_POLLS_BEFORE_YIELD"));
    }

    #[test]
    fn collectives_collection_has_backend_variables() {
        let c = CollectivesCollectionCreator.create();
        assert_eq!(c.layer, "MPICH-collectives");
        assert_eq!(c.cvars.len(), 4);
        assert_eq!(c.pvars.len(), 5);
        assert_eq!(c.probes.len(), 5);
        let names: Vec<_> = c.cvars.iter().map(|d| d.name).collect();
        assert!(names.contains(&"MPIR_CVAR_ALLREDUCE_INTRA_ALGORITHM"));
        assert!(c.pvars.iter().any(|p| p.descriptor.name == "bcast_time_us"));
    }

    #[test]
    fn register_validates_through_probe() {
        let mut c = MpichCollectionCreator.create();
        assert!(c.register(1, 5.0)); // flush time, valid
        assert!(!c.register(1, -2.0)); // negative time rejected
        let stats = c.finalize_stats();
        assert_eq!(stats.summaries[1].1.count, 1);
    }

    #[test]
    fn reset_clears_observations() {
        let mut c = MpichCollectionCreator.create();
        c.register(2, 1.0);
        c.reset();
        assert_eq!(c.finalize_stats().summaries[2].1.count, 0);
    }
}
