//! Tunable runtimes (backends): the seam that makes AITuning
//! library-agnostic.
//!
//! The paper's central design claim is that "AITuning has been designed
//! to be utilized with different run-time libraries" (§3). Everything
//! that is specific to *one* library — which control variables exist
//! (and their domains and tuning steps), which performance variables
//! are observed, how the RL state vector is laid out, how large the
//! action space is, and how an instrumented episode actually executes —
//! lives behind the [`TunableRuntime`] trait. The RL layers above
//! (controller, agents, replay, hub, campaign engine) are
//! dimension-generic and consume only this interface.
//!
//! Two backends ship today:
//!
//! * [`coarrays`] — the paper's scenario: OpenCoarrays over MPICH-3.2.1
//!   one-sided communication, six cvars (§5.3), five pvars, the
//!   18-feature state compiled into the AOT artifacts.
//! * [`collectives`] — MPI collective-algorithm selection, the scenario
//!   studied by Hunold & Carpen-Amarie (arXiv:1707.09965) and surveyed
//!   by Wickramasinghe & Lumsdaine (arXiv:1611.06334): categorical
//!   cvars pick broadcast/allreduce algorithms, an integer cvar sets
//!   the pipeline segment size, and episodes run an analytic model over
//!   the [`crate::simmpi::collective`] cost functions.
//!
//! Action-space derivation is shared: `1 + 2 × num_cvars` step actions
//! (no-op, per-cvar up/down) plus one *enumerated-choice* action per
//! option of every categorical cvar (see
//! [`crate::coordinator::actions::num_actions`]).

pub mod coarrays;
pub mod collectives;

pub use coarrays::CoarraysRuntime;
pub use collectives::CollectivesRuntime;

use anyhow::Result;

use crate::coordinator::relative::RelativeTracker;
use crate::coordinator::EpisodeResult;
use crate::mpi_t::{CvarDescriptor, CvarSet, PvarDescriptor, PvarStats};
use crate::simmpi::Machine;
use crate::workloads::WorkloadKind;

/// Identity of a tunable runtime. `Ord` follows declaration order;
/// [`BackendId::ordinal`] is the dense index into [`BackendId::ALL`]
/// (digest/fingerprint key, like [`WorkloadKind::ordinal`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum BackendId {
    /// OpenCoarrays / MPICH one-sided communication (the paper's §5).
    #[default]
    Coarrays,
    /// MPI collective-algorithm selection over the simmpi cost models.
    Collectives,
}

impl BackendId {
    pub const ALL: [BackendId; 2] = [BackendId::Coarrays, BackendId::Collectives];

    pub fn name(self) -> &'static str {
        match self {
            BackendId::Coarrays => "coarrays",
            BackendId::Collectives => "collectives",
        }
    }

    /// Dense index in [`BackendId::ALL`].
    pub fn ordinal(self) -> usize {
        match self {
            BackendId::Coarrays => 0,
            BackendId::Collectives => 1,
        }
    }

    pub fn parse(s: &str) -> Option<BackendId> {
        match s.to_ascii_lowercase().as_str() {
            "coarrays" | "coarray" | "caf" | "mpich" => Some(BackendId::Coarrays),
            "collectives" | "collective" | "coll" => Some(BackendId::Collectives),
            _ => None,
        }
    }

    /// The backend's [`TunableRuntime`] singleton.
    pub fn runtime(self) -> &'static dyn TunableRuntime {
        match self {
            BackendId::Coarrays => &CoarraysRuntime,
            BackendId::Collectives => &CollectivesRuntime,
        }
    }

    /// The backend's control-variable registry. Delegates to the
    /// runtime so the table has exactly one source of truth — a drift
    /// between this accessor and [`TunableRuntime::cvars`] would make
    /// ε-greedy draws and action decoding disagree.
    pub fn cvars(self) -> &'static [CvarDescriptor] {
        self.runtime().cvars()
    }

    pub fn num_cvars(self) -> usize {
        self.cvars().len()
    }

    /// Derived action-space size (see [`crate::coordinator::actions`]).
    pub fn num_actions(self) -> usize {
        crate::coordinator::actions::num_actions(self.cvars())
    }

    /// The backend's RL state-vector width.
    pub fn state_dim(self) -> usize {
        self.runtime().state_dim()
    }
}

impl std::fmt::Display for BackendId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One tunable run-time library, as seen by the RL layers.
///
/// A runtime owns the cvar registry (descriptors, domains, steps), the
/// pvar schema, the state-vector layout, the derived action space, and
/// episode execution. Implementations must be pure: two calls to
/// [`TunableRuntime::run_episode`] with identical arguments return
/// bit-identical results (the campaign engine's worker-count-invariance
/// contract rests on this).
pub trait TunableRuntime: Sync {
    /// This runtime's identity.
    ///
    /// Determinism: constant — `ALL[id().ordinal()] == id()` always.
    fn id(&self) -> BackendId;

    /// Communication-layer name, as `AITuning_start(layer)` receives it.
    ///
    /// Determinism: constant for the lifetime of the process.
    fn layer(&self) -> &'static str;

    /// Control variables this runtime exposes (registry order).
    ///
    /// Determinism: a `'static` table — registry order is declaration
    /// order, never hash order, so action decoding is stable.
    fn cvars(&self) -> &'static [CvarDescriptor];

    /// Performance variables this runtime observes (registry order).
    /// Index 4 is total application time by convention
    /// ([`crate::mpi_t::TOTAL_TIME_PVAR`]).
    ///
    /// Determinism: a `'static` table in declaration order.
    fn pvars(&self) -> &'static [PvarDescriptor];

    /// RL state-vector width (flows into Q-net construction and the
    /// tabular discretizer).
    ///
    /// Determinism: constant for the lifetime of the process.
    fn state_dim(&self) -> usize;

    /// Derived action count: `1 + 2 × num_cvars` plus the enumerated
    /// choice actions of categorical cvars.
    ///
    /// Determinism: pure function of the `'static` cvar table.
    fn num_actions(&self) -> usize {
        crate::coordinator::actions::num_actions(self.cvars())
    }

    /// The workloads a training campaign covers by default.
    ///
    /// Determinism: a `'static` table in declaration order.
    fn training_workloads(&self) -> &'static [WorkloadKind];

    /// Build the state vector for one observed run (length must equal
    /// [`TunableRuntime::state_dim`]).
    ///
    /// Determinism: pure function of the arguments — no clocks, no
    /// ambient randomness, no hash iteration; identical inputs produce
    /// bit-identical vectors on every host and worker count.
    #[allow(clippy::too_many_arguments)]
    fn build_state(
        &self,
        stats: &PvarStats,
        reference: &RelativeTracker,
        cvars: &CvarSet,
        machine: &Machine,
        images: usize,
        run_index: usize,
        eager_fraction: f64,
    ) -> Vec<f32>;

    /// Execute one instrumented episode. `workload_seed` fixes the
    /// problem instance; `run_seed` varies run-to-run noise.
    ///
    /// Determinism: pure function of the arguments — two calls with
    /// identical arguments return bit-identical results (the campaign
    /// engine's worker-count-invariance contract rests on this).
    #[allow(clippy::too_many_arguments)]
    fn run_episode(
        &self,
        kind: WorkloadKind,
        images: usize,
        machine: &Machine,
        cvars: &CvarSet,
        noise: f64,
        workload_seed: u64,
        run_seed: u64,
    ) -> Result<EpisodeResult>;

    /// Reward for one run against the reference (§5.1 by default: the
    /// clipped relative total-time improvement).
    ///
    /// Determinism: pure function of the two times, computed in `f64`.
    fn reward(&self, reference_us: f64, total_us: f64) -> f64 {
        crate::coordinator::reward::reward(reference_us, total_us)
    }
}

/// Scale feature shared by the backends: `log2(images)` normalized by
/// the machine's testbed capacity instead of a baked-in constant. The
/// feature reaches 1.0 exactly at [`Machine::max_images`], so a larger
/// testbed raises its declared capacity rather than inheriting the old
/// hard-coded 2048-image ceiling; like the legacy `/ 11.0` form, runs
/// driven *past* the declared capacity exceed 1.0 rather than being
/// clamped (both presets declare 2048, so the value is bit-identical
/// to the legacy normalization — pinned by a property test).
pub fn scale_feature(images: usize, machine: &Machine) -> f32 {
    let ceiling = (machine.max_images.max(2) as f64).log2() as f32;
    (images.max(1) as f64).log2() as f32 / ceiling
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn backend_ids_round_trip() {
        for b in BackendId::ALL {
            assert_eq!(BackendId::parse(b.name()), Some(b));
            assert_eq!(BackendId::ALL[b.ordinal()], b);
            assert_eq!(b.runtime().id(), b);
        }
        assert_eq!(BackendId::parse("nope"), None);
        assert_eq!(BackendId::default(), BackendId::Coarrays);
    }

    #[test]
    fn runtime_tables_are_consistent() {
        for b in BackendId::ALL {
            let rt = b.runtime();
            assert_eq!(rt.cvars().len(), b.num_cvars());
            assert!(rt.state_dim() > 0);
            assert!(rt.num_actions() >= 1 + 2 * b.num_cvars());
            assert!(!rt.training_workloads().is_empty());
            // Index 4 is total time in every pvar schema (the
            // RelativeTracker/reward contract).
            assert_eq!(rt.pvars()[crate::mpi_t::TOTAL_TIME_PVAR.0].name, "total_time_us");
            for (i, d) in rt.cvars().iter().enumerate() {
                assert_eq!(d.id.0, i, "{b}: cvar table out of order");
            }
            for (i, d) in rt.pvars().iter().enumerate() {
                assert_eq!(d.id.0, i, "{b}: pvar table out of order");
            }
        }
    }

    #[test]
    fn scale_feature_derives_ceiling_from_machine() {
        let cheyenne = Machine::cheyenne();
        // 2048 images on the 2048-image testbed saturates the feature
        // exactly — the historical `log2/11` value, now derived.
        assert!((scale_feature(2048, &cheyenne) - 1.0).abs() < 1e-6);
        assert!(scale_feature(64, &cheyenne) < scale_feature(2048, &cheyenne));
        // A larger testbed stretches the axis instead of clipping.
        let mut big = Machine::cheyenne();
        big.max_images = 8192;
        assert!(scale_feature(8192, &big) <= 1.0 + 1e-6);
        assert!(scale_feature(2048, &big) < 1.0);
    }
}
