//! The collectives runtime: tuning MPI collective-algorithm selection.
//!
//! The scenario of Hunold & Carpen-Amarie's performance-guidelines work
//! (arXiv:1707.09965) and the Wickramasinghe & Lumsdaine survey
//! (arXiv:1611.06334): the right broadcast/allreduce algorithm depends
//! on message size, scale and topology, and MPI implementations expose
//! the choice through MPI_T cvars. This backend's cvars are two
//! *categorical* algorithm selectors (which contribute enumerated
//! [`crate::coordinator::Action::Select`] actions on top of the
//! step/no-op block), a pipeline segment-size integer and the SMP
//! hierarchy toggle; episodes run an analytic model over the
//! [`crate::simmpi::collective`] cost functions rather than the
//! discrete-event engine — collective phases are bulk-synchronous, so
//! their cost composes additively per step.
//!
//! Episode execution is a pure function of `(workload_seed, run_seed,
//! cvars, machine, images)`, which is what lets the campaign engine's
//! 1-vs-N-worker fingerprint identity extend to this backend unchanged.

use anyhow::Result;

use crate::coordinator::relative::RelativeTracker;
use crate::coordinator::EpisodeResult;
use crate::mpi_t::{
    CollectionCreator, CollectivesCollectionCreator, CvarDescriptor, CvarId, CvarSet,
    PvarDescriptor, PvarId, PvarStats, TOTAL_TIME_PVAR,
};
use crate::simmpi::collective::{
    allreduce_alg_us, barrier_us, bcast_alg_us, AllreduceAlgorithm, BcastAlgorithm,
};
use crate::simmpi::{Machine, RunStats, SimConfig};
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

use super::{scale_feature, BackendId, TunableRuntime};

/// Collectives state feature count: six relative collective timers,
/// two squashed payload levels, the relative total, scale, four
/// normalized cvars and the run index.
pub const STATE_DIM: usize = 15;

/// Cvar registry positions (see [`crate::mpi_t::COLLECTIVE_CVARS`]).
const BCAST_ALG: CvarId = CvarId(0);
const ALLREDUCE_ALG: CvarId = CvarId(1);
const SEGMENT_SIZE: CvarId = CvarId(2);
const SMP: CvarId = CvarId(3);

/// Per-step collective signature of one workload at one scale — the
/// problem-instance template the episode model executes.
#[derive(Debug, Clone, Copy)]
struct CollectiveSchedule {
    steps: usize,
    bcast_bytes: u64,
    allreduce_bytes: u64,
    allreduces_per_step: usize,
    compute_us: f64,
}

/// Every workload has *some* collective signature; the PRK collectives
/// kernel is the collective-dominated one this backend trains on, the
/// others contribute lighter mixes (useful for stratified-replay
/// campaigns across workloads).
fn schedule_for(kind: WorkloadKind) -> CollectiveSchedule {
    match kind {
        // The collective-heavy kernel's parameters come from the CAF
        // skeleton itself (one source of truth): the coarrays engine
        // and this analytic model must describe the same problem.
        WorkloadKind::PrkCollectives => {
            let k = crate::workloads::prk::Collectives::default();
            CollectiveSchedule {
                steps: k.steps,
                bcast_bytes: k.bcast_bytes,
                allreduce_bytes: k.allreduce_bytes,
                allreduces_per_step: k.allreduces_per_step,
                compute_us: k.compute_us,
            }
        }
        WorkloadKind::PrkTranspose => CollectiveSchedule {
            steps: 8,
            bcast_bytes: 128 * 1024,
            allreduce_bytes: 64 * 1024,
            allreduces_per_step: 1,
            compute_us: 220.0,
        },
        WorkloadKind::LatticeBoltzmann => CollectiveSchedule {
            steps: 12,
            bcast_bytes: 32 * 1024,
            allreduce_bytes: 96 * 1024,
            allreduces_per_step: 2,
            compute_us: 260.0,
        },
        // Halo-exchange codes: small parameter broadcasts, one global
        // residual reduction per step.
        _ => CollectiveSchedule {
            steps: 10,
            bcast_bytes: 16 * 1024,
            allreduce_bytes: 8 * 1024,
            allreduces_per_step: 1,
            compute_us: 300.0,
        },
    }
}

/// The collective-algorithm-selection tunable runtime.
#[derive(Debug, Default, Clone, Copy)]
pub struct CollectivesRuntime;

/// Squash a byte count into ~[0, 1] (1 GiB ≈ 0.7).
fn squash_bytes(v: f64) -> f32 {
    ((1.0 + v.max(0.0)).ln() / 30.0).min(1.0) as f32
}

impl TunableRuntime for CollectivesRuntime {
    fn id(&self) -> BackendId {
        BackendId::Collectives
    }

    fn layer(&self) -> &'static str {
        "MPICH-collectives"
    }

    fn cvars(&self) -> &'static [CvarDescriptor] {
        crate::mpi_t::COLLECTIVE_CVARS
    }

    fn pvars(&self) -> &'static [PvarDescriptor] {
        crate::mpi_t::COLLECTIVE_PVARS
    }

    fn state_dim(&self) -> usize {
        STATE_DIM
    }

    fn training_workloads(&self) -> &'static [WorkloadKind] {
        &[
            WorkloadKind::PrkCollectives,
            WorkloadKind::PrkTranspose,
            WorkloadKind::LatticeBoltzmann,
        ]
    }

    fn build_state(
        &self,
        stats: &PvarStats,
        reference: &RelativeTracker,
        cvars: &CvarSet,
        machine: &Machine,
        images: usize,
        run_index: usize,
        _eager_fraction: f64,
    ) -> Vec<f32> {
        let mut s = vec![0.0f32; STATE_DIM];
        let zero = crate::metrics::stats::Summary::default();
        let get = |id: usize| stats.get(PvarId(id)).copied().unwrap_or(zero);

        // 0-5: per-collective-class timers, relative to the reference.
        let bcast = get(0);
        s[0] = reference.relative(PvarId(0), bcast.mean) as f32;
        s[1] = reference.relative_max(PvarId(0), bcast.max) as f32;
        let allreduce = get(1);
        s[2] = reference.relative(PvarId(1), allreduce.mean) as f32;
        s[3] = reference.relative_max(PvarId(1), allreduce.max) as f32;
        let barrier = get(2);
        s[4] = reference.relative(PvarId(2), barrier.mean) as f32;
        s[5] = reference.relative_max(PvarId(2), barrier.max) as f32;
        // 6-7: payload sizes (absolute level pvar, squashed).
        let payload = get(3);
        s[6] = squash_bytes(payload.mean);
        s[7] = squash_bytes(payload.max);
        // 8: total time, relative (the reward's sibling).
        s[8] = reference.relative(TOTAL_TIME_PVAR, get(4).max) as f32;
        // 9: scale, normalized by the machine's testbed capacity.
        s[9] = scale_feature(images, machine);
        // 10-13: current cvar values (normalized).
        s[10..14].copy_from_slice(&cvars.normalized());
        // 14: tuning progress.
        s[14] = (run_index as f32 / 20.0).min(2.0);

        for (i, v) in s.iter().enumerate() {
            debug_assert!(v.is_finite(), "collectives state feature {i} not finite");
        }
        s
    }

    fn run_episode(
        &self,
        kind: WorkloadKind,
        images: usize,
        machine: &Machine,
        cvars: &CvarSet,
        noise: f64,
        workload_seed: u64,
        run_seed: u64,
    ) -> Result<EpisodeResult> {
        anyhow::ensure!(
            cvars.backend() == BackendId::Collectives,
            "collectives episode needs a collectives cvar set, got {}",
            cvars.backend()
        );
        let p = images.max(2);
        let sched = schedule_for(kind);
        // Problem instance: per-step payload jitter fixed by the
        // workload seed (the *same application* across tuning runs).
        let mut wl_rng = Rng::new(workload_seed);
        let step_payloads: Vec<(u64, u64)> = (0..sched.steps)
            .map(|_| {
                let jb = 0.75 + 0.5 * wl_rng.f64();
                let ja = 0.75 + 0.5 * wl_rng.f64();
                (
                    ((sched.bcast_bytes as f64 * jb) as u64).max(64),
                    ((sched.allreduce_bytes as f64 * ja) as u64).max(64),
                )
            })
            .collect();

        let bcast_alg = BcastAlgorithm::from_cvar(cvars.get(BCAST_ALG));
        let allreduce_alg = AllreduceAlgorithm::from_cvar(cvars.get(ALLREDUCE_ALG));
        let segment = cvars.get(SEGMENT_SIZE).max(1) as u64;
        let smp = cvars.get(SMP) != 0;
        // The cost functions read machine/scale from SimConfig and take
        // the algorithm explicitly — they never consult `cfg.cvars`.
        let cfg = SimConfig::new(machine.clone(), cvars.clone(), images);

        let mut collection = CollectivesCollectionCreator.create();
        let mut run_rng = Rng::new(run_seed);
        let mut noisy = |mean: f64| (mean * (1.0 + noise * run_rng.normal())).max(0.0);

        let mut total = 0.0f64;
        let mut bytes_sent = 0u64;
        let mut calls = 0u64;
        for &(bcast_bytes, allreduce_bytes) in &step_payloads {
            let t_bcast = noisy(bcast_alg_us(&cfg, p, bcast_bytes, bcast_alg, segment, smp));
            collection.register(0, t_bcast);
            collection.register(3, bcast_bytes as f64);
            total += t_bcast;
            bytes_sent += bcast_bytes;
            calls += 1;
            for _ in 0..sched.allreduces_per_step {
                let t_ar =
                    noisy(allreduce_alg_us(&cfg, p, allreduce_bytes, allreduce_alg, smp));
                collection.register(1, t_ar);
                collection.register(3, allreduce_bytes as f64);
                total += t_ar;
                bytes_sent += allreduce_bytes;
                calls += 1;
            }
            let t_barrier = noisy(barrier_us(&cfg, p));
            collection.register(2, t_barrier);
            total += t_barrier;
            calls += 1;
            total += noisy(sched.compute_us);
        }
        collection.register(4, total);
        let pvars = collection.finalize_stats();

        let raw = RunStats {
            total_time_us: total,
            collectives: calls,
            bytes_sent,
            ..RunStats::default()
        };
        Ok(EpisodeResult { total_time_us: total, pvars, eager_fraction: 0.0, raw })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn episode(cvars: &CvarSet, images: usize, run_seed: u64) -> EpisodeResult {
        CollectivesRuntime
            .run_episode(
                WorkloadKind::PrkCollectives,
                images,
                &Machine::cheyenne(),
                cvars,
                0.0,
                42,
                run_seed,
            )
            .unwrap()
    }

    /// The known-good configuration for large-payload collectives at
    /// scale: scatter+allgather broadcast, ring allreduce, SMP on.
    fn hand_tuned() -> CvarSet {
        let mut cv = CvarSet::defaults(BackendId::Collectives);
        cv.set(BCAST_ALG, 1);
        cv.set(ALLREDUCE_ALG, 1);
        cv.set(SMP, 1);
        cv
    }

    #[test]
    fn episode_is_deterministic_and_fully_instrumented() {
        let cv = CvarSet::defaults(BackendId::Collectives);
        let a = episode(&cv, 64, 1);
        let b = episode(&cv, 64, 1);
        assert_eq!(a.total_time_us.to_bits(), b.total_time_us.to_bits());
        assert!(a.total_time_us > 0.0);
        for id in 0..5 {
            assert!(a.pvars.get(PvarId(id)).is_some(), "pvar {id} missing");
        }
        assert!((a.pvars.total_time_us().unwrap() - a.total_time_us).abs() < 1e-9);
        assert_eq!(a.raw.collectives, 10 * 4); // bcast + 2 allreduce + barrier
    }

    #[test]
    fn noise_varies_by_run_seed_only() {
        let cv = CvarSet::defaults(BackendId::Collectives);
        let rt = CollectivesRuntime;
        let m = Machine::cheyenne();
        let a = rt
            .run_episode(WorkloadKind::PrkCollectives, 32, &m, &cv, 0.05, 7, 1)
            .unwrap();
        let b = rt
            .run_episode(WorkloadKind::PrkCollectives, 32, &m, &cv, 0.05, 7, 2)
            .unwrap();
        assert_ne!(a.total_time_us, b.total_time_us);
    }

    #[test]
    fn tuned_algorithms_beat_the_default_on_the_collective_heavy_workload() {
        // The landscape the backend exists to expose: binomial bcast +
        // recursive-doubling allreduce (MPICH defaults) lose clearly to
        // scatter/allgather + ring + SMP on 1 MiB-class payloads at
        // scale.
        let default = episode(&CvarSet::defaults(BackendId::Collectives), 128, 1);
        let tuned = episode(&hand_tuned(), 128, 1);
        assert!(
            tuned.total_time_us < default.total_time_us * 0.85,
            "tuned {} vs default {}",
            tuned.total_time_us,
            default.total_time_us
        );
    }

    #[test]
    fn state_vector_reflects_the_schema() {
        let cv = CvarSet::defaults(BackendId::Collectives);
        let m = Machine::cheyenne();
        let r = episode(&cv, 64, 1);
        let mut tracker = RelativeTracker::for_backend(BackendId::Collectives);
        tracker.record_reference(&r.pvars);
        let s = CollectivesRuntime.build_state(&r.pvars, &tracker, &cv, &m, 64, 0, 0.0);
        assert_eq!(s.len(), STATE_DIM);
        // Reference run: all relative features are exactly zero.
        for i in [0, 1, 2, 3, 4, 5, 8] {
            assert_eq!(s[i], 0.0, "feature {i}");
        }
        assert!(s[6] > 0.0 && s[6] <= 1.0, "payload feature {}", s[6]);
        // A faster follow-up run shows positive relatives.
        let faster = episode(&hand_tuned(), 64, 1);
        let s2 =
            CollectivesRuntime.build_state(&faster.pvars, &tracker, &hand_tuned(), &m, 64, 3, 0.0);
        assert!(s2[8] > 0.0, "total-time relative must be positive: {}", s2[8]);
        assert!(s.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn every_workload_has_a_schedule() {
        for kind in WorkloadKind::ALL {
            let cv = CvarSet::defaults(BackendId::Collectives);
            let r = CollectivesRuntime
                .run_episode(kind, 16, &Machine::edison(), &cv, 0.0, 1, 1)
                .unwrap();
            assert!(r.total_time_us > 0.0, "{}", kind.name());
        }
    }

    #[test]
    fn rejects_a_foreign_cvar_set() {
        let err = CollectivesRuntime.run_episode(
            WorkloadKind::PrkCollectives,
            16,
            &Machine::cheyenne(),
            &CvarSet::vanilla(), // coarrays registry
            0.0,
            1,
            1,
        );
        assert!(err.is_err());
    }
}
