//! The coarrays runtime — the paper's scenario (§5): OpenCoarrays over
//! MPICH-3.2.1 one-sided communication.
//!
//! State layout (must match `python/compile/model.py`, which AOT-bakes
//! these dimensions into the Q-network artifacts): the MPICH
//! `unexpected_recvq_length` pvar, user-defined timing pvars
//! (win_flush / put / get averages and maxima), total application
//! time, the number of processes, the current normalized
//! control-variable values and the run index.

use anyhow::Result;

use crate::coordinator::episode;
use crate::coordinator::relative::RelativeTracker;
use crate::coordinator::EpisodeResult;
use crate::metrics::stats::Summary;
use crate::mpi_t::{CvarDescriptor, CvarSet, PvarDescriptor, PvarId, PvarStats};
use crate::simmpi::Machine;
use crate::workloads::WorkloadKind;

use super::{scale_feature, BackendId, TunableRuntime};

/// Coarrays state feature count (compiled into the AOT artifacts).
pub const STATE_DIM: usize = 18;

/// Coarrays action count: 6 cvars × {up, down} + no-op.
pub const NUM_ACTIONS: usize = 13;

/// Compress a non-negative magnitude into ~[0, 1] smoothly.
fn squash(v: f64) -> f32 {
    ((1.0 + v.max(0.0)).ln() / 10.0).min(1.0) as f32
}

/// Build the 18-feature state vector for the Q-network.
///
/// Time-like pvars are *relative* (§5.1): expressed as the improvement
/// fraction vs the reference run, so positive = faster than reference.
/// The scale feature's ceiling derives from the machine description
/// ([`Machine::max_images`]) instead of a baked-in 2048-image constant.
#[allow(clippy::too_many_arguments)]
pub fn build_state(
    stats: &PvarStats,
    reference: &RelativeTracker,
    cvars: &CvarSet,
    machine: &Machine,
    images: usize,
    run_index: usize,
    eager_fraction: f64,
) -> Vec<f32> {
    let mut s = vec![0.0f32; STATE_DIM];
    let zero = Summary::default();
    let get = |id: usize| stats.get(PvarId(id)).copied().unwrap_or(zero);

    // 0-1: unexpected queue (absolute level pvar, squashed)
    let umq = get(0);
    s[0] = squash(umq.mean);
    s[1] = squash(umq.max);
    // 2-7: flush/put/get timers, relative to reference
    let flush = get(1);
    s[2] = reference.relative(PvarId(1), flush.mean) as f32;
    s[3] = reference.relative_max(PvarId(1), flush.max) as f32;
    let put = get(2);
    s[4] = reference.relative(PvarId(2), put.mean) as f32;
    s[5] = reference.relative_max(PvarId(2), put.max) as f32;
    let getp = get(3);
    s[6] = reference.relative(PvarId(3), getp.mean) as f32;
    s[7] = reference.relative_max(PvarId(3), getp.max) as f32;
    // 8: total time, relative (the reward's sibling)
    let total = get(4);
    s[8] = reference.relative(PvarId(4), total.max) as f32;
    // 9: scale, normalized by the machine's testbed capacity
    s[9] = scale_feature(images, machine);
    // 10-15: current cvar values (normalized)
    s[10..16].copy_from_slice(&cvars.normalized());
    // 16: tuning progress
    s[16] = (run_index as f32 / 20.0).min(2.0);
    // 17: protocol mix actually used
    s[17] = eager_fraction as f32;

    for (i, v) in s.iter().enumerate() {
        debug_assert!(v.is_finite(), "state feature {i} not finite");
    }
    s
}

/// The paper's tunable runtime.
#[derive(Debug, Default, Clone, Copy)]
pub struct CoarraysRuntime;

impl TunableRuntime for CoarraysRuntime {
    fn id(&self) -> BackendId {
        BackendId::Coarrays
    }

    fn layer(&self) -> &'static str {
        "MPICH"
    }

    fn cvars(&self) -> &'static [CvarDescriptor] {
        crate::mpi_t::MPICH_CVARS
    }

    fn pvars(&self) -> &'static [PvarDescriptor] {
        crate::mpi_t::MPICH_PVARS
    }

    fn state_dim(&self) -> usize {
        STATE_DIM
    }

    fn training_workloads(&self) -> &'static [WorkloadKind] {
        &WorkloadKind::TRAINING
    }

    fn build_state(
        &self,
        stats: &PvarStats,
        reference: &RelativeTracker,
        cvars: &CvarSet,
        machine: &Machine,
        images: usize,
        run_index: usize,
        eager_fraction: f64,
    ) -> Vec<f32> {
        build_state(stats, reference, cvars, machine, images, run_index, eager_fraction)
    }

    fn run_episode(
        &self,
        kind: WorkloadKind,
        images: usize,
        machine: &Machine,
        cvars: &CvarSet,
        noise: f64,
        workload_seed: u64,
        run_seed: u64,
    ) -> Result<EpisodeResult> {
        episode::run_episode(kind, images, machine, cvars, noise, workload_seed, run_seed)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn constants_match_the_derived_layout() {
        let rt = CoarraysRuntime;
        assert_eq!(rt.state_dim(), STATE_DIM);
        assert_eq!(rt.num_actions(), NUM_ACTIONS);
        assert_eq!(crate::coordinator::actions::num_actions(rt.cvars()), NUM_ACTIONS);
    }

    fn stats_with(total: f64) -> PvarStats {
        PvarStats {
            summaries: vec![
                (PvarId(0), Summary::of(&[2.0, 4.0])),
                (PvarId(1), Summary::of(&[10.0])),
                (PvarId(2), Summary::of(&[5.0])),
                (PvarId(3), Summary::of(&[1.0])),
                (PvarId(4), Summary::of(&[total])),
            ],
        }
    }

    #[test]
    fn reference_run_gives_zero_relatives() {
        let stats = stats_with(1000.0);
        let mut reference = RelativeTracker::new();
        reference.record_reference(&stats);
        let m = Machine::cheyenne();
        let s = build_state(&stats, &reference, &CvarSet::vanilla(), &m, 256, 0, 0.5);
        assert_eq!(s.len(), STATE_DIM);
        assert_eq!(s[2], 0.0);
        assert_eq!(s[8], 0.0);
        assert!(s[0] > 0.0);
        assert_eq!(s[17], 0.5);
    }

    #[test]
    fn faster_run_has_positive_relative_total() {
        let reference_stats = stats_with(1000.0);
        let mut reference = RelativeTracker::new();
        reference.record_reference(&reference_stats);
        let m = Machine::cheyenne();
        let s =
            build_state(&stats_with(800.0), &reference, &CvarSet::vanilla(), &m, 256, 3, 0.0);
        assert!(s[8] > 0.0, "improvement must be positive: {}", s[8]);
        let worse =
            build_state(&stats_with(1500.0), &reference, &CvarSet::vanilla(), &m, 256, 3, 0.0);
        assert!(worse[8] < 0.0);
    }

    #[test]
    fn images_scale_feature() {
        let stats = stats_with(1.0);
        let mut r = RelativeTracker::new();
        r.record_reference(&stats);
        let m = Machine::cheyenne();
        let s64 = build_state(&stats, &r, &CvarSet::vanilla(), &m, 64, 0, 0.0);
        let s2048 = build_state(&stats, &r, &CvarSet::vanilla(), &m, 2048, 0, 0.0);
        assert!(s64[9] < s2048[9]);
        assert!((s2048[9] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn scale_ceiling_follows_the_machine_description() {
        // Satellite fix pin: a larger testbed must stretch the scale
        // axis (the old `/ 11.0` constant pushed the feature past 1.0
        // for anything beyond 2048 images on any machine).
        let stats = stats_with(1.0);
        let mut r = RelativeTracker::new();
        r.record_reference(&stats);
        let mut big = Machine::cheyenne();
        big.max_images = 32_768; // hypothetical larger deployment
        let s = build_state(&stats, &r, &CvarSet::vanilla(), &big, 32_768, 0, 0.0);
        assert!((s[9] - 1.0).abs() < 1e-6, "full machine must sit at 1.0: {}", s[9]);
        let mid = build_state(&stats, &r, &CvarSet::vanilla(), &big, 2048, 0, 0.0);
        assert!(mid[9] < 1.0, "2048 images is mid-scale on a 32k machine: {}", mid[9]);
    }
}
