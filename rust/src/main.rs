//! `aituning` — the leader binary.
//!
//! Subcommands:
//!   tune        run the §5 tuning loop on one workload/scale
//!   run         one instrumented episode under a given configuration
//!   campaign    the §6 multi-workload training campaign
//!   convergence the §5.5 synthetic-model convergence study
//!   sweep       1-D sweep of one cvar (e.g. POLLS_BEFORE_YIELD, §6.2)
//!   baselines   random/evolutionary/human baselines on a workload
//!
//! Run with no arguments for usage.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use aituning::backend::BackendId;
use aituning::baselines::{human_tuned, Evolutionary, RandomSearch, Searcher};
use aituning::campaign::{
    ablation_table, job_grid, CampaignConfig, CampaignEngine, CampaignJob, EvalSpec,
    SpillOptions, SpillRun, SpilledReport,
};
use aituning::convergence::{run_convergence, ConvergenceConfig, SyntheticModel};
use aituning::coordinator::{
    AgentKind, Controller, HubLrSchedule, MergeMode, ReplayPolicyKind, SharedLearning, SyncMode,
    TuningConfig,
};
use aituning::mpi_t::{registry_for_backend, CvarId, CvarSet, VariableRegistry};
use aituning::simmpi::Machine;
use aituning::util::args::Args;
use aituning::util::bench::Table;
use aituning::workloads::WorkloadKind;

fn usage() -> ! {
    eprintln!(
        "aituning — ML-based tuning for run-time communication libraries
USAGE:
  aituning tune        --workload icar --images 256 [--runs 20]
                       [--agent dqn|dqn-aot|dqn-target|tabular]  (dqn = the native
                       engine, works on every backend; dqn-aot = compiled PJRT
                       artifacts, coarrays layout only)
                       [--machine cheyenne|edison] [--seed N] [--noise F]
                       [--backend coarrays|collectives]
                       [--replay uniform|stratified|prioritized]
  aituning run         --workload icar --images 64 [--cvar NAME=VALUE,NAME=VALUE]
                       [--backend coarrays|collectives]
  aituning campaign    [--images 64,128,256] [--runs-per 20]
                       [--agent dqn|dqn-aot|dqn-target|tabular]
                       [--machine cheyenne|edison|both] [--workers N]  (0 = one per core)
                       [--backend coarrays|collectives]  (which tunable runtime; the
                       workload list defaults to the backend's training set)
                       [--replay uniform|stratified|prioritized]  (replay retention/
                       selection policy; stratified keeps rare workloads resident in
                       the shared hub buffer)
                       [--shared] [--sync-every 5]  (--shared couples the jobs through
                       the LearnerHub and reports the independent-vs-shared ablation)
                       [--merge weights|grads]  (how the hub folds pushes: averaged
                       weights, or A3C-style accumulated gradients + one hub Adam
                       step per round — grads needs the native DQN agent)
                       [--sync-mode sync|async] [--staleness N]  (async drops the
                       round barrier: each segment's push merges the moment it
                       finishes, and the staleness window N bounds how many hub
                       generations any merged push may lag its pull; N=0 is the
                       synchronous schedule by definition. Needs --shared; async
                       does not support --spill-dir/--resume)
                       [--hub-lr-schedule constant|invsqrt[:P]|halving[:P]]
                       [--hub-steps N]  (grads mode's master optimizer: lr decay
                       clocked on cumulative hub Adam steps with period P, and how
                       many Adam steps each merged push applies)
                       [--no-fuse-training]  (disable the fused cross-job training
                       GEMMs of sync rounds; results are bit-identical either way —
                       this only trades away the packed-panel throughput. Needs
                       --shared)
                       [--spill-dir DIR | --resume DIR]  (on-disk campaign store:
                       spill finished jobs to per-shard segments for flat memory, and
                       resume a killed campaign from where it stopped)
                       [--crash-after N]  (testing hook: interrupt the spilled run
                       after N jobs / merge rounds; requires a store dir)
  aituning convergence [--model parabola|coupled|bool] [--noise 0.3] [--runs 400]
  aituning sweep       --cvar MPIR_CVAR_POLLS_BEFORE_YIELD --values 200,1000,1500
                       --workload icar --images 512 [--base async] [--workers N]
                       [--backend coarrays|collectives]
                       [--machine cheyenne|edison|both] [--replay uniform|stratified|prioritized]
                       [--spill-dir DIR | --resume DIR]  (persist the episode cache in
                       a campaign store dir so later sweeps skip repeated episodes)
  aituning baselines   --workload icar --images 256 [--budget 20] [--workers N]
                       [--backend coarrays|collectives]
                       [--replay uniform|stratified|prioritized]
"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("tune") => cmd_tune(&args),
        Some("run") => cmd_run(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("convergence") => cmd_convergence(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("baselines") => cmd_baselines(&args),
        _ => usage(),
    }
}

fn parse_workload(args: &Args) -> Result<WorkloadKind> {
    let name = args.get("workload").context("--workload required")?;
    WorkloadKind::parse(name).with_context(|| format!("unknown workload {name:?}"))
}

fn parse_machine(args: &Args) -> Result<Machine> {
    let name = args.get_or("machine", "cheyenne");
    Machine::by_name(name).with_context(|| format!("unknown machine {name:?}"))
}

/// `--machine cheyenne|edison|both` — multi-machine subcommands lift
/// the machine into the job/spec list so one worker pool spans both
/// testbeds.
fn parse_machines(args: &Args) -> Result<Vec<Machine>> {
    match args.get_or("machine", "cheyenne") {
        "both" | "all" => Ok(vec![Machine::cheyenne(), Machine::edison()]),
        name => Ok(vec![
            Machine::by_name(name).with_context(|| format!("unknown machine {name:?}"))?
        ]),
    }
}

/// `--backend coarrays|collectives` — which tunable runtime to drive.
fn parse_backend(args: &Args) -> Result<BackendId> {
    let name = args.get_or("backend", "coarrays");
    BackendId::parse(name)
        .with_context(|| format!("unknown backend {name:?} (coarrays|collectives)"))
}

/// `--replay uniform|stratified|prioritized` — replay retention and
/// minibatch-selection policy (controller buffers and, under
/// `--shared`, the hub's global buffer).
fn parse_replay(args: &Args) -> Result<ReplayPolicyKind> {
    let name = args.get_or("replay", "uniform");
    ReplayPolicyKind::parse(name)
        .with_context(|| format!("unknown replay policy {name:?} (uniform|stratified|prioritized)"))
}

fn parse_agent(args: &Args) -> Result<AgentKind> {
    let name = args.get_or("agent", "dqn");
    AgentKind::parse(name)
        .with_context(|| format!("unknown agent {name:?} (dqn|dqn-aot|dqn-target|tabular)"))
}

/// `--spill-dir DIR` (create a fresh campaign store) or `--resume DIR`
/// (reopen one); mutually exclusive because resuming reuses the dir
/// the store already lives in. `--crash-after N` only makes sense
/// against a store — an interrupted in-memory campaign keeps nothing.
fn parse_store(args: &Args) -> Result<Option<(PathBuf, SpillOptions)>> {
    let spill = args.get("spill-dir");
    let resume = args.get("resume");
    if spill.is_some() && resume.is_some() {
        bail!("--spill-dir and --resume are mutually exclusive (resume reuses the store's dir)");
    }
    let crash_after = match args.get("crash-after") {
        Some(_) => Some(args.usize_or("crash-after", 0)?),
        None => None,
    };
    let Some(dir) = spill.or(resume) else {
        if crash_after.is_some() {
            bail!("--crash-after requires --spill-dir or --resume");
        }
        return Ok(None);
    };
    Ok(Some((PathBuf::from(dir), SpillOptions { resume: resume.is_some(), crash_after })))
}

/// `--merge weights|grads` — how a shared campaign's hub folds worker
/// pushes into the master state.
fn parse_merge(args: &Args) -> Result<MergeMode> {
    let name = args.get_or("merge", "weights");
    MergeMode::parse(name).with_context(|| format!("unknown merge mode {name:?} (weights|grads)"))
}

/// `--sync-mode sync|async` + `--staleness N` — the shared schedule:
/// round-synchronous barriers, or bounded-staleness asynchronous
/// merges within a window of N hub generations.
fn parse_sync_mode(args: &Args) -> Result<SyncMode> {
    let name = args.get_or("sync-mode", "sync");
    let staleness = args.usize_or("staleness", 4)?;
    let mode = SyncMode::parse(name, staleness)
        .with_context(|| format!("unknown sync mode {name:?} (sync|async)"))?;
    if args.get("staleness").is_some() && !matches!(mode, SyncMode::Async { .. }) {
        bail!("--staleness only applies with --sync-mode async");
    }
    Ok(mode)
}

/// `--hub-lr-schedule constant|invsqrt[:P]|halving[:P]` + `--hub-steps N`
/// — the hub-side Adam schedule for `--merge grads`.
fn parse_hub_schedule(args: &Args) -> Result<HubLrSchedule> {
    let name = args.get_or("hub-lr-schedule", "constant");
    HubLrSchedule::parse(name).with_context(|| {
        format!("unknown hub lr schedule {name:?} (constant|invsqrt[:P]|halving[:P])")
    })
}

fn tuning_config(args: &Args) -> Result<TuningConfig> {
    Ok(TuningConfig {
        machine: parse_machine(args)?,
        backend: parse_backend(args)?,
        agent: parse_agent(args)?,
        runs: args.usize_or("runs", 20)?,
        noise: args.f64_or("noise", 0.02)?,
        seed: args.u64_or("seed", 0)?,
        replay_policy: parse_replay(args)?,
        ..TuningConfig::default()
    })
}

fn cmd_tune(args: &Args) -> Result<()> {
    let kind = parse_workload(args)?;
    let images = args.usize_or("images", 256)?;
    let cfg = tuning_config(args)?;
    let mut ctl = Controller::new(cfg)?;
    println!("tuning {} at {} images with {} agent...", kind.name(), images, ctl.agent_name());
    let out = ctl.tune(kind, images)?;
    println!("\nper-run log:");
    let mut t = Table::new(&["run", "total (µs)", "reward", "action", "eps"]);
    for r in &out.log.runs {
        t.row(vec![
            r.run_index.to_string(),
            format!("{:.0}", r.total_time_us),
            format!("{:+.4}", r.reward),
            r.action
                .map(|a| {
                    aituning::coordinator::Action::from_index(ctl.cfg.backend.cvars(), a)
                        .describe(ctl.cfg.backend.cvars())
                })
                .unwrap_or_else(|| "reference".into()),
            format!("{:.2}", r.epsilon),
        ]);
    }
    t.print();
    println!("\nreference: {:.0} µs", out.reference_us);
    println!("best:      {:.0} µs  ({:+.1}%)", out.best_us, out.improvement() * 100.0);
    println!("best cfg:     {}", out.best);
    println!("ensemble cfg: {}", out.ensemble);
    let ens = ctl.evaluate(kind, images, &out.ensemble, 3)?;
    println!(
        "ensemble eval: {:.0} µs ({:+.1}%)",
        ens,
        (out.reference_us - ens) / out.reference_us * 100.0
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let kind = parse_workload(args)?;
    let images = args.usize_or("images", 64)?;
    let machine = parse_machine(args)?;
    let backend = parse_backend(args)?;
    let registry = registry_for_backend(backend);
    let mut cvars = CvarSet::defaults(backend);
    // --cvar NAME=VALUE[,NAME=VALUE...]
    if let Some(spec) = args.get("cvar") {
        for part in spec.split(',') {
            let (name, value) = part.split_once('=').context("--cvar NAME=VALUE")?;
            let d = registry
                .cvar_by_name(name)
                .with_context(|| format!("unknown cvar {name:?} for backend {backend}"))?;
            cvars.set(d.id, value.parse().context("cvar value must be integer")?);
        }
    }
    let r = backend.runtime().run_episode(
        kind,
        images,
        &machine,
        &cvars,
        args.f64_or("noise", 0.02)?,
        args.u64_or("seed", 42)?,
        args.u64_or("run-seed", 1)?,
    )?;
    println!(
        "backend={backend} workload={} images={images} machine={}",
        kind.name(),
        machine.name
    );
    println!("config: {cvars}");
    println!("total: {:.0} µs", r.total_time_us);
    if backend == BackendId::Coarrays {
        println!(
            "eager/rdv: {}/{}  umq max: {:.0}  flush mean: {:.1} µs  yields: {}",
            r.raw.eager_msgs,
            r.raw.rendezvous_msgs,
            r.raw.umq_summary().max,
            r.raw.flush_summary().mean,
            r.raw.yields
        );
    } else {
        // The collectives model reports per-class pvar statistics.
        for d in backend.runtime().pvars() {
            if let Some(summary) = r.pvars.get(d.id) {
                println!(
                    "{}: mean {:.1}  max {:.1}  (n={})",
                    d.name, summary.mean, summary.max, summary.count
                );
            }
        }
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let images: Vec<usize> = args
        .get_or("images", "64,128,256")
        .split(',')
        .map(|s| s.parse().context("bad --images list"))
        .collect::<Result<_>>()?;
    let machines = parse_machines(args)?;
    let backend = parse_backend(args)?;
    let shared_mode = args.flag("shared");
    let mut base = TuningConfig {
        machine: machines[0].clone(),
        backend,
        agent: parse_agent(args)?,
        runs: args.usize_or("runs-per", 20)?,
        noise: args.f64_or("noise", 0.02)?,
        seed: args.u64_or("seed", 0)?,
        replay_policy: parse_replay(args)?,
        ..TuningConfig::default()
    };
    // Parse the shared-learning flags unconditionally so a typo'd mode
    // (or one of them without --shared, which would otherwise be
    // silently ignored) fails loudly instead of running an unintended
    // campaign.
    let merge = parse_merge(args)?;
    let mode = parse_sync_mode(args)?;
    let hub_lr_schedule = parse_hub_schedule(args)?;
    let hub_steps = args.usize_or("hub-steps", 1)?;
    if shared_mode {
        base.shared = Some(SharedLearning {
            sync_every: args.usize_or("sync-every", 5)?,
            merge,
            mode,
            hub_lr_schedule,
            hub_steps,
        });
    } else {
        for flag in ["merge", "sync-mode", "staleness", "hub-lr-schedule", "hub-steps"] {
            if args.get(flag).is_some() {
                bail!("--{flag} only applies to shared campaigns; add --shared");
            }
        }
        if args.flag("no-fuse-training") {
            bail!("--no-fuse-training only applies to shared campaigns; add --shared");
        }
    }
    let workloads = backend.runtime().training_workloads();
    let jobs = job_grid(backend, &machines, workloads, &images, base.agent, base.seed);
    let engine = CampaignEngine::new(CampaignConfig {
        base,
        workers: args.usize_or("workers", 0)?,
        straggle: None,
        // A pure throughput knob: fused and sequential round bodies are
        // bit-identical per job, so disabling fusion can never change a
        // result — only how long it takes to produce.
        fuse_training: !args.flag("no-fuse-training"),
    });

    if let Some((dir, opts)) = parse_store(args)? {
        return run_campaign_spilled(&engine, &jobs, &dir, shared_mode, &opts);
    }

    if shared_mode {
        // Independent-vs-shared ablation: same jobs, same seeds, the
        // only difference is the LearnerHub coupling.
        let independent = engine.run(&jobs)?;
        let shared = engine.run_shared(&jobs)?;
        ablation_table(&independent, &shared).print();
        let hub = shared.hub.context("shared report carries hub state")?;
        println!(
            "\ngeomean speedup: independent {:.3}x vs shared {:.3}x (sync cadence: {} runs)",
            independent.geomean_speedup(),
            shared.geomean_speedup(),
            engine.config().base.shared.map(|s| s.sync_every).unwrap_or_default(),
        );
        println!("schedule: {mode}");
        println!("hub: {}", hub.describe());
        println!(
            "wall clock: independent {:.2}s, shared {:.2}s on {} workers",
            independent.wall_clock.as_secs_f64(),
            shared.wall_clock.as_secs_f64(),
            shared.workers
        );
        println!(
            "fingerprints: independent {:016x}, shared {:016x}",
            independent.fingerprint(),
            shared.fingerprint()
        );
        return Ok(());
    }

    let report = engine.run(&jobs)?;
    let mut t = Table::new(&[
        "machine", "workload", "images", "reference (µs)", "best (µs)", "improvement",
    ]);
    for r in &report.results {
        t.row(vec![
            r.job.machine.to_string(),
            r.job.workload.name().to_string(),
            r.job.images.to_string(),
            format!("{:.0}", r.outcome.reference_us),
            format!("{:.0}", r.outcome.best_us),
            format!("{:+.1}%", r.outcome.improvement() * 100.0),
        ]);
    }
    t.print();
    println!(
        "\ntotal runs: {} across {} jobs on {} workers in {:.2}s (geomean speedup {:.3}x)",
        report.total_app_runs(),
        report.results.len(),
        report.workers,
        report.wall_clock.as_secs_f64(),
        report.geomean_speedup()
    );
    println!("fingerprint: {:016x}", report.fingerprint());
    Ok(())
}

/// Campaign through the on-disk store: workers spill each finished job
/// to per-shard segment files, the report streams back from disk, and
/// a killed run resumes from whatever the store already holds.
fn run_campaign_spilled(
    engine: &CampaignEngine,
    jobs: &[CampaignJob],
    dir: &Path,
    shared_mode: bool,
    opts: &SpillOptions,
) -> Result<()> {
    let run = if shared_mode {
        // A store holds exactly one campaign's results, so the
        // in-memory independent-vs-shared ablation leg is skipped
        // here; run without a store dir to see the ablation table.
        println!("spilled shared campaign (ablation leg skipped: one store, one campaign)\n");
        engine.run_shared_spilled(jobs, dir, opts)?
    } else {
        engine.run_spilled(jobs, dir, opts)?
    };
    let report = match run {
        SpillRun::Interrupted { completed, total } => {
            println!(
                "campaign interrupted after {completed}/{total} {}; resume with --resume {}",
                if shared_mode { "rounds" } else { "jobs" },
                dir.display()
            );
            return Ok(());
        }
        SpillRun::Complete(report) => report,
    };
    print_spilled_report(&report);
    Ok(())
}

fn print_spilled_report(report: &SpilledReport) {
    let mut t = Table::new(&[
        "machine", "workload", "images", "reference (µs)", "best (µs)", "improvement",
    ]);
    for r in &report.rows {
        t.row(vec![
            r.job.machine.to_string(),
            r.job.workload.name().to_string(),
            r.job.images.to_string(),
            format!("{:.0}", r.reference_us),
            format!("{:.0}", r.best_us),
            format!("{:+.1}%", r.improvement() * 100.0),
        ]);
    }
    t.print();
    if let Some(hub) = &report.hub {
        println!("\nhub: {}", hub.describe());
    }
    println!(
        "\ntotal runs: {} across {} jobs ({} replayed from the store, {} executed) \
         on {} workers in {:.2}s (geomean speedup {:.3}x)",
        report.total_app_runs(),
        report.rows.len(),
        report.jobs_loaded,
        report.jobs_executed,
        report.workers,
        report.wall_clock.as_secs_f64(),
        report.geomean_speedup()
    );
    println!("fingerprint: {:016x}", report.fingerprint());
}

fn cmd_convergence(args: &Args) -> Result<()> {
    let model = match args.get_or("model", "parabola") {
        "parabola" => SyntheticModel::Parabola { cvar: CvarId(4), best: 2600, curvature: 12.0 },
        "coupled" => SyntheticModel::CoupledParabola {
            int_cvar: CvarId(5),
            bool_cvar: CvarId(0),
            best_off: 131_072,
            // 192 action steps above the default (reachable in-budget).
            best_on: 327_680,
            bool_gain: 0.25,
            curvature: 4.0,
        },
        "bool" => SyntheticModel::BoolStep { cvar: CvarId(0), gain: 0.3 },
        other => bail!("unknown model {other:?}"),
    };
    let cfg = ConvergenceConfig {
        agent: parse_agent(args)?,
        runs: args.usize_or("runs", 400)?,
        noise: args.f64_or("noise", 0.0)?,
        seed: args.u64_or("seed", 0)?,
        ..ConvergenceConfig::default()
    };
    let rep = run_convergence(&model, &cfg)?;
    println!("model: {model:?}");
    println!("noise: {:.0}%  runs: {}", cfg.noise * 100.0, cfg.runs);
    println!("best distance to known optimum: {:.4}", rep.best_distance);
    println!("best mean-time ratio vs optimum: {:.4}", rep.best_ratio);
    println!("best cfg: {}", rep.best_cvars);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let kind = parse_workload(args)?;
    let images = args.usize_or("images", 512)?;
    let machines = parse_machines(args)?;
    let backend = parse_backend(args)?;
    let cvar_name = args.get("cvar").context("--cvar required")?;
    let d = registry_for_backend(backend)
        .cvar_by_name(cvar_name)
        .with_context(|| format!("unknown cvar {cvar_name:?} for backend {backend}"))?
        .clone();
    let values: Vec<i64> = args
        .get("values")
        .context("--values required (comma list)")?
        .split(',')
        .map(|s| s.parse().context("bad value"))
        .collect::<Result<_>>()?;
    let mut base = CvarSet::defaults(backend);
    if backend == BackendId::Coarrays && args.get_or("base", "") == "async" {
        base.set(CvarId(0), 1);
    }
    let reps = args.usize_or("reps", 3)?;

    // Every (machine, sweep point) pair is an independent fixed-config
    // evaluation: one spec list, one worker pool spanning both
    // testbeds, per-episode work items.
    let specs: Vec<EvalSpec> = machines
        .iter()
        .flat_map(|machine| {
            values.iter().map(|&v| {
                let mut cv = base.clone();
                cv.set(d.id, v);
                EvalSpec { machine: machine.clone(), workload: kind, images, cvars: cv }
            })
        })
        .collect();
    let engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig {
            machine: machines[0].clone(),
            backend,
            noise: args.f64_or("noise", 0.02)?,
            seed: args.u64_or("seed", 42)?,
            replay_policy: parse_replay(args)?,
            ..TuningConfig::default()
        },
        workers: args.usize_or("workers", 0)?,
        straggle: None,
        fuse_training: true,
    });

    // Sweeps evaluate fixed configurations — there is no shared
    // learner, so the async schedule cannot apply; reject it loudly
    // rather than silently running a sync-shaped sweep.
    if parse_sync_mode(args)?.runs_async() {
        bail!("--sync-mode async applies to campaign --shared; sweep evaluates fixed configs");
    }

    // --spill-dir and --resume are synonyms here: a sweep has no
    // partial-progress state to recover, only the episode cache, so
    // both just persist it in the store dir's episodes.jsonl.
    let episodes = match parse_store(args)? {
        Some((_, opts)) if opts.crash_after.is_some() => {
            bail!("--crash-after only applies to campaign, not sweep")
        }
        Some((dir, _)) => {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating store dir {}", dir.display()))?;
            let path = dir.join("episodes.jsonl");
            let loaded = engine.cache().load_from(&path)?;
            if loaded > 0 {
                println!("episode cache: loaded {loaded} entries from {}", path.display());
            }
            Some(path)
        }
        None => None,
    };

    let means = engine.evaluate_specs(&specs, reps)?;

    let mut t = Table::new(&["machine", cvar_name, "total (µs)", "vs first"]);
    for (mi, machine) in machines.iter().enumerate() {
        let row0 = mi * values.len();
        let base_t = means[row0];
        for (vi, &v) in values.iter().enumerate() {
            let mean = means[row0 + vi];
            t.row(vec![
                machine.name.to_string(),
                v.to_string(),
                format!("{mean:.0}"),
                format!("{:+.2}%", (base_t - mean) / base_t * 100.0),
            ]);
        }
    }
    t.print();
    if let Some(path) = &episodes {
        engine.cache().save_to(path)?;
        println!(
            "episode cache: {} entries saved to {} ({} hits / {} misses this sweep)",
            engine.cache().len(),
            path.display(),
            engine.cache().hits(),
            engine.cache().misses()
        );
    }
    Ok(())
}

fn cmd_baselines(args: &Args) -> Result<()> {
    let kind = parse_workload(args)?;
    let images = args.usize_or("images", 256)?;
    let budget = args.usize_or("budget", 20)?;
    let cfg = tuning_config(args)?;
    // Scoring runs through the engine: fixed-config evaluations fan out
    // across workers and repeat visits hit the episode cache.
    let engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig { agent: AgentKind::Tabular, ..cfg.clone() },
        workers: args.usize_or("workers", 0)?,
        straggle: None,
        fuse_training: true,
    });

    let backend = cfg.backend;
    let vanilla = engine.evaluate(kind, images, &CvarSet::defaults(backend), 3)?;

    let mut t = Table::new(&["method", "total (µs)", "vs default"]);
    let pct = |v: f64| format!("{:+.1}%", (vanilla - v) / vanilla * 100.0);
    t.row(vec!["default".into(), format!("{vanilla:.0}"), "+0.0%".into()]);
    if backend == BackendId::Coarrays {
        // The paper's §6.2 manual baseline is specific to the eager
        // threshold — a coarrays knob.
        let human = engine.evaluate(kind, images, &human_tuned(), 3)?;
        t.row(vec!["human (eager x10)".into(), format!("{human:.0}"), pct(human)]);
    }

    let mut random = RandomSearch::for_backend(cfg.seed + 1, backend);
    let (_, rand_t) = {
        let mut eval = |cvs: &[CvarSet]| engine.evaluate_batch(kind, images, cvs, 1);
        random.search_batched(budget, &mut eval)?
    };
    t.row(vec!["random".into(), format!("{rand_t:.0}"), pct(rand_t)]);

    let mut evo = Evolutionary::for_backend(cfg.seed + 2, backend);
    let (_, evo_t) = {
        let mut eval = |cvs: &[CvarSet]| engine.evaluate_batch(kind, images, cvs, 1);
        evo.search_batched(budget, &mut eval)?
    };
    t.row(vec!["evolutionary".into(), format!("{evo_t:.0}"), pct(evo_t)]);

    // AITuning itself, same budget, as a one-job campaign.
    let tune_engine = CampaignEngine::new(CampaignConfig {
        base: TuningConfig { runs: budget, ..cfg.clone() },
        workers: 1,
        straggle: None,
        fuse_training: true,
    });
    let report = tune_engine.run(&[CampaignJob {
        backend,
        machine: cfg.machine.name,
        workload: kind,
        images,
        agent: cfg.agent,
        seed: cfg.seed,
    }])?;
    let out = &report.results[0].outcome;
    t.row(vec![
        format!("aituning ({:?})", cfg.agent),
        format!("{:.0}", out.best_us),
        pct(out.best_us),
    ]);
    t.print();
    println!(
        "\nepisode cache: {} entries, {} hits / {} misses",
        engine.cache().len(),
        engine.cache().hits(),
        engine.cache().misses()
    );
    Ok(())
}
