//! RL state layout — must match `python/compile/model.py`.
//!
//! The paper's state (§5.3): the MPICH `unexpected_recvq_length` pvar,
//! user-defined timing pvars (win_flush / put / get averages and
//! maxima), total application time, the number of processes, and (so
//! the agent can tell configurations apart) the current normalized
//! control-variable values plus the run index.

use crate::metrics::stats::Summary;
use crate::mpi_t::{CvarSet, PvarId, PvarStats};

use super::relative::RelativeTracker;

/// State feature count (compiled into the AOT artifacts).
pub const STATE_DIM: usize = 18;

/// Action count: 6 cvars × {up, down} + no-op.
pub const NUM_ACTIONS: usize = 13;

/// Compress a non-negative magnitude into ~[0, 1] smoothly.
fn squash(v: f64) -> f32 {
    ((1.0 + v.max(0.0)).ln() / 10.0).min(1.0) as f32
}

/// Build the 18-feature state vector for the Q-network.
///
/// Time-like pvars are *relative* (§5.1): expressed as the improvement
/// fraction vs the reference run, so positive = faster than reference.
pub fn build_state(
    stats: &PvarStats,
    reference: &RelativeTracker,
    cvars: &CvarSet,
    images: usize,
    run_index: usize,
    eager_fraction: f64,
) -> [f32; STATE_DIM] {
    let mut s = [0.0f32; STATE_DIM];
    let zero = Summary::default();
    let get = |id: usize| stats.get(PvarId(id)).copied().unwrap_or(zero);

    // 0-1: unexpected queue (absolute level pvar, squashed)
    let umq = get(0);
    s[0] = squash(umq.mean);
    s[1] = squash(umq.max);
    // 2-7: flush/put/get timers, relative to reference
    let flush = get(1);
    s[2] = reference.relative(PvarId(1), flush.mean) as f32;
    s[3] = reference.relative_max(PvarId(1), flush.max) as f32;
    let put = get(2);
    s[4] = reference.relative(PvarId(2), put.mean) as f32;
    s[5] = reference.relative_max(PvarId(2), put.max) as f32;
    let getp = get(3);
    s[6] = reference.relative(PvarId(3), getp.mean) as f32;
    s[7] = reference.relative_max(PvarId(3), getp.max) as f32;
    // 8: total time, relative (the reward's sibling)
    let total = get(4);
    s[8] = reference.relative(PvarId(4), total.max) as f32;
    // 9: scale
    s[9] = (images.max(1) as f64).log2() as f32 / 11.0; // 2048 -> 1.0
    // 10-15: current cvar values (normalized)
    let norm = cvars.normalized();
    s[10..16].copy_from_slice(&norm);
    // 16: tuning progress
    s[16] = (run_index as f32 / 20.0).min(2.0);
    // 17: protocol mix actually used
    s[17] = eager_fraction as f32;

    for (i, v) in s.iter().enumerate() {
        debug_assert!(v.is_finite(), "state feature {i} not finite");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::stats::Summary;

    fn stats_with(total: f64) -> PvarStats {
        PvarStats {
            summaries: vec![
                (PvarId(0), Summary::of(&[2.0, 4.0])),
                (PvarId(1), Summary::of(&[10.0])),
                (PvarId(2), Summary::of(&[5.0])),
                (PvarId(3), Summary::of(&[1.0])),
                (PvarId(4), Summary::of(&[total])),
            ],
        }
    }

    #[test]
    fn reference_run_gives_zero_relatives() {
        let stats = stats_with(1000.0);
        let mut reference = RelativeTracker::new();
        reference.record_reference(&stats);
        let s = build_state(&stats, &reference, &CvarSet::vanilla(), 256, 0, 0.5);
        assert_eq!(s[2], 0.0);
        assert_eq!(s[8], 0.0);
        assert!(s[0] > 0.0);
        assert_eq!(s[17], 0.5);
    }

    #[test]
    fn faster_run_has_positive_relative_total() {
        let reference_stats = stats_with(1000.0);
        let mut reference = RelativeTracker::new();
        reference.record_reference(&reference_stats);
        let s = build_state(&stats_with(800.0), &reference, &CvarSet::vanilla(), 256, 3, 0.0);
        assert!(s[8] > 0.0, "improvement must be positive: {}", s[8]);
        let worse = build_state(&stats_with(1500.0), &reference, &CvarSet::vanilla(), 256, 3, 0.0);
        assert!(worse[8] < 0.0);
    }

    #[test]
    fn images_scale_feature() {
        let stats = stats_with(1.0);
        let mut r = RelativeTracker::new();
        r.record_reference(&stats);
        let s64 = build_state(&stats, &r, &CvarSet::vanilla(), 64, 0, 0.0);
        let s2048 = build_state(&stats, &r, &CvarSet::vanilla(), 2048, 0, 0.0);
        assert!(s64[9] < s2048[9]);
        assert!((s2048[9] - 1.0).abs() < 1e-6);
    }
}
