//! Relative performance variables (§5.1).
//!
//! "During the first run, the performance variable declared as relative
//! will maintain in memory the absolute value ... During the other runs,
//! all the values of a relative performance variable are expressed as
//! the difference between the absolute value obtained during the first
//! run and the current absolute value." Positive = improvement.
//!
//! We report the *fraction* `(ref − cur) / ref` rather than the raw
//! difference so features are scale-free across workloads.

use std::collections::HashMap;

use crate::backend::BackendId;
use crate::mpi_t::{PvarId, PvarStats, TOTAL_TIME_PVAR};

/// Reference-run standardization state for relative pvars. Which pvars
/// are *declared relative* comes from the backend's pvar schema.
#[derive(Debug, Clone)]
pub struct RelativeTracker {
    backend: BackendId,
    /// pvar id -> (reference mean, reference max)
    ///
    /// Audited lookup-only (detlint R1): probed with `get`, mutated
    /// with `insert`/`clear` — never iterated, so hash order cannot
    /// reach state vectors or fingerprints.
    reference: HashMap<PvarId, (f64, f64)>,
}

impl Default for RelativeTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl RelativeTracker {
    /// Tracker over the coarrays (paper) pvar schema.
    pub fn new() -> RelativeTracker {
        RelativeTracker::for_backend(BackendId::Coarrays)
    }

    /// Tracker over `backend`'s pvar schema.
    pub fn for_backend(backend: BackendId) -> RelativeTracker {
        RelativeTracker { backend, reference: HashMap::new() }
    }

    /// Record the reference (first) run — `AITUNING_FIRST_RUN=1`.
    pub fn record_reference(&mut self, stats: &PvarStats) {
        let schema = self.backend.runtime().pvars();
        self.reference.clear();
        for (id, summary) in &stats.summaries {
            let relative = schema.get(id.0).map(|d| d.relative).unwrap_or(true);
            if relative {
                self.reference.insert(*id, (summary.mean, summary.max));
            }
        }
    }

    pub fn has_reference(&self) -> bool {
        !self.reference.is_empty()
    }

    /// Relative improvement of a mean value: `(ref − cur)/ref`, clipped
    /// to ±2 so outliers can't blow up the state.
    pub fn relative(&self, id: PvarId, current_mean: f64) -> f64 {
        match self.reference.get(&id) {
            Some(&(reference, _)) if reference.abs() > 1e-12 => {
                ((reference - current_mean) / reference).clamp(-2.0, 2.0)
            }
            _ => 0.0,
        }
    }

    /// Relative improvement of a max value.
    pub fn relative_max(&self, id: PvarId, current_max: f64) -> f64 {
        match self.reference.get(&id) {
            Some(&(_, reference)) if reference.abs() > 1e-12 => {
                ((reference - current_max) / reference).clamp(-2.0, 2.0)
            }
            _ => 0.0,
        }
    }

    /// Reference total time (reward basis), if recorded.
    pub fn reference_total_us(&self) -> Option<f64> {
        self.reference.get(&TOTAL_TIME_PVAR).map(|&(_, max)| max)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::metrics::stats::Summary;

    fn stats(total: f64) -> PvarStats {
        PvarStats { summaries: vec![(PvarId(4), Summary::of(&[total]))] }
    }

    #[test]
    fn improvement_is_positive() {
        let mut r = RelativeTracker::new();
        r.record_reference(&stats(100.0));
        assert!((r.relative_max(PvarId(4), 80.0) - 0.2).abs() < 1e-12);
        assert!(r.relative_max(PvarId(4), 120.0) < 0.0);
        assert_eq!(r.reference_total_us(), Some(100.0));
    }

    #[test]
    fn unknown_pvar_is_zero() {
        let r = RelativeTracker::new();
        assert_eq!(r.relative(PvarId(1), 55.0), 0.0);
        assert!(!r.has_reference());
    }

    #[test]
    fn non_relative_pvars_not_tracked() {
        let mut r = RelativeTracker::new();
        let mut st = stats(100.0);
        st.summaries.push((PvarId(0), Summary::of(&[7.0]))); // UMQ: absolute
        r.record_reference(&st);
        assert_eq!(r.relative(PvarId(0), 3.0), 0.0);
    }

    #[test]
    fn outliers_are_clipped() {
        let mut r = RelativeTracker::new();
        r.record_reference(&stats(1.0));
        assert_eq!(r.relative_max(PvarId(4), 1e9), -2.0);
    }
}
