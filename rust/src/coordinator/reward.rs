//! The reward function (§5.1): based on the *relative*
//! total_execution_time pvar — the improvement fraction over the
//! reference run, clipped to [-1, 1].

/// Reward for a run of `total_us` against the reference `reference_us`.
pub fn reward(reference_us: f64, total_us: f64) -> f64 {
    if reference_us <= 0.0 {
        return 0.0;
    }
    ((reference_us - total_us) / reference_us).clamp(-1.0, 1.0)
}

/// A run is "penalized" (§5.4) if it is slower than the reference by
/// more than this fraction; ensemble inference discards such runs.
pub const PENALTY_THRESHOLD: f64 = 0.0;

/// Did this run penalize performance relative to the reference?
pub fn is_penalized(reference_us: f64, total_us: f64) -> bool {
    reward(reference_us, total_us) < PENALTY_THRESHOLD
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    #[test]
    fn improvement_positive() {
        assert!((reward(100.0, 80.0) - 0.2).abs() < 1e-12);
        assert!(reward(100.0, 130.0) < 0.0);
        assert_eq!(reward(100.0, 100.0), 0.0);
    }

    #[test]
    fn clipping() {
        assert_eq!(reward(100.0, 1e9), -1.0);
        assert_eq!(reward(1e9, 0.0), 1.0);
    }

    #[test]
    fn degenerate_reference() {
        assert_eq!(reward(0.0, 50.0), 0.0);
    }

    #[test]
    fn penalty_detection() {
        assert!(is_penalized(100.0, 101.0));
        assert!(!is_penalized(100.0, 99.0));
    }
}
