//! Experience replay (§3.1/§5.2): uniform random sampling over the
//! accumulated experience breaks temporal correlation. The paper trains
//! on a random subset of the whole experience; we sample uniform
//! minibatches shaped for the AOT train-step artifact.

use crate::runtime::TrainBatch;
use crate::util::rng::Rng;

use super::actions::one_hot;
use super::state::{NUM_ACTIONS, STATE_DIM};

/// One (s, a, r, s', done) experience tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub state: [f32; STATE_DIM],
    pub action: usize,
    pub reward: f32,
    pub next_state: [f32; STATE_DIM],
    pub done: bool,
}

/// Bounded uniform replay buffer.
///
/// `Clone` is part of the shared-learning contract: the hub hands each
/// worker a snapshot of the global buffer at sync points, and a clone
/// reproduces the ring layout exactly (same slot order, same overwrite
/// cursor), so a 1-job shared campaign replays the independent path
/// bit-for-bit.
#[derive(Debug, Clone)]
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    next: usize,
    total_seen: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> ReplayBuffer {
        assert!(capacity > 0);
        ReplayBuffer { buf: Vec::with_capacity(capacity), capacity, next: 0, total_seen: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        assert!(t.action < NUM_ACTIONS);
        self.total_seen += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn total_seen(&self) -> usize {
        self.total_seen
    }

    /// Uniformly sample a minibatch of `batch` transitions (with
    /// replacement if the buffer is smaller than `batch`), shaped for
    /// the `q_train` artifact.
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> TrainBatch {
        assert!(!self.buf.is_empty(), "sampling from empty replay buffer");
        let mut states = Vec::with_capacity(batch * STATE_DIM);
        let mut actions = Vec::with_capacity(batch * NUM_ACTIONS);
        let mut rewards = Vec::with_capacity(batch);
        let mut next_states = Vec::with_capacity(batch * STATE_DIM);
        let mut done = Vec::with_capacity(batch);
        for _ in 0..batch {
            let t = &self.buf[rng.below(self.buf.len() as u64) as usize];
            states.extend_from_slice(&t.state);
            actions.extend_from_slice(&one_hot(t.action));
            rewards.push(t.reward);
            next_states.extend_from_slice(&t.next_state);
            done.push(if t.done { 1.0 } else { 0.0 });
        }
        TrainBatch { states, actions_onehot: actions, rewards, next_states, done }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Stored transitions in ring-slot order (deterministic for a given
    /// push sequence) — used by the hub digest and merge tests.
    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        self.buf.iter()
    }

    /// Most recent transition (per-run immediate training).
    pub fn latest(&self) -> Option<&Transition> {
        if self.buf.len() < self.capacity {
            self.buf.last()
        } else {
            let idx = (self.next + self.capacity - 1) % self.capacity;
            self.buf.get(idx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(reward: f32) -> Transition {
        Transition {
            state: [0.0; STATE_DIM],
            action: 1,
            reward,
            next_state: [0.0; STATE_DIM],
            done: false,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.total_seen(), 5);
        assert_eq!(rb.latest().unwrap().reward, 4.0);
    }

    #[test]
    fn sample_shapes_match_artifact() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..4 {
            rb.push(t(i as f32));
        }
        let mut rng = Rng::new(0);
        let b = rb.sample(32, &mut rng);
        assert!(b.validate(32, STATE_DIM, NUM_ACTIONS).is_ok());
    }

    #[test]
    fn latest_across_fill_and_wrap_boundary() {
        // Walk latest() through every phase: partial fill, the exact
        // moment the buffer becomes full (no overwrite yet), the first
        // overwrite, and wrapping past the end of the ring.
        let mut rb = ReplayBuffer::new(3);
        assert!(rb.latest().is_none());
        rb.push(t(0.0));
        assert_eq!(rb.latest().unwrap().reward, 0.0);
        rb.push(t(1.0));
        rb.push(t(2.0)); // exactly full; next overwrite slot is 0
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.latest().unwrap().reward, 2.0);
        rb.push(t(3.0)); // first overwrite (slot 0)
        assert_eq!(rb.latest().unwrap().reward, 3.0);
        rb.push(t(4.0));
        rb.push(t(5.0)); // fills slot 2; next wraps back to 0
        assert_eq!(rb.latest().unwrap().reward, 5.0);
        rb.push(t(6.0)); // second trip around the ring
        assert_eq!(rb.latest().unwrap().reward, 6.0);
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.total_seen(), 7);
    }

    #[test]
    fn capacity_one_ring() {
        let mut rb = ReplayBuffer::new(1);
        for i in 0..4 {
            rb.push(t(i as f32));
            assert_eq!(rb.latest().unwrap().reward, i as f32);
            assert_eq!(rb.len(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(4);
        let mut rng = Rng::new(0);
        rb.sample(8, &mut rng);
    }
}
