//! One episode = one simulated application run, instrumented exactly the
//! way the paper instruments a real run: the PMPI shim sets control
//! variables before `MPI_Init`, probes register user-defined pvar values
//! during execution, and the `MPI_Finalize` wrapper collects statistics.

use anyhow::{Context, Result};

use crate::coarray::{lower_all, RuntimeOptions};
use crate::mpi_t::{
    Collection, CollectionCreator, CvarSet, MpichCollectionCreator, PmpiHooks, PmpiLayer,
    PvarStats, Session,
};
use crate::simmpi::{Engine, Machine, RunStats, SimConfig};
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

/// Everything observed from one instrumented run.
#[derive(Debug, Clone)]
pub struct EpisodeResult {
    pub total_time_us: f64,
    pub pvars: PvarStats,
    pub eager_fraction: f64,
    pub raw: RunStats,
}

/// AITuning's PMPI hook implementation: owns the MPI_T collection and
/// the cvar set to install before init.
struct TuningHooks {
    install: CvarSet,
    collection: Collection,
    finalized: Option<PvarStats>,
}

impl PmpiHooks for TuningHooks {
    fn before_init(&mut self, session: &mut Session) {
        // AITuning_setControlVariables (Listing 1): before PMPI_Init.
        // The hook signature returns (), mirroring the C shim; a cvar
        // set that fails before init is an unrecoverable config error.
        // detlint: allow(R4) -- PmpiHooks returns (); config failure here cannot be propagated
        session.set_all_cvars(&self.install).expect("cvars set before init");
    }

    fn after_init(&mut self, session: &mut Session) {
        // AITuning_setPerformanceVariables: sessions/handles after init.
        // detlint: allow(R4) -- PmpiHooks returns (); session creation failure here cannot be propagated
        session.create_pvar_session().expect("pvar session after init");
    }

    fn on_win_flush(&mut self, duration_us: f64) {
        self.collection.register(1, duration_us);
    }

    fn on_put(&mut self, duration_us: f64) {
        self.collection.register(2, duration_us);
    }

    fn on_get(&mut self, duration_us: f64) {
        self.collection.register(3, duration_us);
    }

    fn on_umq_sample(&mut self, length: usize) {
        self.collection.register(0, length as f64);
    }

    fn on_finalize(&mut self, _session: &mut Session, total_time_us: f64) {
        self.collection.register(4, total_time_us);
        self.finalized = Some(self.collection.finalize_stats());
    }
}

/// Run one instrumented episode.
///
/// `workload_seed` fixes the problem instance (the *same application*
/// across tuning runs); `run_seed` varies run-to-run noise.
pub fn run_episode(
    kind: WorkloadKind,
    images: usize,
    machine: &Machine,
    cvars: &CvarSet,
    noise: f64,
    workload_seed: u64,
    run_seed: u64,
) -> Result<EpisodeResult> {
    // Build the application (outside MPI, as in reality).
    let mut wl_rng = Rng::new(workload_seed);
    let programs = kind.instantiate().build(images, &mut wl_rng);
    let lowered = lower_all(&programs, &RuntimeOptions::default());

    // PMPI wrapper sequence around the simulated execution.
    let mut hooks = TuningHooks {
        install: cvars.clone(),
        collection: MpichCollectionCreator.create(),
        finalized: None,
    };
    let raw = {
        let mut pmpi = PmpiLayer::new(&mut hooks);
        pmpi.mpi_init_thread()?;

        let effective = pmpi.session.effective_cvars().clone();
        let mut cfg = SimConfig::new(machine.clone(), effective, images);
        cfg.noise = noise;
        cfg.seed = run_seed;
        let raw = Engine::new(cfg, lowered).run();

        // Feed observed values through the probes (Listing 3).
        for &v in &raw.flush_times {
            pmpi.record_win_flush(v);
        }
        for &v in &raw.put_times {
            pmpi.record_put(v);
        }
        for &v in &raw.get_times {
            pmpi.record_get(v);
        }
        for &v in &raw.umq_samples {
            pmpi.record_umq_sample(v as usize);
        }
        pmpi.mpi_finalize(raw.total_time_us)?;
        raw
    };

    let pvars = hooks.finalized.context("finalize populated stats")?;
    Ok(EpisodeResult {
        total_time_us: raw.total_time_us,
        eager_fraction: raw.eager_fraction(),
        pvars,
        raw,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::mpi_t::PvarId;

    #[test]
    fn episode_produces_all_pvars() {
        let r = run_episode(
            WorkloadKind::LatticeBoltzmann,
            4,
            &Machine::cheyenne(),
            &CvarSet::vanilla(),
            0.0,
            42,
            1,
        )
        .unwrap();
        assert!(r.total_time_us > 0.0);
        // All five pvars present, total_time registered once.
        for id in 0..5 {
            assert!(r.pvars.get(PvarId(id)).is_some(), "pvar {id} missing");
        }
        assert_eq!(r.pvars.get(PvarId(4)).unwrap().count, 1);
        assert!((r.pvars.total_time_us().unwrap() - r.total_time_us).abs() < 1e-9);
    }

    #[test]
    fn cvars_flow_through_to_simulation() {
        let mut fast = CvarSet::vanilla();
        fast.set(crate::mpi_t::CvarId(0), 1); // async progress
        let vanilla = run_episode(
            WorkloadKind::Icar, 8, &Machine::cheyenne(), &CvarSet::vanilla(), 0.0, 42, 1,
        )
        .unwrap();
        let tuned =
            run_episode(WorkloadKind::Icar, 8, &Machine::cheyenne(), &fast, 0.0, 42, 1).unwrap();
        assert_ne!(vanilla.total_time_us, tuned.total_time_us);
    }

    #[test]
    fn noise_varies_by_run_seed() {
        let a = run_episode(
            WorkloadKind::LatticeBoltzmann, 4, &Machine::edison(), &CvarSet::vanilla(), 0.05, 7, 1,
        )
        .unwrap();
        let b = run_episode(
            WorkloadKind::LatticeBoltzmann, 4, &Machine::edison(), &CvarSet::vanilla(), 0.05, 7, 2,
        )
        .unwrap();
        assert_ne!(a.total_time_us, b.total_time_us);
    }
}
