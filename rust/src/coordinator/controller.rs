//! The Controller (§5.1): the object the run-time library talks to via
//! the `AITuning_*` surface. Owns the agent, replay buffer, relative-
//! pvar tracker and tuning schedule; drives the run→learn→act loop.

use anyhow::{Context, Result};

use crate::backend::{BackendId, TunableRuntime};
use crate::metrics::recorder::{RunRecord, TuningLog};
use crate::mpi_t::CvarSet;
use crate::runtime::{FusedGrads, TrainBatch};
use crate::simmpi::Machine;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

use super::actions::Action;
use super::agent::{Agent, AgentKind, DqnAgent};
use super::ensemble::ensemble;
use super::hub::{HubContribution, HubLrSchedule, HubView, MergeMode, SyncMode};
use super::relative::RelativeTracker;
use super::replay::{LocalReplay, ReplayPolicyKind, Transition};
use super::tabular::TabularAgent;

/// Shared-learning mode (A3C-style): the controller participates in a
/// [`crate::coordinator::hub::LearnerHub`] campaign, pulling the master
/// state at segment boundaries and recording every new transition (and,
/// in gradient-merge mode, every raw gradient) for the next hub push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharedLearning {
    /// Tuning runs between hub syncs (the merge cadence).
    pub sync_every: usize,
    /// How the hub folds pushes into the master state
    /// (`--merge weights|grads`; grads requires the native DQN agent).
    pub merge: MergeMode,
    /// Round-synchronous (the fingerprint-tested reference) or
    /// bounded-staleness asynchronous (`--sync-mode async
    /// --staleness N`; see `docs/shared_learning.md`).
    pub mode: SyncMode,
    /// Learning-rate schedule of the hub-side Adam steps
    /// ([`MergeMode::Grads`] only; `--hub-lr-schedule`).
    pub hub_lr_schedule: HubLrSchedule,
    /// Hub-side Adam steps per gradient merge (`--hub-steps`;
    /// [`MergeMode::Grads`] only). The default of 1 reproduces the
    /// PR 5 single-step semantics bit-identically.
    pub hub_steps: usize,
}

impl Default for SharedLearning {
    fn default() -> SharedLearning {
        SharedLearning {
            sync_every: 5,
            merge: MergeMode::Weights,
            mode: SyncMode::Sync,
            hub_lr_schedule: HubLrSchedule::Constant,
            hub_steps: 1,
        }
    }
}

/// Tuning hyper-parameters and environment description.
#[derive(Debug, Clone)]
pub struct TuningConfig {
    pub machine: Machine,
    /// Which tunable runtime (backend) this controller drives: the
    /// cvar/pvar registries, state layout, action space and episode
    /// execution all come from it.
    pub backend: BackendId,
    pub agent: AgentKind,
    /// Tuning runs per application (§5.4 recommends ≥ 20).
    pub runs: usize,
    /// ε-greedy exploration: linear from `eps_start` to `eps_end`.
    pub eps_start: f64,
    pub eps_end: f64,
    /// Q-learning discount and Adam learning rate.
    pub gamma: f32,
    pub lr: f32,
    /// Replay buffer capacity and minibatch size.
    pub replay_capacity: usize,
    pub replay_batch: usize,
    /// Replay retention/selection policy (see
    /// [`crate::coordinator::replay`]); also adopted by the hub's
    /// global buffer in shared campaigns.
    pub replay_policy: ReplayPolicyKind,
    /// Full replay refresh cadence (§5.2: every 200 runs).
    pub replay_refresh_every: usize,
    /// Extra minibatches per refresh.
    pub replay_refresh_batches: usize,
    /// Simulator run-to-run noise.
    pub noise: f64,
    /// Master seed.
    pub seed: u64,
    /// Artifacts directory for the DQN agent.
    pub artifacts_dir: std::path::PathBuf,
    /// Shared-learning participation (None = independent session, the
    /// paper's original single-learner loop).
    pub shared: Option<SharedLearning>,
}

impl Default for TuningConfig {
    fn default() -> TuningConfig {
        TuningConfig {
            machine: Machine::cheyenne(),
            backend: BackendId::Coarrays,
            agent: AgentKind::Dqn,
            runs: 20,
            eps_start: 0.8,
            eps_end: 0.05,
            gamma: 0.9,
            lr: 1e-3,
            replay_capacity: 8192,
            replay_batch: 32,
            replay_policy: ReplayPolicyKind::Uniform,
            replay_refresh_every: 200,
            replay_refresh_batches: 8,
            noise: 0.02,
            seed: 0,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            shared: None,
        }
    }
}

/// Result of tuning one application at one scale.
#[derive(Debug, Clone)]
pub struct TuningOutcome {
    pub log: TuningLog,
    /// Configuration of the single best run.
    pub best: CvarSet,
    /// Ensemble configuration (§5.4) — what AITuning ships.
    pub ensemble: CvarSet,
    /// Total time of the reference (vanilla) run.
    pub reference_us: f64,
    /// Best run's total time.
    pub best_us: f64,
}

impl TuningOutcome {
    /// Fractional improvement of the best run over the reference.
    ///
    /// A degenerate reference (zero, negative or non-finite total time)
    /// yields 0.0 instead of NaN/inf, so the value is always safe to
    /// aggregate into campaign reports and benchmark JSON.
    pub fn improvement(&self) -> f64 {
        if !(self.reference_us > 0.0 && self.reference_us.is_finite()) {
            return 0.0;
        }
        (self.reference_us - self.best_us) / self.reference_us
    }
}

/// In-flight state of one tuning session, between
/// [`Controller::begin_session`] and [`Controller::finish_session`].
/// Holding it explicitly (instead of on `tune`'s stack) lets the
/// shared-learning driver interleave segments of many sessions with
/// hub merges; `tune` itself is now begin + one full-length step +
/// finish, so the independent path executes the exact same sequence of
/// RNG draws and episodes it always did.
struct ActiveSession {
    kind: WorkloadKind,
    images: usize,
    workload_seed: u64,
    log: TuningLog,
    tracker: RelativeTracker,
    cvars: CvarSet,
    prev_state: Vec<f32>,
    reference_us: f64,
    /// Next tuning-run index (1-based; run 0 was the reference).
    next_run: usize,
}

/// Bookkeeping stashed between [`Controller::step_run_presampled`] and
/// [`Controller::complete_fused`]: everything the deferred tail of the
/// run needs once the fused trainer hands the gradients back.
struct PendingFused {
    /// Replay slots the presampled minibatch drew (priority feedback).
    picks: Vec<usize>,
    /// The run's log record, not yet pushed.
    record: RunRecord,
    /// The run's resulting RL state, not yet adopted as `prev_state`.
    state: Vec<f32>,
}

/// The AITuning controller.
pub struct Controller {
    pub cfg: TuningConfig,
    agent: Box<dyn Agent>,
    replay: LocalReplay,
    rng: Rng,
    /// Runs executed across the controller's lifetime (drives the
    /// §5.2 every-200-runs replay refresh across applications).
    lifetime_runs: usize,
    /// Session in progress (segmented tuning).
    session: Option<ActiveSession>,
    /// A presampled run awaiting its fused-training completion.
    pending_fused: Option<PendingFused>,
    /// Transitions generated since the last hub push (shared mode
    /// only; stays empty for independent sessions).
    pending: Vec<Transition>,
    /// Did the last hub pull carry a master state? Once it does, a
    /// gradient-merge worker stops shipping full state snapshots — the
    /// hub reads nothing but the gradients after its bootstrap round.
    seen_master: bool,
    /// Precomputed greedy action for the *next* selection, staged by
    /// the campaign round's batched `best_action` path
    /// ([`Controller::stage_greedy_hint`]). Consumed (or invalidated)
    /// by exactly one selection, so a stale hint can never leak into a
    /// later run.
    greedy_hint: Option<usize>,
}

impl Controller {
    /// `AITuning_start`: construct the controller for a layer.
    pub fn new(cfg: TuningConfig) -> Result<Controller> {
        let mut rng = Rng::new(cfg.seed);
        let grads_mode = cfg.shared.is_some_and(|s| s.merge == MergeMode::Grads);
        anyhow::ensure!(
            !grads_mode || cfg.agent == AgentKind::Dqn,
            "gradient-level shared learning (--merge grads) requires the native DQN engine \
             (--agent dqn); the {:?} agent cannot export raw gradients",
            cfg.agent
        );
        let agent: Box<dyn Agent> = match cfg.agent {
            AgentKind::Dqn => {
                let mut agent = DqnAgent::native(cfg.backend, &mut rng);
                if grads_mode {
                    agent.enable_grad_accumulation()?;
                }
                Box::new(agent)
            }
            AgentKind::DqnAot => {
                Box::new(DqnAgent::load(&cfg.artifacts_dir, &mut rng, cfg.backend)?)
            }
            AgentKind::DqnTarget => Box::new(DqnAgent::load_with_mode(
                &cfg.artifacts_dir,
                &mut rng,
                true,
                cfg.backend,
            )?),
            AgentKind::Tabular => Box::new(TabularAgent::new(cfg.backend.num_actions())),
        };
        let replay =
            LocalReplay::for_backend(cfg.replay_capacity, cfg.replay_policy, cfg.backend);
        Ok(Controller {
            cfg,
            agent,
            replay,
            rng,
            lifetime_runs: 0,
            session: None,
            pending_fused: None,
            pending: Vec::new(),
            seen_master: false,
            greedy_hint: None,
        })
    }

    /// The tunable runtime this controller drives.
    pub fn runtime(&self) -> &'static dyn TunableRuntime {
        self.cfg.backend.runtime()
    }

    /// Current exploration rate for tuning-run `i` of `n` (0-based).
    ///
    /// Linear decay from `eps_start` to `eps_end`; the final run always
    /// uses `eps_end` *exactly* (no floating-point residue), and a
    /// single-run schedule (`n == 1`) goes straight to `eps_end` rather
    /// than never decaying.
    fn epsilon(&self, i: usize, n: usize) -> f64 {
        if n <= 1 || i + 1 >= n {
            return self.cfg.eps_end;
        }
        let f = i as f64 / (n - 1) as f64;
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * f
    }

    /// ε-greedy action selection. The RNG draw order is fixed — one
    /// `chance` draw always, one `below` draw on the explore branch —
    /// so a staged greedy hint (which replaces only the Q-value
    /// *computation*, never a draw) cannot shift the random stream.
    fn select_action(&mut self, state: &[f32], eps: f64) -> Result<usize> {
        // Valid for this one selection only, whichever branch wins.
        let hint = self.greedy_hint.take();
        if self.rng.chance(eps) {
            return Ok(self.rng.below(self.cfg.backend.num_actions() as u64) as usize);
        }
        if let Some(h) = hint {
            #[cfg(debug_assertions)]
            {
                let q = self.agent.q_values(state)?;
                debug_assert_eq!(
                    h,
                    crate::runtime::argmax(&q),
                    "staged greedy hint disagrees with the live agent's argmax"
                );
            }
            return Ok(h);
        }
        let q = self.agent.q_values(state)?;
        Ok(crate::runtime::argmax(&q))
    }

    /// ε-greedy selection for a `[batch, state_dim]` matrix of states
    /// through **one** batched forward pass. Draw-for-draw equivalent
    /// to calling [`Controller::select_action`] on each row in order:
    /// the per-row `chance`/`below` draws happen first, in row order,
    /// exactly as the sequential path would make them; only then are
    /// the greedy rows' Q-values computed, as a single
    /// [`Agent::q_values_batch`] call instead of one forward per row.
    pub fn select_actions_batch(
        &mut self,
        states: &[f32],
        batch: usize,
        eps: f64,
    ) -> Result<Vec<usize>> {
        let dim = self.cfg.backend.state_dim();
        let n = self.cfg.backend.num_actions();
        anyhow::ensure!(
            batch > 0 && states.len() == batch * dim,
            "batch states size {} != {batch} x {dim}",
            states.len()
        );
        let mut actions = vec![0usize; batch];
        let mut greedy_rows: Vec<usize> = Vec::new();
        let mut greedy_states: Vec<f32> = Vec::new();
        for r in 0..batch {
            if self.rng.chance(eps) {
                actions[r] = self.rng.below(n as u64) as usize;
            } else {
                greedy_rows.push(r);
                greedy_states.extend_from_slice(&states[r * dim..(r + 1) * dim]);
            }
        }
        if !greedy_rows.is_empty() {
            let q = self.agent.q_values_batch(&greedy_states, greedy_rows.len())?;
            for (k, &r) in greedy_rows.iter().enumerate() {
                actions[r] = crate::runtime::argmax(&q[k * n..(k + 1) * n]);
            }
        }
        Ok(actions)
    }

    /// Stage the precomputed greedy action for this controller's next
    /// selection — the campaign round's batched `best_action` path.
    /// The caller guarantees `hint` is the argmax of the **current**
    /// agent's Q-values at the pending session state (i.e. the batch
    /// was evaluated over exactly the parameters this agent holds);
    /// debug builds re-verify that against the live agent. `None`
    /// clears any leftover hint.
    pub fn stage_greedy_hint(&mut self, hint: Option<usize>) {
        self.greedy_hint = hint;
    }

    /// The pending RL state of the active session — the input of its
    /// next action selection — if a session is open with runs still to
    /// execute. This is what the campaign round batches across jobs
    /// for the shared greedy-selection GEMM.
    pub fn session_state(&self) -> Option<&[f32]> {
        self.session
            .as_ref()
            .filter(|s| s.next_run <= self.cfg.runs)
            .map(|s| s.prev_state.as_slice())
    }

    /// One minibatch: sample, train, and — when the agent reports
    /// realized per-sample TD errors — feed them back into the replay
    /// policy's priority state (adaptive PER; a no-op for priority-free
    /// policies and for agents without a per-sample signal, which keep
    /// the static |reward| proxy).
    fn train_minibatch(&mut self) -> Result<()> {
        let (batch, picks) =
            self.replay.sample_with_picks(self.cfg.replay_batch, &mut self.rng);
        let outcome = self.agent.train(&batch, self.cfg.lr, self.cfg.gamma)?;
        if let Some(td_errors) = &outcome.td_errors {
            for (&pick, &td) in picks.iter().zip(td_errors) {
                self.replay.feedback(pick, td.abs() as f64);
            }
        }
        Ok(())
    }

    /// Train on replay: one minibatch per run, plus the periodic
    /// full-replay refresh (§5.2).
    fn learn(&mut self) -> Result<()> {
        if self.replay.is_empty() {
            return Ok(());
        }
        self.train_minibatch()?;
        if self.lifetime_runs % self.cfg.replay_refresh_every == 0 {
            for _ in 0..self.cfg.replay_refresh_batches {
                self.train_minibatch()?;
            }
        }
        Ok(())
    }

    /// Tune one application at one scale: the full §5 loop.
    pub fn tune(&mut self, kind: WorkloadKind, images: usize) -> Result<TuningOutcome> {
        self.begin_session(kind, images)?;
        self.step_session(self.cfg.runs)?;
        self.finish_session()
    }

    /// Start a tuning session: execute the reference run (run 0,
    /// `AITUNING_FIRST_RUN=1`, vanilla config) and set up the per-
    /// session state. Follow with [`Controller::step_session`] calls
    /// and a [`Controller::finish_session`].
    pub fn begin_session(&mut self, kind: WorkloadKind, images: usize) -> Result<()> {
        anyhow::ensure!(self.session.is_none(), "a tuning session is already in progress");
        let runtime = self.runtime();
        let workload_seed = self.cfg.seed ^ seed_mix(kind, images);
        let mut log = TuningLog::new(kind.name(), images);
        let mut tracker = RelativeTracker::for_backend(self.cfg.backend);
        let cvars = CvarSet::defaults(self.cfg.backend);

        let run_seed = self.rng.next_u64();
        let reference = runtime.run_episode(
            kind, images, &self.cfg.machine, &cvars, self.cfg.noise, workload_seed, run_seed,
        )?;
        tracker.record_reference(&reference.pvars);
        let reference_us = reference.total_time_us;
        self.lifetime_runs += 1;
        log.push(RunRecord {
            run_index: 0,
            cvars: cvars.clone(),
            total_time_us: reference_us,
            reward: 0.0,
            action: None,
            epsilon: 1.0,
            pvars: reference.pvars.clone(),
        });

        let prev_state = runtime.build_state(
            &reference.pvars,
            &tracker,
            &cvars,
            &self.cfg.machine,
            images,
            0,
            reference.eager_fraction,
        );
        self.session = Some(ActiveSession {
            kind,
            images,
            workload_seed,
            log,
            tracker,
            cvars,
            prev_state,
            reference_us,
            next_run: 1,
        });
        Ok(())
    }

    /// Execute up to `max_runs` tuning runs of the active session (the
    /// shared-learning segment size); returns how many ran. The ε
    /// schedule, action selection, replay pushes and training updates
    /// are identical to the monolithic loop — segmentation changes
    /// *when* the caller regains control, never what executes.
    pub fn step_session(&mut self, max_runs: usize) -> Result<usize> {
        anyhow::ensure!(
            self.pending_fused.is_none(),
            "a presampled run is awaiting its fused-training completion"
        );
        let mut session = self.session.take().context("no tuning session in progress")?;
        let total = self.cfg.runs;
        let mut executed = 0;
        while session.next_run <= total && executed < max_runs {
            let (record, state) = self.run_once(&mut session)?;
            self.learn()?;
            session.log.push(record);
            session.prev_state = state;
            session.next_run += 1;
            executed += 1;
        }
        self.session = Some(session);
        Ok(executed)
    }

    /// One tuning run of the active session through the transition
    /// push: selection, episode, reward, state build, replay/pending
    /// push. Returns the run's log record and resulting RL state; the
    /// caller finishes the run (training + bookkeeping) — immediately
    /// in [`Controller::step_session`], deferred across the fused
    /// trainer in [`Controller::step_run_presampled`].
    fn run_once(&mut self, session: &mut ActiveSession) -> Result<(RunRecord, Vec<f32>)> {
        let runtime = self.runtime();
        let total = self.cfg.runs;
        let i = session.next_run;
        let eps = self.epsilon(i - 1, total);
        let action_idx = self.select_action(&session.prev_state, eps)?;
        let action = Action::from_index(runtime.cvars(), action_idx);
        session.cvars = action.apply(&session.cvars);

        let run_seed = self.rng.next_u64();
        let result = runtime.run_episode(
            session.kind,
            session.images,
            &self.cfg.machine,
            &session.cvars,
            self.cfg.noise,
            session.workload_seed,
            run_seed,
        )?;
        let r = runtime.reward(session.reference_us, result.total_time_us);
        self.lifetime_runs += 1;

        let state = runtime.build_state(
            &result.pvars,
            &session.tracker,
            &session.cvars,
            &self.cfg.machine,
            session.images,
            i,
            result.eager_fraction,
        );
        let transition = Transition {
            state: std::mem::take(&mut session.prev_state),
            action: action_idx,
            reward: r as f32,
            next_state: state.clone(),
            done: i == total,
            workload: Some(session.kind),
        };
        if self.cfg.shared.is_some() {
            self.pending.push(transition.clone());
        }
        self.replay.push(transition);

        let record = RunRecord {
            run_index: i,
            cvars: session.cvars.clone(),
            total_time_us: result.total_time_us,
            reward: r,
            action: Some(action_idx),
            epsilon: eps,
            pvars: result.pvars,
        };
        Ok((record, state))
    }

    /// First half of a fused training run: execute one tuning run of
    /// the active session through its transition push, then draw the
    /// training minibatch **at exactly the RNG stream position the
    /// sequential path would draw it** — and hand it to the caller
    /// instead of training on it. The campaign round stacks every
    /// job's batch through [`crate::runtime::FusedTrainer`] and
    /// completes each controller with [`Controller::complete_fused`].
    ///
    /// Determinism: identical draws in identical order to one
    /// `step_session(1)` iteration up to (but excluding) the agent's
    /// own training update, which `complete_fused` replays exactly.
    pub fn step_run_presampled(&mut self) -> Result<TrainBatch> {
        anyhow::ensure!(
            self.pending_fused.is_none(),
            "a presampled run is already awaiting completion"
        );
        let mut session = self.session.take().context("no tuning session in progress")?;
        anyhow::ensure!(
            session.next_run <= self.cfg.runs,
            "session has no tuning runs left to presample"
        );
        let run = self.run_once(&mut session);
        self.session = Some(session);
        let (record, state) = run?;
        // The run's own transition was just pushed, so the buffer can
        // never be empty here — the sequential path's empty-replay
        // early-return is unreachable.
        let (batch, picks) = self.replay.sample_with_picks(self.cfg.replay_batch, &mut self.rng);
        self.pending_fused = Some(PendingFused { picks, record, state });
        Ok(batch)
    }

    /// Second half of a fused training run: apply the gradients the
    /// fused trainer computed for this controller's presampled batch,
    /// then replay the rest of the sequential run tail — priority
    /// feedback from the realized TD errors, the periodic §5.2 replay
    /// refresh (those minibatches train over post-update parameters,
    /// so they are never fused), and the deferred log/state/run-index
    /// bookkeeping.
    ///
    /// Determinism: `step_run_presampled` + `complete_fused` leaves
    /// controller, agent and RNG state bit-identical to the
    /// `step_session(1)` iteration it replaces, because the fused
    /// gradients themselves are bit-identical ([`FusedTrainer`]) and
    /// everything after the gradient computation happens here in the
    /// sequential order.
    ///
    /// [`FusedTrainer`]: crate::runtime::FusedTrainer
    pub fn complete_fused(&mut self, fused: FusedGrads) -> Result<()> {
        let pending =
            self.pending_fused.take().context("no presampled run awaiting completion")?;
        self.agent.apply_train(&fused.grads, fused.loss, self.cfg.lr)?;
        for (&pick, &td) in pending.picks.iter().zip(&fused.td_errors) {
            self.replay.feedback(pick, td.abs() as f64);
        }
        if self.lifetime_runs % self.cfg.replay_refresh_every == 0 {
            for _ in 0..self.cfg.replay_refresh_batches {
                self.train_minibatch()?;
            }
        }
        let session = self.session.as_mut().context("no tuning session in progress")?;
        session.log.push(pending.record);
        session.prev_state = pending.state;
        session.next_run += 1;
        Ok(())
    }

    /// Has the active session executed its full run budget?
    pub fn session_done(&self) -> bool {
        self.session.as_ref().is_some_and(|s| s.next_run > self.cfg.runs)
    }

    /// Close the active session: ensemble inference (§5.4) over the
    /// accumulated log.
    pub fn finish_session(&mut self) -> Result<TuningOutcome> {
        let session = self.session.take().context("no tuning session in progress")?;
        anyhow::ensure!(
            session.next_run > self.cfg.runs,
            "session finished early: {} of {} tuning runs executed",
            session.next_run - 1,
            self.cfg.runs
        );
        let log = session.log;
        let reference_us = session.reference_us;
        let best_rec = log.best_run().context("finished session has an empty run log")?;
        let best = best_rec.cvars.clone();
        let best_us = best_rec.total_time_us;
        // A zero-run session has no tuning records: ship this backend's
        // defaults rather than ensemble()'s coarrays fallback.
        let ensemble_cfg = if log.runs.len() > 1 {
            ensemble(&log.runs[1..], reference_us)
        } else {
            CvarSet::defaults(self.cfg.backend)
        };
        Ok(TuningOutcome { log, best, ensemble: ensemble_cfg, reference_us, best_us })
    }

    /// Pull the hub's master state (shared learning): adopt the merged
    /// agent weights and the global replay snapshot. The snapshot rides
    /// behind an `Arc` ([`crate::coordinator::replay::LocalReplay::adopt`])
    /// — one pointer copy, never a ring clone; new local transitions
    /// accumulate in a fresh tail on top of it. Touches no controller
    /// RNG state, so the local trajectory's randomness is unaffected by
    /// *when* syncs happen.
    pub fn sync_from_hub(&mut self, view: &HubView) -> Result<()> {
        self.agent.sync(view)?;
        if view.master.is_some() {
            self.seen_master = true;
            self.replay.adopt(std::sync::Arc::clone(&view.replay));
        }
        Ok(())
    }

    /// Package this controller's push for the next hub merge: the local
    /// agent state, the replay shard accumulated since the last push
    /// (drained) and — when the agent accumulates them — the segment's
    /// raw gradients (drained; gradient-merge campaigns). Once a
    /// gradient-merge hub has a master, the state snapshot is skipped:
    /// the hub reads only the gradients past its bootstrap round, so
    /// cloning the full params + Adam moments every round would be
    /// pure waste.
    pub fn hub_contribution(&mut self, job_index: usize) -> Result<HubContribution> {
        let grads = self.agent.take_grads();
        let state = if grads.is_some() && self.seen_master {
            None
        } else {
            Some(self.agent.snapshot()?)
        };
        Ok(HubContribution {
            job_index,
            state,
            transitions: std::mem::take(&mut self.pending),
            grads,
        })
    }

    /// Evaluate a fixed configuration (no learning) — used to score the
    /// ensemble config and the baselines.
    pub fn evaluate(
        &mut self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
    ) -> Result<f64> {
        debug_assert_eq!(cvars.backend(), self.cfg.backend);
        let workload_seed = self.cfg.seed ^ seed_mix(kind, images);
        let mut total = 0.0;
        for _ in 0..repeats.max(1) {
            let run_seed = self.rng.next_u64();
            let r = self.runtime().run_episode(
                kind, images, &self.cfg.machine, cvars, self.cfg.noise, workload_seed, run_seed,
            )?;
            total += r.total_time_us;
        }
        Ok(total / repeats.max(1) as f64)
    }

    /// Evaluate a fixed configuration through the campaign engine's
    /// episode cache with *deterministic* per-repeat seeds, so repeated
    /// scoring of the same configuration (ensemble scoring, baselines)
    /// skips re-simulation. Unlike [`Controller::evaluate`] this does
    /// not consume controller RNG state.
    pub fn evaluate_cached(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
        cache: &crate::campaign::EpisodeCache,
    ) -> Result<f64> {
        crate::campaign::evaluate_config(&self.cfg, kind, images, cvars, repeats, Some(cache))
    }

    pub fn agent_name(&self) -> &'static str {
        self.agent.name()
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    /// The controller's replay window (diagnostics: occupancy and
    /// selection-weight inspection — e.g. the adaptive-PER tests).
    pub fn replay(&self) -> &LocalReplay {
        &self.replay
    }

    /// Bounded training-loss diagnostics (ring + running stats).
    pub fn losses(&self) -> &crate::runtime::LossRing {
        self.agent.losses()
    }

    pub fn lifetime_runs(&self) -> usize {
        self.lifetime_runs
    }
}

/// Stable per-(workload, images) seed component: the same application
/// instance is tuned across all of a campaign's runs. Shared with the
/// campaign engine so cached evaluations agree with controller runs.
pub(crate) fn seed_mix(kind: WorkloadKind, images: usize) -> u64 {
    let k = kind.name().bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    k.wrapping_mul(0x9e3779b97f4a7c15) ^ (images as u64).wrapping_mul(0xd1b54a32d192ed03)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;

    fn tabular_cfg() -> TuningConfig {
        TuningConfig {
            agent: AgentKind::Tabular,
            runs: 10,
            noise: 0.01,
            seed: 3,
            ..TuningConfig::default()
        }
    }

    #[test]
    fn tabular_tuning_improves_lbm() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        let out = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();
        assert_eq!(out.log.runs.len(), 11);
        assert!(out.best_us <= out.reference_us * 1.02, "best should not be much worse");
        assert!(ctl.replay_len() == 10);
    }

    #[test]
    fn epsilon_schedule_decays() {
        let ctl = Controller::new(tabular_cfg()).unwrap();
        assert!(ctl.epsilon(0, 20) > ctl.epsilon(19, 20));
        assert!((ctl.epsilon(19, 20) - 0.05).abs() < 1e-9);
    }

    #[test]
    fn epsilon_last_run_is_exactly_eps_end() {
        let ctl = Controller::new(tabular_cfg()).unwrap();
        // Exact equality, not within-epsilon: the schedule must *reach*
        // eps_end on the final run for any run budget.
        assert_eq!(ctl.epsilon(9, 10), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(1, 2), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(0, 2), ctl.cfg.eps_start);
        assert_eq!(ctl.epsilon(19, 20), ctl.cfg.eps_end);
    }

    #[test]
    fn epsilon_single_run_schedule_decays() {
        // Regression: with runs == 1 the old schedule stayed at
        // eps_start forever; the only run is also the last, so it must
        // exploit at eps_end.
        let ctl = Controller::new(tabular_cfg()).unwrap();
        assert_eq!(ctl.epsilon(0, 1), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(0, 0), ctl.cfg.eps_end);
    }

    #[test]
    fn improvement_with_zero_reference_is_clamped() {
        // Regression: reference_us == 0.0 used to propagate NaN/inf
        // silently into benchmark JSON.
        let out = TuningOutcome {
            log: TuningLog::new("x", 1),
            best: CvarSet::vanilla(),
            ensemble: CvarSet::vanilla(),
            reference_us: 0.0,
            best_us: 10.0,
        };
        assert_eq!(out.improvement(), 0.0);
        let nan_ref = TuningOutcome { reference_us: f64::NAN, ..out };
        assert_eq!(nan_ref.improvement(), 0.0);
    }

    #[test]
    fn segmented_session_replays_monolithic_tune_bitwise() {
        // The shared-learning driver steps sessions in small segments;
        // segmentation must not perturb the trajectory at all.
        let mut a = Controller::new(tabular_cfg()).unwrap();
        let out_a = a.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();

        let mut b = Controller::new(tabular_cfg()).unwrap();
        b.begin_session(WorkloadKind::LatticeBoltzmann, 8).unwrap();
        assert!(!b.session_done());
        while !b.session_done() {
            b.step_session(3).unwrap();
        }
        let out_b = b.finish_session().unwrap();

        assert_eq!(out_a.log.runs.len(), out_b.log.runs.len());
        for (ra, rb) in out_a.log.runs.iter().zip(&out_b.log.runs) {
            assert_eq!(ra.total_time_us.to_bits(), rb.total_time_us.to_bits());
            assert_eq!(ra.action, rb.action);
            assert_eq!(ra.cvars, rb.cvars);
        }
        assert_eq!(out_a.best_us.to_bits(), out_b.best_us.to_bits());
        assert_eq!(out_a.ensemble, out_b.ensemble);
    }

    #[test]
    fn session_misuse_is_an_error() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        assert!(ctl.step_session(1).is_err(), "no session begun");
        assert!(ctl.finish_session().is_err(), "no session begun");
        ctl.begin_session(WorkloadKind::LatticeBoltzmann, 4).unwrap();
        assert!(
            ctl.begin_session(WorkloadKind::LatticeBoltzmann, 4).is_err(),
            "double begin"
        );
        assert!(ctl.finish_session().is_err(), "finish before the run budget is spent");
    }

    #[test]
    fn pending_transitions_tracked_only_in_shared_mode() {
        let mut plain = Controller::new(tabular_cfg()).unwrap();
        plain.tune(WorkloadKind::LatticeBoltzmann, 4).unwrap();
        assert!(plain.hub_contribution(0).unwrap().transitions.is_empty());

        let cfg = TuningConfig { shared: Some(SharedLearning::default()), ..tabular_cfg() };
        let mut shared = Controller::new(cfg).unwrap();
        shared.tune(WorkloadKind::LatticeBoltzmann, 4).unwrap();
        let push = shared.hub_contribution(3).unwrap();
        assert_eq!(push.job_index, 3);
        assert_eq!(push.transitions.len(), 10, "one transition per tuning run");
        // The push drains the shard.
        assert!(shared.hub_contribution(3).unwrap().transitions.is_empty());
    }

    #[test]
    fn evaluate_is_deterministic_in_expectation() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        let t = ctl.evaluate(WorkloadKind::LatticeBoltzmann, 4, &CvarSet::vanilla(), 2).unwrap();
        assert!(t > 0.0);
    }

    fn dqn_cfg(seed: u64) -> TuningConfig {
        TuningConfig {
            agent: AgentKind::Dqn,
            runs: 6,
            noise: 0.01,
            seed,
            ..TuningConfig::default()
        }
    }

    #[test]
    fn select_actions_batch_matches_sequential_selection() {
        // Same seed, same states: the batched path must reproduce the
        // sequential path's actions AND leave the RNG stream in the
        // same position at every exploration rate.
        for eps in [0.0, 0.35, 1.0] {
            let mut a = Controller::new(dqn_cfg(17)).unwrap();
            let mut b = Controller::new(dqn_cfg(17)).unwrap();
            let dim = a.cfg.backend.state_dim();
            let batch = 7;
            let states: Vec<f32> =
                (0..batch * dim).map(|i| (i % 13) as f32 / 13.0 - 0.4).collect();
            let batched = a.select_actions_batch(&states, batch, eps).unwrap();
            let sequential: Vec<usize> = (0..batch)
                .map(|r| b.select_action(&states[r * dim..(r + 1) * dim], eps).unwrap())
                .collect();
            assert_eq!(batched, sequential, "eps {eps}");
            assert_eq!(a.rng.next_u64(), b.rng.next_u64(), "RNG streams diverged at eps {eps}");
        }
    }

    #[test]
    fn greedy_hint_is_consumed_once_and_never_leaks() {
        let mut ctl = Controller::new(dqn_cfg(23)).unwrap();
        let state = vec![0.2f32; ctl.cfg.backend.state_dim()];
        let expect = crate::runtime::argmax(&ctl.agent.q_values(&state).unwrap());
        ctl.stage_greedy_hint(Some(expect));
        assert_eq!(ctl.select_action(&state, 0.0).unwrap(), expect);
        assert!(ctl.greedy_hint.is_none(), "hint consumed by its selection");
        // The next selection recomputes from the live agent and agrees.
        assert_eq!(ctl.select_action(&state, 0.0).unwrap(), expect);
        // An explore-branch selection still invalidates the hint.
        ctl.stage_greedy_hint(Some(expect));
        ctl.select_action(&state, 1.0).unwrap();
        assert!(ctl.greedy_hint.is_none(), "hint dropped on the explore branch");
        // Staging None clears an earlier hint.
        ctl.stage_greedy_hint(Some(expect));
        ctl.stage_greedy_hint(None);
        assert!(ctl.greedy_hint.is_none());
    }

    #[test]
    fn hinted_selection_replays_unhinted_selection_bitwise() {
        // A correctly-staged hint must not change the action or the RNG
        // stream relative to the unhinted path.
        let mut hinted = Controller::new(dqn_cfg(41)).unwrap();
        let mut plain = Controller::new(dqn_cfg(41)).unwrap();
        let dim = hinted.cfg.backend.state_dim();
        let state: Vec<f32> = (0..dim).map(|i| (i as f32) / dim as f32 - 0.5).collect();
        for eps in [0.0, 0.5, 0.9] {
            let h = crate::runtime::argmax(&hinted.agent.q_values(&state).unwrap());
            hinted.stage_greedy_hint(Some(h));
            let a = hinted.select_action(&state, eps).unwrap();
            let b = plain.select_action(&state, eps).unwrap();
            assert_eq!(a, b, "eps {eps}");
        }
        assert_eq!(hinted.rng.next_u64(), plain.rng.next_u64());
    }

    #[test]
    fn session_state_tracks_the_pending_selection_input() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        assert!(ctl.session_state().is_none(), "no session, no state");
        ctl.begin_session(WorkloadKind::LatticeBoltzmann, 4).unwrap();
        let dim = ctl.cfg.backend.state_dim();
        assert_eq!(ctl.session_state().map(<[f32]>::len), Some(dim));
        while !ctl.session_done() {
            ctl.step_session(3).unwrap();
        }
        assert!(ctl.session_state().is_none(), "exhausted session has no pending selection");
        ctl.finish_session().unwrap();
        assert!(ctl.session_state().is_none());
    }
}
