//! The Controller (§5.1): the object the run-time library talks to via
//! the `AITuning_*` surface. Owns the agent, replay buffer, relative-
//! pvar tracker and tuning schedule; drives the run→learn→act loop.

use anyhow::Result;

use crate::metrics::recorder::{RunRecord, TuningLog};
use crate::mpi_t::CvarSet;
use crate::simmpi::Machine;
use crate::util::rng::Rng;
use crate::workloads::WorkloadKind;

use super::actions::Action;
use super::agent::{Agent, AgentKind, DqnAgent};
use super::ensemble::ensemble;
use super::episode::run_episode;
use super::relative::RelativeTracker;
use super::replay::{ReplayBuffer, Transition};
use super::reward::reward;
use super::state::{build_state, NUM_ACTIONS, STATE_DIM};
use super::tabular::TabularAgent;

/// Tuning hyper-parameters and environment description.
#[derive(Debug, Clone)]
pub struct TuningConfig {
    pub machine: Machine,
    pub agent: AgentKind,
    /// Tuning runs per application (§5.4 recommends ≥ 20).
    pub runs: usize,
    /// ε-greedy exploration: linear from `eps_start` to `eps_end`.
    pub eps_start: f64,
    pub eps_end: f64,
    /// Q-learning discount and Adam learning rate.
    pub gamma: f32,
    pub lr: f32,
    /// Replay buffer capacity and minibatch size.
    pub replay_capacity: usize,
    pub replay_batch: usize,
    /// Full replay refresh cadence (§5.2: every 200 runs).
    pub replay_refresh_every: usize,
    /// Extra minibatches per refresh.
    pub replay_refresh_batches: usize,
    /// Simulator run-to-run noise.
    pub noise: f64,
    /// Master seed.
    pub seed: u64,
    /// Artifacts directory for the DQN agent.
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for TuningConfig {
    fn default() -> TuningConfig {
        TuningConfig {
            machine: Machine::cheyenne(),
            agent: AgentKind::Dqn,
            runs: 20,
            eps_start: 0.8,
            eps_end: 0.05,
            gamma: 0.9,
            lr: 1e-3,
            replay_capacity: 8192,
            replay_batch: 32,
            replay_refresh_every: 200,
            replay_refresh_batches: 8,
            noise: 0.02,
            seed: 0,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }
}

/// Result of tuning one application at one scale.
#[derive(Debug, Clone)]
pub struct TuningOutcome {
    pub log: TuningLog,
    /// Configuration of the single best run.
    pub best: CvarSet,
    /// Ensemble configuration (§5.4) — what AITuning ships.
    pub ensemble: CvarSet,
    /// Total time of the reference (vanilla) run.
    pub reference_us: f64,
    /// Best run's total time.
    pub best_us: f64,
}

impl TuningOutcome {
    /// Fractional improvement of the best run over the reference.
    ///
    /// A degenerate reference (zero, negative or non-finite total time)
    /// yields 0.0 instead of NaN/inf, so the value is always safe to
    /// aggregate into campaign reports and benchmark JSON.
    pub fn improvement(&self) -> f64 {
        if !(self.reference_us > 0.0 && self.reference_us.is_finite()) {
            return 0.0;
        }
        (self.reference_us - self.best_us) / self.reference_us
    }
}

/// The AITuning controller.
pub struct Controller {
    pub cfg: TuningConfig,
    agent: Box<dyn Agent>,
    replay: ReplayBuffer,
    rng: Rng,
    /// Runs executed across the controller's lifetime (drives the
    /// §5.2 every-200-runs replay refresh across applications).
    lifetime_runs: usize,
}

impl Controller {
    /// `AITuning_start`: construct the controller for a layer.
    pub fn new(cfg: TuningConfig) -> Result<Controller> {
        let mut rng = Rng::new(cfg.seed);
        let agent: Box<dyn Agent> = match cfg.agent {
            AgentKind::Dqn => Box::new(DqnAgent::load(&cfg.artifacts_dir, &mut rng)?),
            AgentKind::DqnTarget => {
                Box::new(DqnAgent::load_with_mode(&cfg.artifacts_dir, &mut rng, true)?)
            }
            AgentKind::Tabular => Box::new(TabularAgent::new()),
        };
        let replay = ReplayBuffer::new(cfg.replay_capacity);
        Ok(Controller { cfg, agent, replay, rng, lifetime_runs: 0 })
    }

    /// Current exploration rate for tuning-run `i` of `n` (0-based).
    ///
    /// Linear decay from `eps_start` to `eps_end`; the final run always
    /// uses `eps_end` *exactly* (no floating-point residue), and a
    /// single-run schedule (`n == 1`) goes straight to `eps_end` rather
    /// than never decaying.
    fn epsilon(&self, i: usize, n: usize) -> f64 {
        if n <= 1 || i + 1 >= n {
            return self.cfg.eps_end;
        }
        let f = i as f64 / (n - 1) as f64;
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * f
    }

    /// ε-greedy action selection.
    fn select_action(&mut self, state: &[f32; STATE_DIM], eps: f64) -> Result<usize> {
        if self.rng.chance(eps) {
            Ok(self.rng.below(NUM_ACTIONS as u64) as usize)
        } else {
            let q = self.agent.q_values(state)?;
            Ok(crate::runtime::argmax(&q))
        }
    }

    /// Train on replay: one minibatch per run, plus the periodic
    /// full-replay refresh (§5.2).
    fn learn(&mut self) -> Result<()> {
        if self.replay.is_empty() {
            return Ok(());
        }
        let batch = self.replay.sample(self.cfg.replay_batch, &mut self.rng);
        self.agent.train(&batch, self.cfg.lr, self.cfg.gamma)?;
        if self.lifetime_runs % self.cfg.replay_refresh_every == 0 {
            for _ in 0..self.cfg.replay_refresh_batches {
                let batch = self.replay.sample(self.cfg.replay_batch, &mut self.rng);
                self.agent.train(&batch, self.cfg.lr, self.cfg.gamma)?;
            }
        }
        Ok(())
    }

    /// Tune one application at one scale: the full §5 loop.
    pub fn tune(&mut self, kind: WorkloadKind, images: usize) -> Result<TuningOutcome> {
        let workload_seed = self.cfg.seed ^ seed_mix(kind, images);
        let mut log = TuningLog::new(kind.name(), images);
        let mut tracker = RelativeTracker::new();
        let mut cvars = CvarSet::vanilla();

        // --- Run 0: reference (AITUNING_FIRST_RUN=1), vanilla config ---
        let run_seed = self.rng.next_u64();
        let reference = run_episode(
            kind, images, &self.cfg.machine, &cvars, self.cfg.noise, workload_seed, run_seed,
        )?;
        tracker.record_reference(&reference.pvars);
        let reference_us = reference.total_time_us;
        self.lifetime_runs += 1;
        log.push(RunRecord {
            run_index: 0,
            cvars: cvars.clone(),
            total_time_us: reference_us,
            reward: 0.0,
            action: None,
            epsilon: 1.0,
            pvars: reference.pvars.clone(),
        });

        let mut prev_state = build_state(
            &reference.pvars, &tracker, &cvars, images, 0, reference.eager_fraction,
        );

        // --- Tuning runs ---
        for i in 1..=self.cfg.runs {
            let eps = self.epsilon(i - 1, self.cfg.runs);
            let action_idx = self.select_action(&prev_state, eps)?;
            let action = Action::from_index(action_idx);
            cvars = action.apply(&cvars);

            let run_seed = self.rng.next_u64();
            let result = run_episode(
                kind, images, &self.cfg.machine, &cvars, self.cfg.noise, workload_seed, run_seed,
            )?;
            let r = reward(reference_us, result.total_time_us);
            self.lifetime_runs += 1;

            let state = build_state(
                &result.pvars, &tracker, &cvars, images, i, result.eager_fraction,
            );
            self.replay.push(Transition {
                state: prev_state,
                action: action_idx,
                reward: r as f32,
                next_state: state,
                done: i == self.cfg.runs,
            });
            self.learn()?;

            log.push(RunRecord {
                run_index: i,
                cvars: cvars.clone(),
                total_time_us: result.total_time_us,
                reward: r,
                action: Some(action_idx),
                epsilon: eps,
                pvars: result.pvars,
            });
            prev_state = state;
        }

        let best_rec = log.best_run().expect("nonempty log");
        let best = best_rec.cvars.clone();
        let best_us = best_rec.total_time_us;
        let ensemble_cfg = ensemble(&log.runs[1..], reference_us);
        Ok(TuningOutcome { log, best, ensemble: ensemble_cfg, reference_us, best_us })
    }

    /// Evaluate a fixed configuration (no learning) — used to score the
    /// ensemble config and the baselines.
    pub fn evaluate(
        &mut self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
    ) -> Result<f64> {
        let workload_seed = self.cfg.seed ^ seed_mix(kind, images);
        let mut total = 0.0;
        for _ in 0..repeats.max(1) {
            let run_seed = self.rng.next_u64();
            let r = run_episode(
                kind, images, &self.cfg.machine, cvars, self.cfg.noise, workload_seed, run_seed,
            )?;
            total += r.total_time_us;
        }
        Ok(total / repeats.max(1) as f64)
    }

    /// Evaluate a fixed configuration through the campaign engine's
    /// episode cache with *deterministic* per-repeat seeds, so repeated
    /// scoring of the same configuration (ensemble scoring, baselines)
    /// skips re-simulation. Unlike [`Controller::evaluate`] this does
    /// not consume controller RNG state.
    pub fn evaluate_cached(
        &self,
        kind: WorkloadKind,
        images: usize,
        cvars: &CvarSet,
        repeats: usize,
        cache: &crate::campaign::EpisodeCache,
    ) -> Result<f64> {
        crate::campaign::evaluate_config(&self.cfg, kind, images, cvars, repeats, Some(cache))
    }

    pub fn agent_name(&self) -> &'static str {
        self.agent.name()
    }

    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    pub fn loss_history(&self) -> &[f32] {
        self.agent.loss_history()
    }

    pub fn lifetime_runs(&self) -> usize {
        self.lifetime_runs
    }
}

/// Stable per-(workload, images) seed component: the same application
/// instance is tuned across all of a campaign's runs. Shared with the
/// campaign engine so cached evaluations agree with controller runs.
pub(crate) fn seed_mix(kind: WorkloadKind, images: usize) -> u64 {
    let k = kind.name().bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    k.wrapping_mul(0x9e3779b97f4a7c15) ^ (images as u64).wrapping_mul(0xd1b54a32d192ed03)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tabular_cfg() -> TuningConfig {
        TuningConfig {
            agent: AgentKind::Tabular,
            runs: 10,
            noise: 0.01,
            seed: 3,
            ..TuningConfig::default()
        }
    }

    #[test]
    fn tabular_tuning_improves_lbm() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        let out = ctl.tune(WorkloadKind::LatticeBoltzmann, 8).unwrap();
        assert_eq!(out.log.runs.len(), 11);
        assert!(out.best_us <= out.reference_us * 1.02, "best should not be much worse");
        assert!(ctl.replay_len() == 10);
    }

    #[test]
    fn epsilon_schedule_decays() {
        let ctl = Controller::new(tabular_cfg()).unwrap();
        assert!(ctl.epsilon(0, 20) > ctl.epsilon(19, 20));
        assert!((ctl.epsilon(19, 20) - 0.05).abs() < 1e-9);
    }

    #[test]
    fn epsilon_last_run_is_exactly_eps_end() {
        let ctl = Controller::new(tabular_cfg()).unwrap();
        // Exact equality, not within-epsilon: the schedule must *reach*
        // eps_end on the final run for any run budget.
        assert_eq!(ctl.epsilon(9, 10), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(1, 2), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(0, 2), ctl.cfg.eps_start);
        assert_eq!(ctl.epsilon(19, 20), ctl.cfg.eps_end);
    }

    #[test]
    fn epsilon_single_run_schedule_decays() {
        // Regression: with runs == 1 the old schedule stayed at
        // eps_start forever; the only run is also the last, so it must
        // exploit at eps_end.
        let ctl = Controller::new(tabular_cfg()).unwrap();
        assert_eq!(ctl.epsilon(0, 1), ctl.cfg.eps_end);
        assert_eq!(ctl.epsilon(0, 0), ctl.cfg.eps_end);
    }

    #[test]
    fn improvement_with_zero_reference_is_clamped() {
        // Regression: reference_us == 0.0 used to propagate NaN/inf
        // silently into benchmark JSON.
        let out = TuningOutcome {
            log: TuningLog::new("x", 1),
            best: CvarSet::vanilla(),
            ensemble: CvarSet::vanilla(),
            reference_us: 0.0,
            best_us: 10.0,
        };
        assert_eq!(out.improvement(), 0.0);
        let nan_ref = TuningOutcome { reference_us: f64::NAN, ..out };
        assert_eq!(nan_ref.improvement(), 0.0);
    }

    #[test]
    fn evaluate_is_deterministic_in_expectation() {
        let mut ctl = Controller::new(tabular_cfg()).unwrap();
        let t = ctl.evaluate(WorkloadKind::LatticeBoltzmann, 4, &CvarSet::vanilla(), 2).unwrap();
        assert!(t > 0.0);
    }
}
