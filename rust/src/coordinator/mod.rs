//! The AITuning coordinator — the paper's contribution (§5).
//!
//! A [`Controller`] drives repeated executions of an application. Each
//! run: the end-of-run MPI_T performance-variable statistics (relative
//! to the first, reference run — §5.1) form the RL *state*; the deep
//! Q-network proposes an *action* (a fixed-step change to one control
//! variable — §5.2); the next run executes under the new configuration
//! and its total-time improvement is the *reward*. Experience replay
//! stabilizes training (§3.1/§5.2; no Q-target network, as in the
//! paper). After the tuning runs, ensemble inference (§5.4) merges the
//! best configurations. Experience retention and minibatch selection
//! are a pluggable subsystem ([`replay`]: uniform / workload-stratified
//! / prioritized policies behind the [`ReplayPolicy`] seam).
//!
//! Beyond the paper's single-session loop, [`hub`] adds a `LearnerHub`
//! parameter server: parallel campaign workers pull/push weight and
//! replay snapshots at a fixed cadence and the hub merges them in
//! deterministic job order (see [`crate::campaign`] for the driver).

pub mod actions;
pub mod agent;
pub mod controller;
pub mod ensemble;
pub mod episode;
pub mod hub;
pub mod relative;
pub mod replay;
pub mod reward;
pub mod tabular;

pub use actions::{num_actions, one_hot, Action};
pub use agent::{Agent, AgentKind, DqnAgent, TrainOutcome};
pub use controller::{Controller, SharedLearning, TuningConfig, TuningOutcome};
pub use episode::{run_episode, EpisodeResult};
pub use hub::{
    AgentState, HubContribution, HubLrSchedule, HubSummary, HubView, LearnerHub, MergeMode,
    SyncMode,
};
pub use relative::RelativeTracker;
pub use replay::{
    LocalReplay, PrioritizedSampler, ReplayBuffer, ReplayPolicy, ReplayPolicyKind,
    StratifiedRing, Transition, UniformRing,
};
// The coarrays backend's layout constants and state builder — kept as
// re-exports for the paper-facing call sites (benches, the AOT
// manifest contract); backend-generic code sizes everything from a
// BackendId instead.
pub use crate::backend::coarrays::{build_state, NUM_ACTIONS, STATE_DIM};
pub use tabular::TabularAgent;
