//! The action space: one fixed-step change to one control variable
//! (§5.2), or no-op. 6 cvars × {up, down} + no-op = 13 actions.

use crate::mpi_t::{CvarId, CvarSet, MPICH_CVARS};

use super::state::NUM_ACTIONS;

/// A tuning action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the configuration.
    Noop,
    /// Step `cvar` up or down by its fixed step (booleans toggle).
    Step { cvar: CvarId, up: bool },
}

impl Action {
    /// Decode an action index (the Q-network's output ordering):
    /// 0 = no-op; then `1 + 2*c` = cvar c up, `2 + 2*c` = cvar c down.
    pub fn from_index(index: usize) -> Action {
        assert!(index < NUM_ACTIONS, "action index {index} out of range");
        if index == 0 {
            return Action::Noop;
        }
        let k = index - 1;
        Action::Step { cvar: CvarId(k / 2), up: k % 2 == 0 }
    }

    pub fn index(&self) -> usize {
        match *self {
            Action::Noop => 0,
            Action::Step { cvar, up } => 1 + 2 * cvar.0 + usize::from(!up),
        }
    }

    /// Apply to a configuration (clamped by the cvar's domain).
    pub fn apply(&self, cvars: &CvarSet) -> CvarSet {
        match *self {
            Action::Noop => cvars.clone(),
            Action::Step { cvar, up } => {
                let mut next = cvars.clone();
                let d = &MPICH_CVARS[cvar.0];
                next.set(cvar, d.step(cvars.get(cvar), up));
                next
            }
        }
    }

    /// Human-readable description for logs.
    pub fn describe(&self) -> String {
        match *self {
            Action::Noop => "no-op".to_string(),
            Action::Step { cvar, up } => {
                let d = &MPICH_CVARS[cvar.0];
                let short = d.name.strip_prefix("MPIR_CVAR_").unwrap_or(d.name);
                format!("{short} {}", if up { "+step" } else { "-step" })
            }
        }
    }
}

/// One-hot encode an action index for the train batch.
pub fn one_hot(index: usize) -> [f32; NUM_ACTIONS] {
    let mut v = [0.0; NUM_ACTIONS];
    v[index] = 1.0;
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_round_trip() {
        for i in 0..NUM_ACTIONS {
            assert_eq!(Action::from_index(i).index(), i, "index {i}");
        }
    }

    #[test]
    fn apply_steps_eager_max() {
        let base = CvarSet::vanilla();
        let up = Action::Step { cvar: CvarId(5), up: true }.apply(&base);
        assert_eq!(up.eager_max(), base.eager_max() + 1024);
        let down = Action::Step { cvar: CvarId(5), up: false }.apply(&base);
        assert_eq!(down.eager_max(), base.eager_max() - 1024);
    }

    #[test]
    fn apply_toggles_bools() {
        let base = CvarSet::vanilla();
        let on = Action::Step { cvar: CvarId(0), up: true }.apply(&base);
        assert!(on.async_progress());
        let off = Action::Step { cvar: CvarId(0), up: false }.apply(&on);
        assert!(!off.async_progress());
    }

    #[test]
    fn noop_is_identity() {
        let base = CvarSet::vanilla();
        assert_eq!(Action::Noop.apply(&base), base);
    }

    #[test]
    fn one_hot_shape() {
        let v = one_hot(3);
        assert_eq!(v.iter().sum::<f32>(), 1.0);
        assert_eq!(v[3], 1.0);
    }
}
