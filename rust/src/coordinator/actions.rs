//! The action space, derived from a backend's cvar registry.
//!
//! Layout (§5.2 generalized): index 0 is no-op; indices `1 + 2c` /
//! `2 + 2c` step cvar `c` up / down by its fixed step (booleans
//! toggle, choices move to the neighbouring option); after the step
//! block, every *categorical* cvar contributes one enumerated
//! **select** action per option, in registry order. For the coarrays
//! backend (six scalar cvars, no categorical domains) this reproduces
//! the paper's `6 × {up, down} + no-op = 13` exactly.

use crate::mpi_t::{CvarDescriptor, CvarDomain, CvarId, CvarSet};

/// A tuning action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the configuration.
    Noop,
    /// Step `cvar` up or down by its fixed step (booleans toggle,
    /// choices move one option over).
    Step { cvar: CvarId, up: bool },
    /// Jump a categorical cvar directly to one of its options.
    Select { cvar: CvarId, choice: usize },
}

/// Derived action count for a cvar table:
/// `1 + 2 × num_cvars + Σ options(categorical cvars)`.
pub fn num_actions(table: &[CvarDescriptor]) -> usize {
    1 + 2 * table.len()
        + table
            .iter()
            .map(|d| match d.domain {
                CvarDomain::Choice { options } => options.len(),
                _ => 0,
            })
            .sum::<usize>()
}

impl Action {
    /// Decode an action index (the Q-network's output ordering).
    pub fn from_index(table: &[CvarDescriptor], index: usize) -> Action {
        assert!(
            index < num_actions(table),
            "action index {index} out of range for {}-action table",
            num_actions(table)
        );
        if index == 0 {
            return Action::Noop;
        }
        let k = index - 1;
        if k < 2 * table.len() {
            return Action::Step { cvar: CvarId(k / 2), up: k % 2 == 0 };
        }
        let mut k = k - 2 * table.len();
        for d in table {
            if let CvarDomain::Choice { options } = d.domain {
                if k < options.len() {
                    return Action::Select { cvar: d.id, choice: k };
                }
                k -= options.len();
            }
        }
        unreachable!("index checked against num_actions above")
    }

    /// Inverse of [`Action::from_index`].
    pub fn index(&self, table: &[CvarDescriptor]) -> usize {
        match *self {
            Action::Noop => 0,
            Action::Step { cvar, up } => 1 + 2 * cvar.0 + usize::from(!up),
            Action::Select { cvar, choice } => {
                let mut idx = 1 + 2 * table.len();
                for d in &table[..cvar.0] {
                    if let CvarDomain::Choice { options } = d.domain {
                        idx += options.len();
                    }
                }
                idx + choice
            }
        }
    }

    /// Apply to a configuration (clamped by the cvar's domain, using
    /// the configuration's own backend registry).
    pub fn apply(&self, cvars: &CvarSet) -> CvarSet {
        match *self {
            Action::Noop => cvars.clone(),
            Action::Step { cvar, up } => {
                let mut next = cvars.clone();
                let d = &cvars.table()[cvar.0];
                next.set(cvar, d.step(cvars.get(cvar), up));
                next
            }
            Action::Select { cvar, choice } => {
                let mut next = cvars.clone();
                next.set(cvar, choice as i64); // set() clamps to the domain
                next
            }
        }
    }

    /// Human-readable description for logs.
    pub fn describe(&self, table: &[CvarDescriptor]) -> String {
        let short = |d: &CvarDescriptor| {
            d.name.strip_prefix("MPIR_CVAR_").unwrap_or(d.name).to_string()
        };
        match *self {
            Action::Noop => "no-op".to_string(),
            Action::Step { cvar, up } => {
                format!("{} {}", short(&table[cvar.0]), if up { "+step" } else { "-step" })
            }
            Action::Select { cvar, choice } => {
                let d = &table[cvar.0];
                let option = match d.domain {
                    CvarDomain::Choice { options } => options.get(choice).copied().unwrap_or("?"),
                    _ => "?",
                };
                format!("{}={option}", short(d))
            }
        }
    }
}

/// One-hot encode an action index for the train batch (`n` = action
/// count of the backend that produced the index).
pub fn one_hot(index: usize, n: usize) -> Vec<f32> {
    let mut v = vec![0.0; n];
    v[index] = 1.0;
    v
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::backend::BackendId;
    use crate::mpi_t::{BCAST_ALGORITHMS, COLLECTIVE_CVARS, MPICH_CVARS};

    #[test]
    fn coarrays_layout_is_the_papers_13() {
        assert_eq!(num_actions(MPICH_CVARS), 13);
        for i in 0..13 {
            assert_eq!(Action::from_index(MPICH_CVARS, i).index(MPICH_CVARS), i, "index {i}");
        }
    }

    #[test]
    fn collectives_layout_adds_enumerated_choices() {
        // 1 + 2*4 steps + (3 bcast + 2 allreduce) selects = 14.
        assert_eq!(num_actions(COLLECTIVE_CVARS), 14);
        for i in 0..14 {
            let a = Action::from_index(COLLECTIVE_CVARS, i);
            assert_eq!(a.index(COLLECTIVE_CVARS), i, "index {i} via {a:?}");
        }
        // First select action targets the first categorical cvar's
        // first option.
        let first_select = 1 + 2 * COLLECTIVE_CVARS.len();
        assert_eq!(
            Action::from_index(COLLECTIVE_CVARS, first_select),
            Action::Select { cvar: CvarId(0), choice: 0 }
        );
        let last = Action::from_index(COLLECTIVE_CVARS, 13);
        assert_eq!(last, Action::Select { cvar: CvarId(1), choice: 1 });
    }

    #[test]
    fn select_jumps_directly_to_an_option() {
        let base = CvarSet::defaults(BackendId::Collectives);
        let jumped = Action::Select { cvar: CvarId(0), choice: 2 }.apply(&base);
        assert_eq!(jumped.get(CvarId(0)), 2);
        // Out-of-range choices clamp instead of panicking.
        let clamped = Action::Select { cvar: CvarId(0), choice: 99 }.apply(&base);
        assert_eq!(clamped.get(CvarId(0)), BCAST_ALGORITHMS.len() as i64 - 1);
    }

    #[test]
    fn apply_steps_eager_max() {
        let base = CvarSet::vanilla();
        let up = Action::Step { cvar: CvarId(5), up: true }.apply(&base);
        assert_eq!(up.eager_max(), base.eager_max() + 1024);
        let down = Action::Step { cvar: CvarId(5), up: false }.apply(&base);
        assert_eq!(down.eager_max(), base.eager_max() - 1024);
    }

    #[test]
    fn apply_toggles_bools() {
        let base = CvarSet::vanilla();
        let on = Action::Step { cvar: CvarId(0), up: true }.apply(&base);
        assert!(on.async_progress());
        let off = Action::Step { cvar: CvarId(0), up: false }.apply(&on);
        assert!(!off.async_progress());
    }

    #[test]
    fn step_moves_choice_to_neighbouring_option() {
        let base = CvarSet::defaults(BackendId::Collectives);
        let next = Action::Step { cvar: CvarId(0), up: true }.apply(&base);
        assert_eq!(next.get(CvarId(0)), 1);
        let back = Action::Step { cvar: CvarId(0), up: false }.apply(&next);
        assert_eq!(back.get(CvarId(0)), 0);
    }

    #[test]
    fn noop_is_identity() {
        let base = CvarSet::vanilla();
        assert_eq!(Action::Noop.apply(&base), base);
    }

    #[test]
    fn describe_names_options() {
        let a = Action::Select { cvar: CvarId(1), choice: 1 };
        assert_eq!(a.describe(COLLECTIVE_CVARS), "ALLREDUCE_INTRA_ALGORITHM=ring");
        let s = Action::Step { cvar: CvarId(5), up: true };
        assert_eq!(s.describe(MPICH_CVARS), "CH3_EAGER_MAX_MSG_SIZE +step");
    }

    #[test]
    fn one_hot_shape() {
        let v = one_hot(3, 13);
        assert_eq!(v.len(), 13);
        assert_eq!(v.iter().sum::<f32>(), 1.0);
        assert_eq!(v[3], 1.0);
    }
}
