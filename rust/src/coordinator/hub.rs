//! The `LearnerHub` parameter server: shared learning across parallel
//! tuning sessions (the A3C-style merge the paper's single-session loop
//! does not have).
//!
//! PR 1's campaign engine runs every `(workload, images)` cell as an
//! *isolated* learner: 16 workers explore no better than 16 lonely
//! ones. The hub converts the campaign into one distributed learner
//! while keeping the engine's determinism contract:
//!
//! * the hub owns a **master agent state** (DQN: `QParams` + Adam
//!   moments; tabular: the Q-table) and a **global replay buffer**
//!   running one of the [`crate::coordinator::replay`] policies
//!   (uniform / workload-stratified / prioritized retention);
//! * workers *pull* a snapshot ([`LearnerHub::view`]) at segment start
//!   — both halves (master state and replay buffer) ride behind
//!   `Arc`s, so a pull is O(1), never a tensor or ring copy — and
//!   train locally for a fixed cadence of tuning runs
//!   ([`crate::coordinator::SharedLearning::sync_every`]);
//! * workers *push* [`HubContribution`]s — their locally-updated agent
//!   state plus the replay shard of new transitions — and the hub
//!   merges them **in job-index order** ([`LearnerHub::merge`]):
//!   states are averaged with order-sequenced `f64` accumulation
//!   ([`crate::runtime::average_params`]) and replay shards are
//!   appended shard-by-shard in that same order.
//!
//! Because every merge input arrives in job order and every merge
//! operation is order-sequenced, the hub state after round *r* is a
//! pure function of the job list and the base config — never of worker
//! count or thread scheduling. [`LearnerHub::digest`] folds the master
//! state and the replay contents into the campaign fingerprint so the
//! 1-vs-N-worker bit-identity checks cover shared learning too.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{adam_step, average_adam, average_params, AdamState, QParams};
use crate::util::fnv::Fnv64;
use crate::workloads::WorkloadKind;

use crate::backend::BackendId;

use super::replay::{ReplayBuffer, ReplayPolicyKind, Transition};

/// How the hub folds one round of contributions into the master state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergeMode {
    /// Average the pushed agent states (weights + Adam moments /
    /// Q-tables) in job order — the PR 2 semantics, and the only mode
    /// every agent kind supports.
    #[default]
    Weights,
    /// A3C-style gradient merging: workers push the raw gradients
    /// accumulated over their segment (native DQN engine only) and the
    /// hub applies **one job-order-sequenced Adam step per round** to
    /// the master parameters with the hub-owned optimizer moments. The
    /// first round bootstraps the master from the state average (the
    /// pushed states already embody that segment's local updates), so
    /// no learning is discarded.
    Grads,
}

impl MergeMode {
    pub const ALL: [MergeMode; 2] = [MergeMode::Weights, MergeMode::Grads];

    pub fn name(self) -> &'static str {
        match self {
            MergeMode::Weights => "weights",
            MergeMode::Grads => "grads",
        }
    }

    /// Dense index in [`MergeMode::ALL`] (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        match self {
            MergeMode::Weights => 0,
            MergeMode::Grads => 1,
        }
    }

    pub fn parse(s: &str) -> Option<MergeMode> {
        match s.to_ascii_lowercase().as_str() {
            "weights" | "weight" | "avg" => Some(MergeMode::Weights),
            "grads" | "grad" | "gradients" => Some(MergeMode::Grads),
            _ => None,
        }
    }
}

impl std::fmt::Display for MergeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How workers coordinate with the hub
/// (`docs/shared_learning.md` states the exact semantics of each).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SyncMode {
    /// Round-synchronous (the PR 2 semantics): every worker barriers on
    /// the slowest job each round and the hub merges the whole round in
    /// job-index order. Bit-identical at any worker count — the
    /// fingerprint-tested reference.
    #[default]
    Sync,
    /// Bounded-staleness asynchronous: workers push the moment their
    /// segment ends and pull whatever master is current; at most
    /// `staleness + 1` contributions are in flight at once, so no
    /// merged contribution is ever more than `staleness` hub
    /// generations old. `staleness == 0` degenerates to the
    /// synchronous path (and keeps its bit-identity).
    Async {
        /// Maximum hub-generation staleness `S` of a merged
        /// contribution (the concurrency window is `S + 1`).
        staleness: usize,
    },
}

impl SyncMode {
    pub fn name(self) -> &'static str {
        match self {
            SyncMode::Sync => "sync",
            SyncMode::Async { .. } => "async",
        }
    }

    /// The staleness window `S` (0 for the synchronous mode).
    pub fn staleness(self) -> usize {
        match self {
            SyncMode::Sync => 0,
            SyncMode::Async { staleness } => staleness,
        }
    }

    /// True only for the asynchronous mode with a non-zero window —
    /// `Async { staleness: 0 }` is *dispatched* to the synchronous
    /// driver so its bit-identity claim is structural, not emergent.
    pub fn runs_async(self) -> bool {
        matches!(self, SyncMode::Async { staleness } if staleness > 0)
    }

    /// Parse the `--sync-mode` flag value; `staleness` comes from the
    /// separate `--staleness` flag (ignored for `sync`).
    pub fn parse(s: &str, staleness: usize) -> Option<SyncMode> {
        match s.to_ascii_lowercase().as_str() {
            "sync" | "synchronous" => Some(SyncMode::Sync),
            "async" | "asynchronous" => Some(SyncMode::Async { staleness }),
            _ => None,
        }
    }
}

impl std::fmt::Display for SyncMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SyncMode::Sync => f.write_str("sync"),
            SyncMode::Async { staleness } => write!(f, "async(S={staleness})"),
        }
    }
}

/// Learning-rate schedule of the hub-side Adam steps
/// ([`MergeMode::Grads`] only). Clocked by the hub's cumulative Adam
/// step count, never by wall time, so a replayed campaign sees the
/// identical lr sequence. Integer periods keep `Eq` derivable and the
/// digest exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HubLrSchedule {
    /// Fixed base lr. Returns the base `f32` unchanged (no f64 round
    /// trip), so the default schedule is bit-identical to the PR 5
    /// unscheduled hub step.
    #[default]
    Constant,
    /// `base / sqrt(1 + step / period)` — the classic asymptotically
    /// vanishing rate for stale-gradient averaging.
    InvSqrt { period: usize },
    /// `base * 0.5^(step / period)` — geometric decay in plateaus.
    Halving { period: usize },
}

impl HubLrSchedule {
    /// Dense index (digest/fingerprint key).
    pub fn ordinal(self) -> usize {
        match self {
            HubLrSchedule::Constant => 0,
            HubLrSchedule::InvSqrt { .. } => 1,
            HubLrSchedule::Halving { .. } => 2,
        }
    }

    /// Schedule period (0 for the constant schedule — digest key only).
    pub fn period(self) -> usize {
        match self {
            HubLrSchedule::Constant => 0,
            HubLrSchedule::InvSqrt { period } | HubLrSchedule::Halving { period } => period,
        }
    }

    /// Learning rate of hub Adam step number `step` (0-based). Computed
    /// in `f64`, rounded once — except `Constant`, which returns the
    /// base bit-identically.
    pub fn lr_at(self, base: f32, step: usize) -> f32 {
        match self {
            HubLrSchedule::Constant => base,
            HubLrSchedule::InvSqrt { period } => {
                let p = period.max(1) as f64;
                (base as f64 / (1.0 + step as f64 / p).sqrt()) as f32
            }
            HubLrSchedule::Halving { period } => {
                let halvings = (step / period.max(1)).min(i32::MAX as usize) as i32;
                (base as f64 * 0.5f64.powi(halvings)) as f32
            }
        }
    }

    /// Parse `--hub-lr-schedule`: `constant`, `invsqrt:N`, `halving:N`
    /// (a bare `invsqrt`/`halving` defaults the period to 100 steps).
    pub fn parse(s: &str) -> Option<HubLrSchedule> {
        let lower = s.to_ascii_lowercase();
        let (kind, period) = match lower.split_once(':') {
            Some((k, p)) => (k.to_string(), p.parse::<usize>().ok()?.max(1)),
            None => (lower, 100),
        };
        match kind.as_str() {
            "constant" | "const" | "fixed" => Some(HubLrSchedule::Constant),
            "invsqrt" | "inv-sqrt" => Some(HubLrSchedule::InvSqrt { period }),
            "halving" | "halve" | "step" => Some(HubLrSchedule::Halving { period }),
            _ => None,
        }
    }
}

impl std::fmt::Display for HubLrSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HubLrSchedule::Constant => f.write_str("constant"),
            HubLrSchedule::InvSqrt { period } => write!(f, "invsqrt:{period}"),
            HubLrSchedule::Halving { period } => write!(f, "halving:{period}"),
        }
    }
}

/// A portable snapshot of one agent's learnable state — the hub's wire
/// format for both pull (master → worker) and push (worker → hub).
#[derive(Debug, Clone)]
pub enum AgentState {
    /// Deep Q-network: parameters plus Adam moments (both merged, so a
    /// pulled snapshot resumes optimization rather than restarting it).
    Dense { params: QParams, opt: AdamState },
    /// Tabular agent: the discretized Q-table as `(cell, Q(·))` entries
    /// **sorted by cell key**, so digests and averages are independent
    /// of `HashMap` iteration order. Row width is the backend's action
    /// count.
    Table(Vec<(u64, Vec<f32>)>),
}

impl AgentState {
    /// Deterministic average of homogeneous agent states.
    ///
    /// The slice must already be in job-index order: dense tensors are
    /// averaged with in-order `f64` accumulation, and table cells are
    /// averaged over the contributors that visited each cell, again
    /// accumulating in slice order. Mixing dense and tabular states is
    /// an error (a shared campaign must be agent-homogeneous).
    pub fn average(states: &[&AgentState]) -> Result<AgentState> {
        anyhow::ensure!(!states.is_empty(), "cannot average zero agent states");
        match states[0] {
            AgentState::Dense { .. } => {
                let mut params = Vec::with_capacity(states.len());
                let mut opts = Vec::with_capacity(states.len());
                for s in states {
                    match s {
                        AgentState::Dense { params: p, opt: o } => {
                            params.push(p);
                            opts.push(o);
                        }
                        AgentState::Table(_) => {
                            anyhow::bail!("cannot merge tabular state into a dense hub")
                        }
                    }
                }
                Ok(AgentState::Dense {
                    params: average_params(&params)?,
                    opt: average_adam(&opts)?,
                })
            }
            AgentState::Table(_) => {
                let mut acc: BTreeMap<u64, (Vec<f64>, usize)> = BTreeMap::new();
                for s in states {
                    let entries = match s {
                        AgentState::Table(e) => e,
                        AgentState::Dense { .. } => {
                            anyhow::bail!("cannot merge dense state into a tabular hub")
                        }
                    };
                    for (key, q) in entries {
                        let (sum, n) =
                            acc.entry(*key).or_insert_with(|| (vec![0.0; q.len()], 0));
                        anyhow::ensure!(
                            sum.len() == q.len(),
                            "tabular rows of mixed action width in one hub"
                        );
                        for (a, &x) in sum.iter_mut().zip(q) {
                            *a += x as f64;
                        }
                        *n += 1;
                    }
                }
                // BTreeMap iteration yields keys ascending — the Table
                // sorted-by-key invariant holds by construction.
                Ok(AgentState::Table(
                    acc.into_iter()
                        .map(|(key, (sum, n))| {
                            let inv = 1.0 / n as f64;
                            (key, sum.into_iter().map(|x| (x * inv) as f32).collect())
                        })
                        .collect(),
                ))
            }
        }
    }

    /// Staleness-weighted blend `(1 - alpha)·master + alpha·push` for
    /// asynchronous weight merges ([`LearnerHub::merge_one`]).
    ///
    /// Dense tensors (and Adam moments) blend element-wise in `f64`,
    /// rounded once. Table cells present in both states blend the same
    /// way; cells only the push visited are adopted as-is (new
    /// knowledge), cells only the master holds are kept (α discounts
    /// the push, never erases the master). Mixing dense and tabular
    /// states is an error, as in [`AgentState::average`].
    pub fn blend(master: &AgentState, push: &AgentState, alpha: f64) -> Result<AgentState> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&alpha),
            "blend weight {alpha} outside [0, 1]"
        );
        match (master, push) {
            (
                AgentState::Dense { params: mp, opt: mo },
                AgentState::Dense { params: pp, opt: po },
            ) => Ok(AgentState::Dense {
                params: blend_params(mp, pp, alpha)?,
                opt: AdamState {
                    m: blend_params(&mo.m, &po.m, alpha)?,
                    v: blend_params(&mo.v, &po.v, alpha)?,
                    step: ((1.0 - alpha) * mo.step as f64 + alpha * po.step as f64) as f32,
                },
            }),
            (AgentState::Table(master_rows), AgentState::Table(push_rows)) => {
                let mut out: BTreeMap<u64, Vec<f32>> =
                    master_rows.iter().cloned().collect();
                for (key, q) in push_rows {
                    match out.entry(*key) {
                        std::collections::btree_map::Entry::Occupied(mut e) => {
                            let row = e.get_mut();
                            anyhow::ensure!(
                                row.len() == q.len(),
                                "tabular rows of mixed action width in one hub"
                            );
                            for (m, &p) in row.iter_mut().zip(q) {
                                *m = ((1.0 - alpha) * *m as f64 + alpha * p as f64) as f32;
                            }
                        }
                        std::collections::btree_map::Entry::Vacant(e) => {
                            e.insert(q.clone());
                        }
                    }
                }
                // BTreeMap iteration yields keys ascending — the Table
                // sorted-by-key invariant holds by construction.
                Ok(AgentState::Table(out.into_iter().collect()))
            }
            _ => anyhow::bail!("cannot blend dense and tabular agent states in one hub"),
        }
    }

    /// Order-sensitive FNV-1a digest of the state.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        match self {
            AgentState::Dense { params, opt } => {
                h.mix(1);
                h.mix(params.digest());
                h.mix(opt.digest());
            }
            AgentState::Table(entries) => {
                h.mix(2);
                for (key, q) in entries {
                    h.mix(*key);
                    for v in q {
                        h.mix(v.to_bits() as u64);
                    }
                }
            }
        }
        h.finish()
    }
}

/// Element-wise `(1 - alpha)·master + alpha·push` over matching
/// tensors; each element widens to `f64` and rounds once (R2: no f32
/// accumulation on a merge path).
fn blend_params(master: &QParams, push: &QParams, alpha: f64) -> Result<QParams> {
    anyhow::ensure!(
        master.same_shape(push),
        "parameter shape mismatch in staleness-weighted blend"
    );
    QParams::from_flat(
        master
            .tensors
            .iter()
            .zip(&push.tensors)
            .map(|((md, shape), (pd, _))| {
                let data = md
                    .iter()
                    .zip(pd)
                    .map(|(&m, &p)| ((1.0 - alpha) * m as f64 + alpha * p as f64) as f32)
                    .collect();
                (data, shape.clone())
            })
            .collect(),
    )
}

/// What a worker pulls at segment start: the merge round, the master
/// state (absent before the first merge) and a snapshot of the global
/// replay buffer.
#[derive(Debug, Clone)]
pub struct HubView {
    /// Merges completed before this snapshot was taken.
    pub round: usize,
    /// Hub generation (incremental [`LearnerHub::merge_one`] merges
    /// completed) at snapshot time. Always 0 in synchronous campaigns;
    /// the async driver echoes it back with the worker's push so the
    /// hub can enforce and record staleness.
    pub generation: usize,
    /// Master agent state; `None` until the first merge, in which case
    /// workers keep their own freshly-initialized state. Shared behind
    /// an `Arc` for the same reason as `replay`: a pull must not clone
    /// the full parameter/Adam tensors per worker.
    pub master: Option<Arc<AgentState>>,
    /// Frozen snapshot of the global replay buffer, shared behind an
    /// `Arc`: pulling it is one pointer copy, never a ring clone, so an
    /// N-worker round costs O(1) per pull instead of O(capacity).
    pub replay: Arc<ReplayBuffer>,
}

/// One worker's push: its job index (the merge-order key), its
/// locally-trained agent state, the replay shard of transitions
/// generated since the last sync, and — in gradient-merge campaigns —
/// the raw gradients accumulated over the segment.
#[derive(Debug, Clone)]
pub struct HubContribution {
    pub job_index: usize,
    /// Locally-trained agent state. `None` is allowed only in
    /// gradient-merge rounds after the master was bootstrapped — the
    /// hub reads nothing but `grads` then, so workers skip the full
    /// params + Adam-moments clone ([`crate::coordinator::Controller::hub_contribution`]).
    pub state: Option<AgentState>,
    pub transitions: Vec<Transition>,
    /// Segment-accumulated raw gradients (`None` unless the agent runs
    /// the native DQN engine with gradient accumulation enabled).
    /// Required by [`MergeMode::Grads`]; ignored by
    /// [`MergeMode::Weights`].
    pub grads: Option<QParams>,
}

/// Buckets in the observed-staleness histogram; the last bucket is
/// open-ended (staleness `>= STALENESS_BUCKETS - 1`).
pub const STALENESS_BUCKETS: usize = 8;

/// Compact hub-state record attached to shared-campaign reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HubSummary {
    /// Merge rounds completed.
    pub merges: usize,
    /// Transitions currently held by the global replay buffer.
    pub replay_len: usize,
    /// Transitions pushed over the campaign's lifetime (pre-eviction).
    pub total_transitions: usize,
    /// Replay policy the global buffer ran.
    pub policy: ReplayPolicyKind,
    /// How contributions were folded into the master state.
    pub merge: MergeMode,
    /// Resident transitions per workload (ordinal-indexed; see
    /// [`WorkloadKind::ordinal`]) — the §5.2 retention picture: under
    /// eviction pressure a stratified buffer keeps every workload's
    /// entry non-zero, a uniform ring does not.
    pub occupancy: [usize; WorkloadKind::COUNT],
    /// Incremental ([`LearnerHub::merge_one`]) merges completed —
    /// always 0 for synchronous campaigns.
    pub generations: usize,
    /// Observed-staleness histogram of incremental merges: bucket `i`
    /// counts merges whose contribution was `i` generations stale
    /// (bucket 7 is `>= 7`). All-zero for synchronous campaigns.
    pub staleness: [usize; STALENESS_BUCKETS],
    /// Hub-side Adam lr schedule ([`MergeMode::Grads`] only).
    pub lr_schedule: HubLrSchedule,
    /// Hub-side Adam steps per gradient merge.
    pub hub_steps: usize,
    /// [`LearnerHub::digest`] at campaign end.
    pub digest: u64,
}

impl HubSummary {
    /// True when any post-PR-8 hub extension (async generations,
    /// non-default lr schedule, multi-step hub Adam) is in play.
    /// Report fingerprints, manifest digests and `to_json` gate the new
    /// fields on this so every pre-existing synchronous campaign keeps
    /// its PR 8 fingerprint byte-identically.
    pub fn extensions_active(&self) -> bool {
        self.generations > 0
            || self.lr_schedule != HubLrSchedule::Constant
            || self.hub_steps != 1
    }

    /// One-line human rendering for campaign drivers.
    pub fn describe(&self) -> String {
        let mut occupancy = String::new();
        for (i, &n) in self.occupancy.iter().enumerate() {
            if n > 0 {
                occupancy.push_str(&format!(" {}={n}", WorkloadKind::ALL[i].name()));
            }
        }
        if occupancy.is_empty() {
            occupancy.push_str(" (empty)");
        }
        let mut line = format!(
            "{} merges ({} merge), {} transitions pooled ({} resident, {} policy), \
             state digest {:016x}; occupancy:{}",
            self.merges, self.merge, self.total_transitions, self.replay_len, self.policy,
            self.digest, occupancy
        );
        if self.generations > 0 {
            let buckets: Vec<String> =
                self.staleness.iter().map(|n| n.to_string()).collect();
            line.push_str(&format!(
                "; async: {} generations, staleness histogram [{}]",
                self.generations,
                buckets.join(" ")
            ));
        }
        if self.lr_schedule != HubLrSchedule::Constant || self.hub_steps != 1 {
            line.push_str(&format!(
                "; hub adam: {} step(s)/merge, {} schedule",
                self.hub_steps, self.lr_schedule
            ));
        }
        line
    }
}

/// The parameter server. Owned by the shared-campaign driver; all
/// merges happen on the driver thread between rounds, so the hub itself
/// needs no locking — the barrier *is* the synchronization.
#[derive(Debug)]
pub struct LearnerHub {
    master: Option<Arc<AgentState>>,
    /// Global replay buffer. Kept behind an `Arc` so [`LearnerHub::view`]
    /// hands out zero-copy snapshots; [`LearnerHub::merge`] mutates via
    /// `Arc::make_mut`, which clones at most once per round (only while
    /// workers still hold the previous round's snapshot).
    replay: Arc<ReplayBuffer>,
    merges: usize,
    total_transitions: usize,
    /// How each round's contributions update the master state.
    merge_mode: MergeMode,
    /// Learning rate of the hub-side Adam step ([`MergeMode::Grads`]
    /// only; mirrors the campaign base config's `lr`).
    lr: f32,
    /// Incremental ([`LearnerHub::merge_one`]) merges completed — the
    /// async generation clock. Stays 0 for synchronous campaigns, which
    /// is what keeps their digests byte-identical to PR 8.
    generations: usize,
    /// Observed-staleness histogram of incremental merges.
    staleness: [usize; STALENESS_BUCKETS],
    /// Maximum staleness `S` an incremental merge may exhibit; the
    /// async driver's concurrency window guarantees it, the hub
    /// re-checks rather than trusts (like the job-order check in
    /// [`LearnerHub::merge`]).
    staleness_window: usize,
    /// Cumulative hub-side Adam steps — the lr-schedule clock.
    hub_adam_steps: usize,
    /// Hub-side Adam lr schedule ([`MergeMode::Grads`] only).
    lr_schedule: HubLrSchedule,
    /// Adam steps per gradient merge (default 1 — the PR 5 semantics).
    hub_steps: usize,
}

impl LearnerHub {
    /// Fresh hub with an empty global replay buffer of `replay_capacity`
    /// running `policy` over `backend`'s dimensions (use the campaign
    /// base config's values so worker pulls slot straight into their
    /// controllers).
    pub fn new(
        replay_capacity: usize,
        policy: ReplayPolicyKind,
        backend: BackendId,
    ) -> LearnerHub {
        LearnerHub {
            master: None,
            replay: Arc::new(ReplayBuffer::for_backend(replay_capacity, policy, backend)),
            merges: 0,
            total_transitions: 0,
            merge_mode: MergeMode::Weights,
            lr: 1e-3,
            generations: 0,
            staleness: [0; STALENESS_BUCKETS],
            staleness_window: 0,
            hub_adam_steps: 0,
            lr_schedule: HubLrSchedule::Constant,
            hub_steps: 1,
        }
    }

    /// Select the merge mode (builder-style). `lr` is the hub-side Adam
    /// learning rate, used only by [`MergeMode::Grads`]; pass the
    /// campaign base config's `lr` so the hub step matches the workers'.
    pub fn with_merge(mut self, merge: MergeMode, lr: f32) -> LearnerHub {
        self.merge_mode = merge;
        self.lr = lr;
        self
    }

    /// Permit incremental merges up to `window` generations stale
    /// (builder-style; required before the first [`LearnerHub::merge_one`]
    /// with non-zero staleness).
    pub fn with_staleness(mut self, window: usize) -> LearnerHub {
        self.staleness_window = window;
        self
    }

    /// Configure the hub-side optimizer: `schedule` drives the Adam
    /// learning rate over the hub's cumulative step count, `steps` Adam
    /// steps apply per gradient merge (clamped to ≥ 1). The defaults
    /// (`Constant`, 1) are bit-identical to the PR 5 single-step hub.
    pub fn with_hub_optimizer(mut self, schedule: HubLrSchedule, steps: usize) -> LearnerHub {
        self.lr_schedule = schedule;
        self.hub_steps = steps.max(1);
        self
    }

    pub fn merge_mode(&self) -> MergeMode {
        self.merge_mode
    }

    /// Incremental merges completed (the async generation clock).
    pub fn generations(&self) -> usize {
        self.generations
    }

    /// Staleness window `S` the hub enforces on incremental merges.
    pub fn staleness_window(&self) -> usize {
        self.staleness_window
    }

    /// Advance the generation clock without a contribution — lets the
    /// async driver's gate tests walk schedules without building real
    /// agent states.
    #[cfg(test)]
    pub(crate) fn bump_generation_for_test(&mut self) {
        self.generations += 1;
    }

    /// Snapshot for workers to pull at segment start. O(1): both the
    /// master state and the replay snapshot are `Arc` clones of frozen
    /// hub state — no tensor or ring copies.
    pub fn view(&self) -> HubView {
        HubView {
            round: self.merges,
            generation: self.generations,
            master: self.master.clone(),
            replay: Arc::clone(&self.replay),
        }
    }

    /// Merge one round of contributions.
    ///
    /// `contributions` must be in strictly increasing `job_index` order
    /// — the deterministic sequencing contract. (The campaign collector
    /// already restores job order regardless of which worker finished
    /// first; the hub re-checks rather than trusts.) In
    /// [`MergeMode::Weights`] the master state becomes the
    /// order-sequenced average of all pushed states; in
    /// [`MergeMode::Grads`] it takes one Adam step on the
    /// order-sequenced average of the pushed gradient accumulations
    /// (after a bootstrap round that averages states). Either way, each
    /// contribution's replay shard is appended to the global buffer
    /// shard-by-shard, transitions in generation order.
    pub fn merge(&mut self, contributions: &[HubContribution]) -> Result<()> {
        anyhow::ensure!(!contributions.is_empty(), "merge needs at least one contribution");
        for pair in contributions.windows(2) {
            anyhow::ensure!(
                pair[0].job_index < pair[1].job_index,
                "contributions must arrive in strictly increasing job order ({} then {})",
                pair[0].job_index,
                pair[1].job_index
            );
        }
        let collect_states = |contributions: &[HubContribution]| {
            contributions
                .iter()
                .map(|c| {
                    c.state.as_ref().with_context(|| {
                        format!(
                            "job {} pushed no agent state; state-averaging merges \
                             require one from every job",
                            c.job_index
                        )
                    })
                })
                .collect::<Result<Vec<&AgentState>>>()
        };
        match self.merge_mode {
            MergeMode::Weights => {
                self.master = Some(Arc::new(AgentState::average(&collect_states(contributions)?)?));
            }
            MergeMode::Grads => {
                // Strict at every round so a misconfigured worker fails
                // at its first push, not mid-campaign.
                let grads = contributions
                    .iter()
                    .map(|c| {
                        c.grads.as_ref().with_context(|| {
                            format!(
                                "job {} pushed no gradients; MergeMode::Grads requires the \
                                 native DQN engine (--agent dqn)",
                                c.job_index
                            )
                        })
                    })
                    .collect::<Result<Vec<&QParams>>>()?;
                // Scheduled lr for this merge's hub Adam step(s),
                // resolved before the master borrow (the schedule clock
                // lives on `self`). One step at the constant base lr is
                // the PR 5 semantics bit-identically.
                let lrs: Vec<f32> = (0..self.hub_steps)
                    .map(|i| self.lr_schedule.lr_at(self.lr, self.hub_adam_steps + i))
                    .collect();
                match self.master.as_mut() {
                    // Bootstrap round: the pushed states already embody
                    // this segment's local updates, so averaging them
                    // (job-order-sequenced) loses nothing; from the next
                    // round on, only hub Adam steps move the master.
                    None => {
                        let avg = AgentState::average(&collect_states(contributions)?)?;
                        self.master = Some(Arc::new(avg));
                    }
                    Some(master) => {
                        let avg = average_params(&grads)?;
                        match Arc::make_mut(master) {
                            AgentState::Dense { params, opt } => {
                                for &lr in &lrs {
                                    adam_step(params, opt, &avg, lr)?;
                                }
                                self.hub_adam_steps += lrs.len();
                            }
                            AgentState::Table(_) => anyhow::bail!(
                                "gradient merge requires a dense (DQN) master state"
                            ),
                        }
                    }
                }
            }
        }
        // Copy-on-write: detach from snapshots still held by workers
        // (one buffer clone per round at most), then append in order.
        let replay = Arc::make_mut(&mut self.replay);
        for c in contributions {
            for t in &c.transitions {
                replay.push(t.clone());
            }
            self.total_transitions += c.transitions.len();
        }
        self.merges += 1;
        Ok(())
    }

    /// Merge a single contribution incrementally — the asynchronous
    /// (bounded-staleness) counterpart of [`LearnerHub::merge`].
    ///
    /// `pulled_generation` is the hub generation the worker pulled
    /// before training this segment ([`HubView::generation`]); the
    /// difference from the current generation is the contribution's
    /// observed staleness. The hub *enforces* the staleness window the
    /// driver promised (errors name the job and generations involved —
    /// a violation is a driver bug, not data): a contribution more than
    /// [`LearnerHub::staleness_window`] generations stale is rejected.
    ///
    /// Unlike `merge`, the result is order-*dependent* by design —
    /// async campaigns trade the bit-identity claim for wall-clock (see
    /// `docs/shared_learning.md` for what invariants remain). In
    /// [`MergeMode::Weights`] the master moves to the staleness-
    /// discounted blend `(1-α)·master + α·push` with
    /// `α = 1 / (staleness + 2)` (a fresh push counts like one peer in
    /// a two-way average; staler pushes count less). In
    /// [`MergeMode::Grads`] the master takes the scheduled hub Adam
    /// step(s) on the pushed gradients directly — no cross-job
    /// averaging, one push is one increment.
    pub fn merge_one(
        &mut self,
        contribution: &HubContribution,
        pulled_generation: usize,
    ) -> Result<()> {
        let job = contribution.job_index;
        anyhow::ensure!(
            pulled_generation <= self.generations,
            "job {job} claims pull generation {pulled_generation}, but the hub has only \
             reached generation {}; the driver echoed back a generation it never issued",
            self.generations
        );
        let staleness = self.generations - pulled_generation;
        anyhow::ensure!(
            staleness <= self.staleness_window,
            "staleness contract violated: job {job} pulled at generation \
             {pulled_generation} but the hub is at generation {} (staleness {staleness} > \
             window {}); the async driver must block that pull until the hub catches up",
            self.generations,
            self.staleness_window
        );
        match self.merge_mode {
            MergeMode::Weights => {
                let pushed = contribution.state.as_ref().with_context(|| {
                    format!(
                        "job {job} pushed no agent state at generation {}; weight merges \
                         require one from every push",
                        self.generations
                    )
                })?;
                self.master = Some(Arc::new(match self.master.as_deref() {
                    None => pushed.clone(),
                    Some(master) => {
                        let alpha = 1.0 / (staleness as f64 + 2.0);
                        AgentState::blend(master, pushed, alpha)?
                    }
                }));
            }
            MergeMode::Grads => {
                let grads = contribution.grads.as_ref().with_context(|| {
                    format!(
                        "job {job} pushed no gradients at generation {}; MergeMode::Grads \
                         requires the native DQN engine (--agent dqn)",
                        self.generations
                    )
                })?;
                let lrs: Vec<f32> = (0..self.hub_steps)
                    .map(|i| self.lr_schedule.lr_at(self.lr, self.hub_adam_steps + i))
                    .collect();
                match self.master.as_mut() {
                    // Bootstrap: adopt the first push's state wholesale
                    // (it already embodies that segment's local steps).
                    None => {
                        let state = contribution.state.as_ref().with_context(|| {
                            format!(
                                "job {job} pushed no agent state at generation {}; the \
                                 bootstrap push must carry one",
                                self.generations
                            )
                        })?;
                        anyhow::ensure!(
                            matches!(state, AgentState::Dense { .. }),
                            "job {job}: gradient merge requires a dense (DQN) master state"
                        );
                        self.master = Some(Arc::new(state.clone()));
                    }
                    Some(master) => match Arc::make_mut(master) {
                        AgentState::Dense { params, opt } => {
                            for &lr in &lrs {
                                adam_step(params, opt, grads, lr)?;
                            }
                            self.hub_adam_steps += lrs.len();
                        }
                        AgentState::Table(_) => anyhow::bail!(
                            "job {job}: gradient merge requires a dense (DQN) master state"
                        ),
                    },
                }
            }
        }
        let replay = Arc::make_mut(&mut self.replay);
        for t in &contribution.transitions {
            replay.push(t.clone());
        }
        self.total_transitions += contribution.transitions.len();
        self.staleness[staleness.min(STALENESS_BUCKETS - 1)] += 1;
        self.generations += 1;
        self.merges += 1;
        Ok(())
    }

    pub fn master(&self) -> Option<&AgentState> {
        self.master.as_deref()
    }

    pub fn replay(&self) -> &ReplayBuffer {
        &self.replay
    }

    pub fn merges(&self) -> usize {
        self.merges
    }

    /// Order-sensitive digest of the full hub state (master + replay,
    /// in the replay policy's canonical order). Folded into
    /// [`crate::campaign::CampaignReport::fingerprint`] so worker-count
    /// invariance checks cover shared learning under every policy.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.mix(self.merges as u64);
        h.mix(self.replay.kind().ordinal() as u64);
        h.mix(self.merge_mode.ordinal() as u64);
        match &self.master {
            Some(state) => h.mix(state.digest()),
            None => h.mix(0),
        }
        for t in self.replay.iter() {
            for v in &t.state {
                h.mix(v.to_bits() as u64);
            }
            h.mix(t.action as u64);
            h.mix(t.reward.to_bits() as u64);
            for v in &t.next_state {
                h.mix(v.to_bits() as u64);
            }
            h.mix(t.done as u64);
            // 0 = unlabeled; ordinals shift by one.
            h.mix(t.workload.map(|w| w.ordinal() as u64 + 1).unwrap_or(0));
        }
        // Post-PR-8 extensions mix only when active, so every
        // synchronous default-optimizer campaign keeps its PR 8 digest
        // byte-identically (the gate mirrors
        // [`HubSummary::extensions_active`]).
        if self.generations > 0
            || self.lr_schedule != HubLrSchedule::Constant
            || self.hub_steps != 1
        {
            h.mix(self.generations as u64);
            for &n in &self.staleness {
                h.mix(n as u64);
            }
            h.mix(self.lr_schedule.ordinal() as u64);
            h.mix(self.lr_schedule.period() as u64);
            h.mix(self.hub_steps as u64);
            h.mix(self.hub_adam_steps as u64);
        }
        h.finish()
    }

    pub fn summary(&self) -> HubSummary {
        HubSummary {
            merges: self.merges,
            replay_len: self.replay.len(),
            total_transitions: self.total_transitions,
            policy: self.replay.kind(),
            merge: self.merge_mode,
            occupancy: self.replay.occupancy(),
            generations: self.generations,
            staleness: self.staleness,
            lr_schedule: self.lr_schedule,
            hub_steps: self.hub_steps,
            digest: self.digest(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests panic by design
mod tests {
    use super::*;
    use crate::backend::coarrays::{NUM_ACTIONS, STATE_DIM};

    fn table(entries: &[(u64, f32)]) -> AgentState {
        AgentState::Table(
            entries
                .iter()
                .map(|&(k, v)| {
                    let mut q = vec![0.0; NUM_ACTIONS];
                    q[0] = v;
                    (k, q)
                })
                .collect(),
        )
    }

    fn transition(reward: f32) -> Transition {
        Transition {
            state: vec![0.0; STATE_DIM],
            action: 0,
            reward,
            next_state: vec![0.0; STATE_DIM],
            done: false,
            workload: Some(WorkloadKind::LatticeBoltzmann),
        }
    }

    fn contribution(job_index: usize, state: AgentState, rewards: &[f32]) -> HubContribution {
        HubContribution {
            job_index,
            state: Some(state),
            transitions: rewards.iter().map(|&r| transition(r)).collect(),
            grads: None,
        }
    }

    fn dense(values: Vec<f32>) -> AgentState {
        let n = values.len();
        let params = QParams::from_flat(vec![(values, vec![n])]).unwrap();
        let opt = crate::runtime::AdamState::new(&params);
        AgentState::Dense { params, opt }
    }

    fn grad_contribution(
        job_index: usize,
        state: Option<AgentState>,
        grads: Vec<f32>,
    ) -> HubContribution {
        let n = grads.len();
        HubContribution {
            job_index,
            state,
            transitions: Vec::new(),
            grads: Some(QParams::from_flat(vec![(grads, vec![n])]).unwrap()),
        }
    }

    #[test]
    fn table_average_is_per_visited_cell() {
        // Cell 1 visited by both (mean), cells 2/3 by one each (kept).
        let a = table(&[(1, 2.0), (2, 8.0)]);
        let b = table(&[(1, 4.0), (3, 6.0)]);
        let avg = AgentState::average(&[&a, &b]).unwrap();
        match avg {
            AgentState::Table(entries) => {
                assert_eq!(entries.len(), 3);
                assert_eq!(entries[0], {
                    let mut q = vec![0.0; NUM_ACTIONS];
                    q[0] = 3.0;
                    (1, q)
                });
                assert_eq!(entries[1].1[0], 8.0);
                assert_eq!(entries[2].1[0], 6.0);
                // Sorted-by-key invariant.
                assert!(entries.windows(2).all(|w| w[0].0 < w[1].0));
            }
            AgentState::Dense { .. } => panic!("expected table"),
        }
    }

    #[test]
    fn mixed_agent_kinds_refuse_to_merge() {
        let t = table(&[(1, 1.0)]);
        let d = AgentState::Dense {
            params: crate::runtime::QParams::from_flat(vec![(vec![0.0], vec![1])]).unwrap(),
            opt: crate::runtime::AdamState::new(
                &crate::runtime::QParams::from_flat(vec![(vec![0.0], vec![1])]).unwrap(),
            ),
        };
        assert!(AgentState::average(&[&t, &d]).is_err());
        assert!(AgentState::average(&[&d, &t]).is_err());
    }

    #[test]
    fn replay_shards_append_in_job_order() {
        let mut hub = LearnerHub::new(64, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        // Push order scrambled relative to job order would be a driver
        // bug; the hub only accepts job order and appends shard 0's
        // transitions before shard 1's, preserving in-shard order.
        hub.merge(&[
            contribution(0, table(&[(1, 1.0)]), &[10.0, 11.0]),
            contribution(1, table(&[(1, 3.0)]), &[20.0]),
            contribution(2, table(&[(1, 5.0)]), &[30.0, 31.0]),
        ])
        .unwrap();
        let rewards: Vec<f32> = hub.replay().iter().map(|t| t.reward).collect();
        assert_eq!(rewards, vec![10.0, 11.0, 20.0, 30.0, 31.0]);
        assert_eq!(hub.merges(), 1);
        assert_eq!(hub.summary().total_transitions, 5);
    }

    #[test]
    fn out_of_order_contributions_are_rejected() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        let err = hub.merge(&[
            contribution(1, table(&[(1, 1.0)]), &[]),
            contribution(0, table(&[(1, 2.0)]), &[]),
        ]);
        assert!(err.is_err());
        let dup = hub.merge(&[
            contribution(0, table(&[(1, 1.0)]), &[]),
            contribution(0, table(&[(1, 2.0)]), &[]),
        ]);
        assert!(dup.is_err());
        assert!(hub.merge(&[]).is_err());
    }

    #[test]
    fn digest_tracks_master_and_replay() {
        let mut a = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        let mut b = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        assert_eq!(a.digest(), b.digest());
        a.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0])]).unwrap();
        b.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0])]).unwrap();
        assert_eq!(a.digest(), b.digest());
        b.merge(&[contribution(0, table(&[(1, 2.0)]), &[])]).unwrap();
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn view_snapshots_do_not_alias_the_hub() {
        // Copy-on-write: a merge after a pull must not mutate the
        // snapshot the worker still holds.
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        hub.merge(&[contribution(0, table(&[(7, 1.5)]), &[2.0])]).unwrap();
        let view = hub.view();
        hub.merge(&[contribution(0, table(&[(7, 9.0)]), &[3.0])]).unwrap();
        assert_eq!(view.round, 1);
        assert_eq!(view.replay.len(), 1);
        assert_eq!(hub.replay().len(), 2);
        match view.master.as_deref().unwrap() {
            AgentState::Table(entries) => assert_eq!(entries[0].1[0], 1.5),
            AgentState::Dense { .. } => panic!("expected table"),
        }
    }

    #[test]
    fn view_pull_is_zero_copy_until_the_next_merge() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0, 2.0])]).unwrap();
        // Every pull of the same round shares one frozen buffer.
        let a = hub.view();
        let b = hub.view();
        assert!(Arc::ptr_eq(&a.replay, &b.replay), "pulls must share the snapshot");
        assert!(
            Arc::ptr_eq(a.master.as_ref().unwrap(), b.master.as_ref().unwrap()),
            "pulls must share the master state"
        );
        // Only a merge detaches the hub from outstanding snapshots.
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[3.0])]).unwrap();
        let c = hub.view();
        assert!(!Arc::ptr_eq(&a.replay, &c.replay));
        assert_eq!(a.replay.len(), 2);
        assert_eq!(c.replay.len(), 3);
    }

    #[test]
    fn grads_merge_bootstraps_then_applies_one_adam_step_per_round() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.5);
        assert_eq!(hub.merge_mode(), MergeMode::Grads);
        // Round 0: no master yet — bootstrap from the state average
        // (the pushed states already embody the segment's local steps).
        hub.merge(&[
            grad_contribution(0, Some(dense(vec![1.0, 3.0])), vec![9.0, 9.0]),
            grad_contribution(1, Some(dense(vec![3.0, 5.0])), vec![9.0, 9.0]),
        ])
        .unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                assert_eq!(params.tensors[0].0, vec![2.0, 4.0]);
                assert_eq!(opt.step, 0.0, "bootstrap does not consume an optimizer step");
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        // Round 1: one hub-side Adam step on the job-order-sequenced
        // gradient average [2, 0]. At t = 1 the bias corrections cancel,
        // so the step is ≈ lr·sign(g) on the first entry and exactly
        // zero on the second.
        // Past the bootstrap, contributions need not (and, from real
        // workers, do not) carry state snapshots at all.
        hub.merge(&[
            grad_contribution(0, None, vec![1.0, 0.0]),
            grad_contribution(1, None, vec![3.0, 0.0]),
        ])
        .unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                let p = &params.tensors[0].0;
                assert!((p[0] - 1.5).abs() < 1e-6, "master moved by ≈ lr: {p:?}");
                assert_eq!(p[1], 4.0, "zero gradient leaves the entry untouched");
                assert_eq!(opt.step, 1.0);
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        assert_eq!(hub.merges(), 2);
    }

    #[test]
    fn grads_merge_rejects_contributions_without_gradients() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        let err = hub.merge(&[contribution(0, dense(vec![1.0]), &[])]).unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("native DQN engine"), "unhelpful error: {msg}");
        // A tabular master cannot take gradient steps either.
        let mut tab_hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        tab_hub.merge(&[grad_contribution(0, Some(table(&[(1, 1.0)])), vec![1.0])]).unwrap();
        assert!(tab_hub
            .merge(&[grad_contribution(0, Some(table(&[(1, 1.0)])), vec![1.0])])
            .is_err());
        // A state-less push is only legal once a master exists; the
        // bootstrap round must reject it with a named job.
        let mut fresh = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.1);
        let err = fresh.merge(&[grad_contribution(2, None, vec![1.0])]).unwrap_err();
        assert!(format!("{err:?}").contains("job 2"), "{err:?}");
    }

    #[test]
    fn merge_mode_splits_the_hub_digest() {
        let build = |mode| {
            let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
                .with_merge(mode, 1e-3);
            hub.merge(&[grad_contribution(0, Some(dense(vec![1.0, 2.0])), vec![0.5, 0.5])])
                .unwrap();
            hub
        };
        let weights = build(MergeMode::Weights);
        let grads = build(MergeMode::Grads);
        // After one (bootstrap) round the master states coincide, but
        // the digest must still distinguish the modes.
        assert_ne!(weights.digest(), grads.digest());
        assert_eq!(weights.summary().merge, MergeMode::Weights);
        assert_eq!(grads.summary().merge, MergeMode::Grads);
        assert!(grads.summary().describe().contains("grads"));
    }

    #[test]
    fn merge_mode_parse_round_trip() {
        for mode in MergeMode::ALL {
            assert_eq!(MergeMode::parse(mode.name()), Some(mode));
            assert_eq!(MergeMode::ALL[mode.ordinal()], mode);
        }
        assert_eq!(MergeMode::parse("gradients"), Some(MergeMode::Grads));
        assert_eq!(MergeMode::parse("nope"), None);
        assert_eq!(MergeMode::default(), MergeMode::Weights);
    }

    #[test]
    fn summary_reports_policy_and_per_workload_occupancy() {
        let mut hub = LearnerHub::new(16, ReplayPolicyKind::Stratified, BackendId::Coarrays);
        let mut pic = contribution(1, table(&[(2, 1.0)]), &[5.0]);
        for t in &mut pic.transitions {
            t.workload = Some(WorkloadKind::SkeletonPic);
        }
        hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0, 2.0]), pic]).unwrap();
        let s = hub.summary();
        assert_eq!(s.policy, ReplayPolicyKind::Stratified);
        assert_eq!(s.occupancy[WorkloadKind::LatticeBoltzmann.ordinal()], 2);
        assert_eq!(s.occupancy[WorkloadKind::SkeletonPic.ordinal()], 1);
        assert_eq!(s.occupancy.iter().sum::<usize>(), s.replay_len);
        let line = s.describe();
        assert!(line.contains("stratified"), "{line}");
        assert!(line.contains("lattice_boltzmann=2"), "{line}");
        assert!(line.contains("skeleton_pic=1"), "{line}");
        // A synchronous campaign reports no async extensions at all.
        assert!(!s.extensions_active());
        assert!(!line.contains("async:"), "{line}");
    }

    #[test]
    fn merge_one_blends_weights_by_staleness() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_staleness(3);
        // First push: adopted wholesale.
        hub.merge_one(&contribution(0, table(&[(1, 8.0)]), &[1.0]), 0).unwrap();
        assert_eq!(hub.generations(), 1);
        // Fresh push (staleness 0): alpha = 1/2 — a two-way average.
        hub.merge_one(&contribution(1, table(&[(1, 4.0)]), &[]), 1).unwrap();
        match hub.master().unwrap() {
            AgentState::Table(entries) => assert_eq!(entries[0].1[0], 6.0),
            AgentState::Dense { .. } => panic!("expected table"),
        }
        // Stale push (pulled at generation 0, hub now at 2 → staleness
        // 2): alpha = 1/4, so the master moves a quarter of the way.
        hub.merge_one(&contribution(2, table(&[(1, 10.0), (9, 3.0)]), &[]), 0).unwrap();
        match hub.master().unwrap() {
            AgentState::Table(entries) => {
                assert_eq!(entries[0].1[0], 7.0);
                // A cell only the push visited is adopted as-is.
                assert_eq!(entries[1], {
                    let mut q = vec![0.0; NUM_ACTIONS];
                    q[0] = 3.0;
                    (9, q)
                });
            }
            AgentState::Dense { .. } => panic!("expected table"),
        }
        let s = hub.summary();
        assert_eq!(s.generations, 3);
        assert_eq!(s.staleness[0], 2);
        assert_eq!(s.staleness[2], 1);
        assert!(s.extensions_active());
        assert!(s.describe().contains("async: 3 generations"), "{}", s.describe());
    }

    #[test]
    fn merge_one_enforces_the_staleness_contract_with_named_jobs() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_staleness(1);
        for g in 0..3 {
            hub.merge_one(&contribution(g, table(&[(1, 1.0)]), &[]), g.saturating_sub(1))
                .unwrap();
        }
        // Staleness 3 > window 1: rejected, naming job and generations.
        let err = hub
            .merge_one(&contribution(7, table(&[(1, 1.0)]), &[]), 0)
            .unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("job 7"), "{msg}");
        assert!(msg.contains("generation 0"), "{msg}");
        assert!(msg.contains("generation 3"), "{msg}");
        assert!(msg.contains("window 1"), "{msg}");
        // A pull generation from the future is a driver bug too.
        let err = hub
            .merge_one(&contribution(9, table(&[(1, 1.0)]), &[]), 99)
            .unwrap_err();
        assert!(format!("{err:?}").contains("job 9"), "{err:?}");
        // Rejected merges leave the hub untouched.
        assert_eq!(hub.generations(), 3);
    }

    #[test]
    fn merge_one_grads_steps_directly_on_the_push() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.5)
            .with_staleness(2);
        // Bootstrap adopts the pushed state.
        hub.merge_one(&grad_contribution(0, Some(dense(vec![1.0, 4.0])), vec![9.0, 9.0]), 0)
            .unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                assert_eq!(params.tensors[0].0, vec![1.0, 4.0]);
                assert_eq!(opt.step, 0.0);
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        // One push = one Adam step on exactly that push's gradients.
        hub.merge_one(&grad_contribution(1, None, vec![2.0, 0.0]), 1).unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { params, opt } => {
                let p = &params.tensors[0].0;
                assert!((p[0] - 0.5).abs() < 1e-6, "master moved by ≈ lr: {p:?}");
                assert_eq!(p[1], 4.0);
                assert_eq!(opt.step, 1.0);
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        // A gradient-less push past bootstrap still fails with a name.
        let err = hub
            .merge_one(&contribution(5, dense(vec![0.0, 0.0]), &[]), 2)
            .unwrap_err();
        assert!(format!("{err:?}").contains("job 5"), "{err:?}");
    }

    #[test]
    fn dense_blend_weights_master_and_push() {
        let master = dense(vec![0.0, 8.0]);
        let push = dense(vec![4.0, 0.0]);
        match AgentState::blend(&master, &push, 0.25).unwrap() {
            AgentState::Dense { params, .. } => {
                assert_eq!(params.tensors[0].0, vec![1.0, 6.0]);
            }
            AgentState::Table(_) => panic!("expected dense"),
        }
        assert!(AgentState::blend(&master, &table(&[(1, 1.0)]), 0.5).is_err());
        assert!(AgentState::blend(&master, &push, 1.5).is_err());
    }

    #[test]
    fn hub_lr_schedule_decays_and_round_trips() {
        assert_eq!(HubLrSchedule::Constant.lr_at(1e-3, 0), 1e-3);
        assert_eq!(HubLrSchedule::Constant.lr_at(1e-3, 10_000), 1e-3);
        let inv = HubLrSchedule::InvSqrt { period: 4 };
        assert_eq!(inv.lr_at(1.0, 0), 1.0);
        assert!((inv.lr_at(1.0, 4) - 1.0 / 2f32.sqrt()).abs() < 1e-6);
        assert!(inv.lr_at(1.0, 16) < inv.lr_at(1.0, 4));
        let halving = HubLrSchedule::Halving { period: 10 };
        assert_eq!(halving.lr_at(0.8, 9), 0.8);
        assert_eq!(halving.lr_at(0.8, 10), 0.4);
        assert_eq!(halving.lr_at(0.8, 25), 0.2);
        for schedule in [
            HubLrSchedule::Constant,
            HubLrSchedule::InvSqrt { period: 7 },
            HubLrSchedule::Halving { period: 3 },
        ] {
            assert_eq!(HubLrSchedule::parse(&schedule.to_string()), Some(schedule));
        }
        assert_eq!(HubLrSchedule::parse("invsqrt"), Some(HubLrSchedule::InvSqrt { period: 100 }));
        assert_eq!(HubLrSchedule::parse("nope"), None);
        assert_eq!(HubLrSchedule::parse("halving:0"), Some(HubLrSchedule::Halving { period: 1 }));
    }

    #[test]
    fn scheduled_multi_step_hub_adam_consumes_steps() {
        let mut hub = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_merge(MergeMode::Grads, 0.5)
            .with_hub_optimizer(HubLrSchedule::InvSqrt { period: 1 }, 2);
        hub.merge(&[grad_contribution(0, Some(dense(vec![0.0, 0.0])), vec![1.0, 1.0])])
            .unwrap();
        hub.merge(&[grad_contribution(0, None, vec![1.0, 1.0])]).unwrap();
        match hub.master().unwrap() {
            AgentState::Dense { opt, .. } => {
                assert_eq!(opt.step, 2.0, "hub_steps=2 means two Adam steps per merge");
            }
            AgentState::Table(_) => panic!("expected dense master"),
        }
        let s = hub.summary();
        assert_eq!(s.hub_steps, 2);
        assert_eq!(s.lr_schedule, HubLrSchedule::InvSqrt { period: 1 });
        assert!(s.extensions_active());
        assert!(s.describe().contains("hub adam: 2 step(s)/merge"), "{}", s.describe());
    }

    #[test]
    fn sync_digest_ignores_inactive_extensions() {
        // The extension fields must not perturb a default-optimizer
        // synchronous hub's digest — that is the PR 8 byte-identity
        // claim. Two identical sync runs, one built through the new
        // builders with default values, must agree.
        let run = |hub: &mut LearnerHub| {
            hub.merge(&[contribution(0, table(&[(1, 1.0)]), &[1.0])]).unwrap();
            hub.digest()
        };
        let mut plain = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        let mut built = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_hub_optimizer(HubLrSchedule::Constant, 1)
            .with_staleness(4);
        assert_eq!(run(&mut plain), run(&mut built));
        // A non-default optimizer *does* split the digest.
        let mut scheduled = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays)
            .with_hub_optimizer(HubLrSchedule::Halving { period: 5 }, 1);
        assert_ne!(run(&mut scheduled), plain.digest());
        // And so does a single incremental merge.
        let mut incremental = LearnerHub::new(8, ReplayPolicyKind::Uniform, BackendId::Coarrays);
        incremental.merge_one(&contribution(0, table(&[(1, 1.0)]), &[1.0]), 0).unwrap();
        assert_ne!(incremental.digest(), plain.digest());
    }

    #[test]
    fn sync_mode_parse_round_trip() {
        assert_eq!(SyncMode::parse("sync", 3), Some(SyncMode::Sync));
        assert_eq!(SyncMode::parse("async", 3), Some(SyncMode::Async { staleness: 3 }));
        assert_eq!(SyncMode::parse("nope", 0), None);
        assert_eq!(SyncMode::default(), SyncMode::Sync);
        assert_eq!(SyncMode::Sync.staleness(), 0);
        assert_eq!(SyncMode::Async { staleness: 2 }.staleness(), 2);
        assert!(!SyncMode::Sync.runs_async());
        assert!(!SyncMode::Async { staleness: 0 }.runs_async());
        assert!(SyncMode::Async { staleness: 1 }.runs_async());
        assert_eq!(SyncMode::Async { staleness: 2 }.to_string(), "async(S=2)");
    }
}
